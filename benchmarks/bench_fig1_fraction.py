"""Paper Fig. 1: DIGC share of end-to-end ViG inference vs resolution.

Times a full ViG forward against the same forward with the graph fixed
(DIGC ablated): fraction = 1 - t_fixed/t_full. The paper reports 50-95%
on CPU; the qualitative claim is that the share GROWS with resolution."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import vig
from repro.models.module import init_params
from repro.core.digc import digc_blocked
from repro.core.graph import mr_aggregate
from benchmarks.common import emit, timeit


def _forward_fixed_graph(params, imgs, cfg, idx_cache):
    """ViG forward with precomputed neighbor indices (DIGC ablated)."""
    x = vig.patchify(imgs, cfg.patch) @ params["stem"]
    x = x + params["pos"]
    grid = cfg.base_grid
    gb = 0
    for si, depth in enumerate(cfg.depths):
        r = cfg.reduce_ratios[si] if si < len(cfg.reduce_ratios) else 1
        for bi in range(depth):
            bp = params[f"stage{si}"][f"block{bi}"]
            h = vig._ln(x, bp["ln_g"]["scale"])
            h = h @ bp["fc_in"]
            cond = vig._pool_conodes(h, grid, r)  # None = self-graph
            idx = idx_cache[gb]
            agg = mr_aggregate(h, cond if cond is not None else h, idx)
            h = jnp.concatenate([h, agg], axis=-1) @ bp["fc_graph"]
            h = jax.nn.gelu(h) @ bp["fc_out"]
            x = x + h
            f = vig._ln(x, bp["ln_f"]["scale"])
            x = x + jax.nn.gelu(f @ bp["fc1"]) @ bp["fc2"]
            gb += 1
        if si + 1 < len(cfg.depths):
            x = vig._downsample(x, grid, params[f"down{si}"])
            grid //= 2
    return jnp.mean(x, axis=1) @ params["head"]


def run(resolutions=(256, 512, 1024), depth=4):
    rng = np.random.default_rng(0)
    base = vig.VIG_VARIANTS["vig_ti_iso"]
    for res in resolutions:
        cfg = base.replace(image_size=res, depths=(depth,), num_classes=100)
        params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
        imgs = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)

        full = jax.jit(lambda p, im: vig.vig_forward(p, im, cfg))
        t_full = timeit(full, params, imgs, iters=2)

        # precompute the graphs once, then time the DIGC-ablated model
        n = cfg.base_grid ** 2
        work = vig.count_digc_work(cfg)
        x0 = vig.patchify(imgs, cfg.patch) @ params["stem"] + params["pos"]
        idx_cache = [
            digc_blocked(x0, x0, k=w["k"], dilation=w["dilation"])
            for w in work
        ]
        fixed = jax.jit(lambda p, im: _forward_fixed_graph(p, im, cfg, idx_cache))
        t_fixed = timeit(fixed, params, imgs, iters=2)

        frac = max(0.0, 1.0 - t_fixed / t_full)
        emit(f"fig1/digc_fraction_res{res}", t_full * 1e6,
             f"fixed_us={t_fixed*1e6:.0f};digc_share={frac:.2f}")
    return True


if __name__ == "__main__":
    run()
