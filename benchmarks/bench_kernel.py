"""DIGC kernel microbenchmarks (supplement): blocked-impl block-size
sweep + the §Perf hillclimb progression (modeled TPU terms + measured
recall for the approximate variants). Wall-clock on XLA:CPU; the Pallas
kernel itself is validated in interpret mode (tests)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DigcSpec, digc
from repro.core.perfmodel import tpu_digc_estimate
from benchmarks.common import emit, timeit


def _hillclimb():
    """EXPERIMENTS.md §Perf Cell 1, regenerated: modeled terms at the
    paper's largest workload (ViG @ 2048^2)."""
    w = dict(n=16384, m=16384, d=192, k=8, dilation=2)
    iters = [
        ("K0_baseline", {}),
        ("K1_packed", dict(packed=True)),
        ("K2_bf16_mxu", dict(packed=True, mxu_bf16=True)),
        ("K3_bf16_hbm", dict(packed=True, mxu_bf16=True, input_bytes=2)),
        ("K4_big_blocks", dict(packed=True, mxu_bf16=True, input_bytes=2,
                               block_n=512, block_m=1024)),
        ("K5_bucketed_r2", dict(packed=True, mxu_bf16=True, input_bytes=2,
                                block_n=512, block_m=1024, bucket_rounds=2)),
        # PR 6: sorted two-level merge (bitonic LSM + single GMM pass).
        # K6 is the *exact* fp32 form at default tiles; K7 stacks it on
        # the packed/bf16/big-block pipeline it was designed for.
        ("K6_bitonic_exact", dict(kernel_merge="bitonic")),
        ("K7_bitonic_packed", dict(kernel_merge="bitonic", packed=True,
                                   mxu_bf16=True, input_bytes=2,
                                   block_n=512, block_m=1024)),
    ]
    base = None
    for name, kw in iters:
        e = tpu_digc_estimate(**w, **kw)
        base = base or e["latency_s"]
        mxu = e["flops"] / 197e12 / e["latency_s"]
        emit(f"kernel/{name}_us", e["latency_s"] * 1e6,
             f"bound={e['bound']};speedup={base/e['latency_s']:.2f}x;mxu_frac={mxu:.3f}")


def _bucketed_recall(n=2048):
    from repro.kernels import ref as kref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, 192)), jnp.float32)
    _, i_ref = kref.digc_reference(x, x, kd=16)
    a = np.asarray(i_ref)
    for rounds in (1, 2, 3):
        spec = DigcSpec(impl="pallas", k=16, block_n=128, block_m=256,
                        packed=True, bucket_rounds=rounds)
        i_b = digc(x, spec=spec)
        b = np.asarray(i_b)
        rec = np.mean([len(set(a[i]) & set(b[i])) / 16 for i in range(n)])
        emit(f"kernel/bucketed_r{rounds}_recall", rec * 100,
             f"recall@16 percent, N={n} self-graph (registry pallas spec)")


def _merge_ablation(x, k, iters=2):
    """Engine merge-strategy sweep at a fixed tile config: the LSM/GMM
    realization is the lever the block_m sweep above cannot move."""
    n, d = x.shape[-2], x.shape[-1]
    for merge in ("topk", "select", "packed"):
        spec = DigcSpec(impl="blocked", k=k, block_m=1024, merge=merge)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x, iters=iters)
        emit(f"kernel/engine_merge_{merge}_us", t * 1e6,
             f"N={n};D={d};block_m=1024")


def _group_w_ablation(x, k, iters=2):
    """select-merge group width at large block_m (ROADMAP: does a
    two-word 64-lane mask beat the one-word 32-lane default when each
    tile holds thousands of candidates?). Wider groups halve the
    per-round group-min reduction but double the winning-group gather
    and pay a second mask word."""
    n, d = x.shape[-2], x.shape[-1]
    bm = min(4096, n)
    base = None
    for w in (32, 64):
        spec = DigcSpec(impl="blocked", k=k, block_m=bm, merge="select",
                        group_w=w)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        # The w32/w64 gap is ~25% on CPU: needs more samples than the
        # block-size sweep to stay out of the noise floor.
        t = timeit(fn, x, warmup=2, iters=max(3, iters))
        base = base or t
        emit(f"kernel/select_groupw{w}_us", t * 1e6,
             f"N={n};D={d};block_m={bm};speedup_vs_w32={base/t:.2f}x")


def _merge_sweep(smoke: bool = False, iters=2):
    """Kernel merge-strategy sweep: measured interpret wall-clock (the
    CPU floor) plus the modeled TPU bound/mxu_frac for the same config —
    the derived fields are what the interpret numbers cannot show."""
    n = 256 if smoke else 1024
    kd, bn, bm = 16, 128, 256  # bm % kd == 0, bm // kd >= 2
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n, 192)), jnp.float32)
    variants = [
        ("legacy", dict(kernel_merge="legacy")),
        ("bucket_r2", dict(kernel_merge="legacy", packed=True,
                           bucket_rounds=2)),
        ("bitonic", dict(kernel_merge="bitonic")),
    ]
    for name, kw in variants:
        spec = DigcSpec(impl="pallas", k=kd, block_n=bn, block_m=bm, **kw)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x, iters=iters)
        e = tpu_digc_estimate(
            n=n, m=n, d=192, k=kd, dilation=1, block_n=bn, block_m=bm,
            packed=kw.get("packed", False),
            bucket_rounds=kw.get("bucket_rounds", 0),
            kernel_merge=kw["kernel_merge"],
        )
        mxu = e["flops"] / 197e12 / e["latency_s"]
        emit(f"kernel/merge_{name}_us", t * 1e6,
             f"interpret;N={n};kd={kd};bn={bn};bm={bm};"
             f"bound={e['bound']};tpu_model_us={e['latency_s'] * 1e6:.1f};"
             f"mxu_frac={mxu:.3f}")


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    n, d, k = (512, 192, 9) if smoke else (4096, 192, 9)
    iters = 1 if smoke else 2
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    for bm in (256, 512, 1024):
        spec = DigcSpec(impl="blocked", k=k, block_m=bm)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x, iters=iters)
        emit(f"kernel/blocked_bm{bm}_us", t * 1e6, f"N={n};D={d}")
    _merge_ablation(x, k, iters=iters)
    _group_w_ablation(x, k, iters=iters)
    _merge_sweep(smoke, iters=iters)
    _hillclimb()
    _bucketed_recall(n=256 if smoke else 2048)
    return True


if __name__ == "__main__":
    run()
