"""Serving-path benchmark: the jitted functional-state ``VigServeEngine``
vs the legacy eager ``DigcCache`` shim per request, plus the
multi-tenant ragged-arrival trace (bucketed vs the PR-3 fixed-batch
policy).

The acceptance workload is the ViG N=3136 regime (224^2 / patch 4 —
the grid where PR-2 measured the eager cache-aware cluster tier): the
jitted path must serve the cluster tier with **no eager fallback** at
per-request latency <= the eager shim's. Rows record both modes plus
the speedup, per tier, so the jit-vs-eager gap is part of the perf
trajectory.

The multi-tenant rows serve one ragged trace (arrival waves of 1-8
interleaved tenants) through the request path twice: ``buckets=
(1,2,4,8)`` (pad to the smallest fitting bucket, <= 4 compiled
programs) and ``buckets=None`` (the PR-3 baseline: exact-size ticks,
one program per distinct batch size). The cold rows include program
compilation — exactly what the one-program-per-batch-size engine pays
on a ragged stream — and the warm rows re-serve the same trace through
the already-compiled programs (steady state).

The ``serve/guarded_*`` rows price the fault-tolerance guards
(DESIGN.md §11) on the fault-free path: the same ragged trace with
the admission/state screening armed vs ``guards=False``, with the
warm overhead ratio pinned by the acceptance bar (<= 1.05x).

The ``serve/stale_*`` rows price stale-graph serving (DESIGN.md §12):
the same steady multi-tenant trace under every reuse policy vs
``reuse`` off, a drift-gated high-res (N=12544) per-tick row where the
acceptance bar demands >= 1.3x warm speedup, and the recall-vs-
drift_tau sweep that records what graph quality each gate width buys.
``serve/clustertick_*`` profiles the cluster tier's index-build vs
dispatch split across batch sizes (the superlinear-B question,
ROADMAP).

The ``serve/sched_*`` rows price the SLO-bounded admission scheduler
(DESIGN.md §14): a seeded Poisson+burst arrival trace replayed under a
``VirtualClock`` through the auto-tuned bucketed scheduler vs a
fixed-cadence exact-size server, cold (compile-count capped vs
one-program-per-size) and warm (coalesced full ticks vs sub-width
windows), in the dispatch-bound N=256 regime where per-tick fixed
cost is what batching amortizes.
"""

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

TUNE_CACHE = ".digc_tune.json"


def _engine(cfg, params, impl, mode, batch, smoke):
    from repro.serve.engine import VigServeEngine

    return VigServeEngine(
        cfg, params, digc_impl=impl, batch=batch, mode=mode,
        # blocked autotunes through the committed host-keyed cache;
        # smoke keeps its toy workloads out of it (in-memory tuner).
        autotune=(impl == "blocked"),
        tuner_path=None if smoke else TUNE_CACHE,
    )


def run(smoke: bool = False, res: int = 224, batch: int = 2, iters: int = 3):
    from repro.models import vig
    from repro.models.module import init_params

    if smoke:
        res, iters = 32, 1
    # res=224 / patch 4 -> grid 56 -> N=3136 (the PR-2 cluster-tier
    # measurement workload), one isotropic stage of two blocks.
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=res, patch=4, embed_dims=(96,), depths=(2,),
        num_classes=10, k=9,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(
        rng.standard_normal((batch, res, res, 3)), jnp.float32
    )
    n = cfg.base_grid ** 2
    for impl in ("cluster", "blocked"):
        per_mode = {}
        for mode in ("jit", "eager"):
            eng = _engine(cfg, params, impl, mode, batch, smoke)
            # Two warmup calls: compile + engage the warm start, so the
            # measured steady state is what a serving replica sees.
            t = timeit(lambda: eng.infer(imgs), warmup=2, iters=iters)
            per_mode[mode] = t
            emit(
                f"serve/{impl}_{mode}_us", t * 1e6,
                f"B={batch};N={n};per-request forward;mode={mode};"
                f"requests_served={eng.requests_served}",
            )
        emit(
            f"serve/{impl}_jit_speedup", per_mode["eager"] / per_mode["jit"],
            f"B={batch};N={n};eager_us={per_mode['eager'] * 1e6:.0f};"
            f"jit_us={per_mode['jit'] * 1e6:.0f};x_eager_over_jit "
            "(>=1 means the jitted functional-state path wins)",
        )
    _run_multitenant(cfg, params, n, res, smoke)
    _run_guarded(cfg, params, n, res, smoke)
    _run_stale(cfg, params, n, res, smoke)
    _run_stale_highres(smoke)
    _run_stale_recall(smoke)
    _run_clustertick_profile(smoke)
    _run_multires(smoke)
    _run_sharded(smoke)
    _run_sched(smoke)
    return True


def _serve_trace(engine, waves, images):
    """Submit the ragged trace wave by wave and drain; returns wall
    seconds for the full trace (one engine tick per wave)."""
    from repro.serve.engine import VigRequest

    uid = 0
    t0 = time.perf_counter()
    for wave in waves:
        for tenant in wave:
            engine.submit(VigRequest(uid=uid, image=images[tenant],
                                     tenant=tenant))
            uid += 1
        engine.step()
    assert not engine.queue
    return time.perf_counter() - t0


def _run_multitenant(cfg, params, n, res, smoke):
    """Ragged multi-tenant trace: bucket policies vs the PR-3
    fixed-batch (one program per batch size) baseline.

    The bucket set is a compile-count vs padding-waste dial: the
    coarse ``{8}`` policy compiles one program and pads everything
    (best cold-trace throughput — ragged streams are compile-
    dominated), ``{1,2,4,8}`` compiles four and pads by at most 2x
    (best steady-state latency among the bucketed policies), and the
    PR-3 baseline compiles one program per distinct tick size. Rows
    record cold (incl. compiles) and warm (steady) per policy.
    """
    from repro.serve.engine import VigServeEngine

    impl = "cluster"  # the stateful showcase tier (per-slot warm starts)
    if smoke:
        wave_sizes = (1, 3, 2, 4)
        policies = (("b1_2_4", (1, 2, 4)), ("b4", (4,)), ("fixed", None))
        slots = 4
    else:
        wave_sizes = (1, 3, 8, 2, 5, 4, 7, 6)
        policies = (("b1_2_4_8", (1, 2, 4, 8)), ("b8", (8,)),
                    ("fixed", None))
        slots = 8
    # tenants cycle through the slots; wave w serves tenants
    # w, w+1, ... (mod slots) so arrivals interleave raggedly
    waves = [
        [(w + i) % slots for i in range(size)]
        for w, size in enumerate(wave_sizes)
    ]
    total = sum(wave_sizes)
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(slots)]

    results = {}
    for policy, bconf in policies:
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=bconf, batch=slots)
        cold = _serve_trace(eng, waves, images)  # includes compiles
        cold_ticks = sorted(eng.bucket_ticks.items())  # before warm pass
        warm = _serve_trace(eng, waves, images)  # steady state
        results[policy] = (cold, warm, eng)
        emit(
            f"serve/multitenant_{policy}_cold_us", cold / total * 1e6,
            f"N={n};requests={total};waves={list(wave_sizes)};"
            f"programs={eng.compile_count};"
            f"bucket_ticks={cold_ticks};"
            "per-request incl. compiles (ragged trace, cluster tier)",
        )
        emit(
            f"serve/multitenant_{policy}_warm_us", warm / total * 1e6,
            f"N={n};requests={total};steady state, programs compiled",
        )
    for policy, _ in policies[:-1]:  # each bucketed policy vs PR-3
        for phase, idx in (("cold", 0), ("warm", 1)):
            emit(
                f"serve/multitenant_{policy}_speedup_{phase}",
                results["fixed"][idx] / results[policy][idx],
                f"N={n};requests={total};x_fixed_over_{policy};"
                f"{policy}_programs={results[policy][2].compile_count};"
                f"fixed_programs={results['fixed'][2].compile_count}",
            )


def _run_guarded(cfg, params, n, res, smoke):
    """Guard overhead on the fault-free path (DESIGN.md §11).

    The same ragged trace as the multitenant rows, served with the
    fault-tolerance guards armed (admission finiteness screen, per-row
    integrity fingerprints, state finiteness checks — the engine
    default) vs ``guards=False`` (the unguarded PR-6 path). The
    guarded warm row is the number the acceptance bar pins: steady-
    state overhead must stay within a few percent, since every healthy
    tick pays the screening whether or not a fault ever occurs. No
    fault plan is attached — injection costs nothing when absent; this
    measures detection, not injection.
    """
    from repro.serve.engine import VigServeEngine

    impl = "cluster"
    if smoke:
        wave_sizes, bconf, slots = (1, 3, 2, 4), (1, 2, 4), 4
    else:
        wave_sizes, bconf, slots = (1, 3, 8, 2, 5, 4, 7, 6), (1, 2, 4, 8), 8
    waves = [
        [(w + i) % slots for i in range(size)]
        for w, size in enumerate(wave_sizes)
    ]
    total = sum(wave_sizes)
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(slots)]
    engines, cold_s, warm_s = {}, {}, {}
    for label, guards in (("unguarded", False), ("guarded", True)):
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=bconf, batch=slots, guards=guards)
        engines[label] = eng
        cold_s[label] = _serve_trace(eng, waves, images)  # incl. compiles
        warm_s[label] = float("inf")
    # Interleaved best-of-5 warm passes: the overhead row divides two
    # small numbers, so back-to-back measurement (all passes of one
    # engine, then the other) would bake clock/cache drift into the
    # ratio; alternating engines cancels it.
    for _ in range(5):
        for label, eng in engines.items():
            warm_s[label] = min(warm_s[label],
                                _serve_trace(eng, waves, images))
    for eng in engines.values():
        assert eng.stats()["quarantines"] == 0  # fault-free by design
    results = {label: (cold_s[label], warm_s[label]) for label in engines}
    for phase, idx in (("cold", 0), ("warm", 1)):
        emit(
            f"serve/guarded_{phase}_us", results["guarded"][idx] / total * 1e6,
            f"N={n};requests={total};guards on, no fault plan;"
            f"unguarded_us={results['unguarded'][idx] / total * 1e6:.0f};"
            + ("per-request incl. compiles" if phase == "cold"
               else "steady state"),
        )
        emit(
            f"serve/guarded_overhead_{phase}",
            results["guarded"][idx] / results["unguarded"][idx],
            f"N={n};requests={total};x_guarded_over_unguarded "
            "(1.0 = free; acceptance bar: warm <= 1.05)",
        )


def _stale_spec(policy, *, impl="cluster", k=9, max_stale=8):
    from repro.core.builder import DEFAULT_DRIFT_TAU, DigcSpec

    extra = {}
    if policy is not None:
        extra = dict(reuse=policy, drift_tau=DEFAULT_DRIFT_TAU,
                     max_stale=max_stale)
    return DigcSpec(impl=impl, k=k, **extra)


def _run_stale(cfg, params, n, res, smoke):
    """Stale-graph serving policies on a steady multi-tenant stream
    (DESIGN.md §12).

    Each tenant re-submits the *same* image every tick — the
    steady-stream limit where per-row drift is ~0, so the reuse gate's
    headroom is maximal: ``tick``/``layer`` serve the cached graph
    (with a rebuild every ``max_stale`` ticks), ``overlap`` serves the
    cached graph while refreshing it unconditionally (paying the build
    off the serving path's critical answer, not skipping it), and
    ``off`` rebuilds per call — today's baseline. Cold rows include
    compiles; warm rows are best-of-3 steady state. The per-policy
    reuse/rebuild split from ``stats()`` lands in the derived column,
    so the row is auditable against the gate's actual behavior."""
    from repro.serve.engine import VigServeEngine

    slots, ticks = (2, 2) if smoke else (4, 4)
    waves = [list(range(slots))] * ticks
    total = slots * ticks
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(slots)]
    policies = (("off", None), ("reuse_layer", "layer"),
                ("reuse_tick", "tick"), ("overlap", "overlap"))
    results = {}
    for label, policy in policies:
        spec = _stale_spec(policy)
        eng = VigServeEngine(cfg, params, digc_impl=spec, autotune=False,
                             buckets=(slots,), batch=slots)
        cold = _serve_trace(eng, waves, images)  # includes compiles
        warm = float("inf")
        for _ in range(3):
            warm = min(warm, _serve_trace(eng, waves, images))
        st = eng.stats()
        results[label] = (cold, warm)
        info = (f"N={n};requests={total};policy={policy or 'off'};"
                f"graph_reuses={st['graph_reuses']};"
                f"graph_rebuilds={st['graph_rebuilds']}")
        emit(f"serve/stale_{label}_cold_us", cold / total * 1e6,
             info + ";per-request incl. compiles")
        emit(f"serve/stale_{label}_warm_us", warm / total * 1e6,
             info + ";steady state")
    for label, _ in policies[1:]:
        emit(
            f"serve/stale_{label}_speedup_warm",
            results["off"][1] / results[label][1],
            f"N={n};requests={total};x_off_over_{label} "
            "(steady stream, drift ~0)",
        )


def _run_stale_highres(smoke):
    """The acceptance workload: N=12544 (448^2 / patch 4), where DIGC
    is ~95% of the tick (PAPER.md). One jitted stateful ``vig_forward``
    per tick on a steady stream; the ``tick`` policy must clear >= 1.3x
    warm per-tick speedup over ``reuse`` off. Uses the cluster tier —
    the N=12544 serving tier of record — with a long staleness bound so
    the steady window prices the gate, not the periodic refresh."""
    from repro.models import vig
    from repro.models.module import init_params

    res = 32 if smoke else 448
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=res, patch=4, embed_dims=(48,), depths=(2,),
        num_classes=10, k=9,
    )
    n = cfg.base_grid ** 2
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)

    per_policy = {}
    for label, policy in (("off", None), ("tick", "tick")):
        spec = _stale_spec(policy, max_stale=64)
        state = vig.init_vig_state(cfg, 1, spec)
        fwd = jax.jit(lambda p, im, s, _spec=spec: vig.vig_forward(
            p, im, cfg, digc_impl=_spec, state=s))
        for _ in range(2):  # compile + engage the warm/reuse branch
            _, state = fwd(params, img, state)
        jax.block_until_ready(state.entries)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            out, state = fwd(params, img, state)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        per_policy[label] = best
        emit(
            f"serve/stale_highres_{label}_warm_us", best * 1e6,
            f"N={n};B=1;cluster tier;per-tick steady state;"
            f"policy={policy or 'off'}",
        )
    emit(
        "serve/stale_highres_speedup_warm",
        per_policy["off"] / per_policy["tick"],
        f"N={n};x_off_over_tick;acceptance bar: >= 1.3 at N=12544",
    )


def _run_stale_recall(smoke):
    """Recall vs drift_tau: what graph quality each gate width buys.

    The stream mirrors what the drift statistic sees on real embeddings
    (DESIGN.md §12): tiny frame-to-frame jitter (relative drift ~1e-4,
    the graph barely moves) punctuated by scene cuts every third tick —
    fresh content at a shifted energy level. The cut energies are
    normalized so the gate sees a *pinned* ~0.077 relative drift (the
    0.06-0.14 content band) at every N, instead of riding the
    statistic's O(1/sqrt(N*D)) sampling noise. Replayed through
    the reuse gate at every tau, scoring the *served* graph against a
    per-call exact rebuild — the same replay ``core.tuner.tune_reuse``
    uses for its recall floor, so the recorded curve is exactly what
    the tuner would decide from. Taus below the cut band rebuild on
    cuts and reuse through jitter (high recall); taus above it serve a
    dead graph across cuts and recall collapses. One row per (N, tau);
    the default-tau row carries the acceptance bar (recall >= 0.95)."""
    from repro.core.builder import DEFAULT_DRIFT_TAU, DigcSpec
    from repro.core.tuner import tune_reuse

    sizes = (64,) if smoke else (3136, 12544)
    taus = (0.01, 0.02, DEFAULT_DRIFT_TAU, 0.1, 0.2)
    ticks_n = 4 if smoke else 8
    rng = np.random.default_rng(0)
    for n in sizes:
        h = rng.standard_normal((1, n, 32)).astype(np.float32)
        h /= np.sqrt((h * h).mean())
        energy, cuts = 1.0, 0
        ticks = []
        for t in range(ticks_n):
            if t > 0 and t % 3 == 0:
                # scene cut: fresh content, energy stepped by 1.08x so
                # the gate sees ~0.077 relative drift deterministically
                energy = energy / 1.08 if cuts % 2 == 0 else energy * 1.08
                cuts += 1
                f = rng.standard_normal(h.shape).astype(np.float32)
                h = f / np.sqrt((f * f).mean()) * np.sqrt(energy)
            else:
                # frame jitter: drift ~1e-4, graph nearly static
                h = h + 0.01 * rng.standard_normal(h.shape).astype(
                    np.float32)
            ticks.append([("s", jnp.asarray(h), None)])
        _, results = tune_reuse(
            ticks, spec=DigcSpec(impl="blocked", k=9), policy="layer",
            taus=taus, max_stale=8, recall_floor=0.95,
        )
        for r in results:
            bar = (";acceptance bar: recall >= 0.95"
                   if r.drift_tau == DEFAULT_DRIFT_TAU else "")
            emit(
                f"serve/stale_recall_n{n}_tau{r.drift_tau:g}",
                r.recall,
                f"N={n};reuse_frac={r.reuse_frac:.2f};"
                f"admitted={r.admitted};recall of served graph vs "
                f"exact rebuild (synthetic drift stream){bar}",
            )


def _run_clustertick_profile(smoke):
    """Cluster-tick cost split across batch size: index build (k-means
    + member scatter) vs search/dispatch (probe + top-k). The open
    ROADMAP question is why the cluster tick scales *superlinearly* in
    B — these rows pin which half grows faster than linear, per B, so
    the answer is a table lookup instead of a rerun. Self-graph
    workload (no shared co-nodes): the index is vmapped per row,
    matching what serving pays."""
    from repro.core.strategies import (
        cluster_digc,
        default_cluster_params,
        _cluster_index,
    )

    n, bs = (64, (1, 2)) if smoke else (3136, (1, 2, 4, 8))
    d, k = 32, 9
    n_clusters, _ = default_cluster_params(n, None, None)
    cap = max(int(n / n_clusters * 2.0), k)
    rng = np.random.default_rng(0)
    base = None
    for b in bs:
        x = jnp.asarray(rng.standard_normal((b, n, d)), jnp.float32)
        index_fn = jax.jit(jax.vmap(
            lambda yb: _cluster_index(yb, n_clusters=n_clusters, cap=cap,
                                      seed=0)
        ))
        total_fn = jax.jit(lambda a: cluster_digc(a, k=k))
        t_index = timeit(lambda: index_fn(x), warmup=1,
                         iters=1 if smoke else 3)
        t_total = timeit(lambda: total_fn(x), warmup=1,
                         iters=1 if smoke else 3)
        t_dispatch = max(t_total - t_index, 0.0)
        if base is None:
            base = (t_index, t_dispatch)
        emit(
            f"serve/clustertick_b{b}_index_us", t_index * 1e6,
            f"N={n};B={b};k-means + member scatter;"
            f"x_vs_b1={t_index / base[0]:.2f} (linear would be {b}.00)",
        )
        emit(
            f"serve/clustertick_b{b}_dispatch_us", t_dispatch * 1e6,
            f"N={n};B={b};probe + top-k (total - index);"
            f"x_vs_b1={t_dispatch / max(base[1], 1e-12):.2f} "
            f"(linear would be {b}.00)",
        )


_SHARDED_SNIPPET = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigRequest, VigServeEngine

res, waves = {res}, {waves}
cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
    image_size=res, patch=4, embed_dims=(32,), depths=(2,),
    num_classes=10, k=9, digc_impl="ring")
params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
slots = 4
images = [rng.standard_normal((res, res, 3)).astype(np.float32)
          for _ in range(slots)]
wave_t = [[(w + i) % slots for i in range(size)]
          for w, size in enumerate(waves)]

def trace(eng):
    uid = 0
    t0 = time.perf_counter()
    for wave in wave_t:
        for tenant in wave:
            eng.submit(VigRequest(uid=uid, image=images[tenant],
                                  tenant=tenant))
            uid += 1
        eng.step()
    return time.perf_counter() - t0

out = {{}}
for ndev in (1, {ndev}):
    mesh = jax.make_mesh((ndev,), ("ring",))
    eng = VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                         buckets=(1, 2, 4), mesh=mesh, mesh_axis="ring")
    cold = trace(eng)
    warm = trace(eng)
    out[ndev] = dict(cold=cold, warm=warm, programs=eng.compile_count,
                     n=cfg.base_grid ** 2, requests=sum(waves))
print("SHARDED_JSON " + json.dumps(out))
"""


def _run_multires(smoke):
    """Multi-resolution lattice rows (DESIGN.md §13): one
    ``image_sizes=`` engine serving a mixed ragged-resolution trace vs
    the one-engine-per-size baseline (each size gets its own dedicated
    engine; the sum of their trace times is what a deployment without
    the lattice pays). The acceptance cells are N=3136 (224^2/4) and
    N=12544 (448^2/4) — the grid where DIGC is ~95% of the tick
    (PAPER.md) — on the cluster tier, reuse off, so the rows price the
    lattice's admission/program surface, not the §12 gate. Per-N warm
    per-tick rows compare each lattice cell against its dedicated
    engine at steady state (the lattice's overhead is dict lookups and
    per-size state scatter; the bar is parity)."""
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigRequest, VigServeEngine

    sizes = (16, 32) if smoke else (224, 448)
    s0, s1 = sizes
    impl = "cluster"
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=s0, patch=4, embed_dims=(48,), depths=(2,),
        num_classes=10, k=9,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    ns = {s: (s // cfg.patch) ** 2 for s in sizes}
    rng = np.random.default_rng(0)
    # mixed ragged trace: A/B ride the small cell (buckets 1-2), C
    # holds the large one — the arrival shape a detection deployment
    # sees (many small crops, few full frames)
    waves = [[("A", s0)], [("B", s0), ("C", s1)],
             [("A", s0), ("B", s0)], [("C", s1)], [("A", s0)]]
    total = sum(len(w) for w in waves)
    images = {}
    for wave in waves:
        for t, s in wave:
            if (t, s) not in images:
                images[t, s] = rng.standard_normal((s, s, 3)) \
                    .astype(np.float32)
    uid_box = [0]

    def serve(pools):
        t0 = time.perf_counter()
        for wave in waves:
            for t, s in wave:
                pools[s].submit(VigRequest(uid=uid_box[0],
                                           image=images[t, s], tenant=t))
                uid_box[0] += 1
            for eng in {id(e): e for e in pools.values()}.values():
                while eng.queue:
                    eng.step()
        return time.perf_counter() - t0

    lat = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                         buckets=(1, 2), image_sizes=sizes, batch=4)
    lattice = {s: lat for s in sizes}
    dedicated = {}
    for s in sizes:
        c = cfg.replace(image_size=s)
        p = init_params(vig.vig_param_spec(c), jax.random.PRNGKey(0))
        dedicated[s] = VigServeEngine(c, p, digc_impl=impl,
                                      autotune=False, buckets=(1, 2),
                                      batch=4)

    results = {}
    for label, pools in (("", lattice), ("persize_", dedicated)):
        cold = serve(pools)  # includes compiles
        warm = serve(pools)  # steady state
        results[label] = (cold, warm)
        programs = sum({id(e): e.compile_count
                        for e in pools.values()}.values())
        emit(
            f"serve/multires_{label}cold_us", cold / total * 1e6,
            f"N={ns[s1]};sizes={list(sizes)};requests={total};"
            f"programs={programs};per-request incl. compiles "
            "(mixed-resolution ragged trace, cluster tier)",
        )
        emit(
            f"serve/multires_{label}warm_us", warm / total * 1e6,
            f"N={ns[s1]};sizes={list(sizes)};requests={total};"
            "steady state, programs compiled",
        )
    assert lat.compile_count <= len(lat.buckets) * len(sizes)
    for phase, idx in (("cold", 0), ("warm", 1)):
        emit(
            f"serve/multires_speedup_{phase}",
            results["persize_"][idx] / results[""][idx],
            f"N={ns[s1]};sizes={list(sizes)};x_persize_over_lattice;"
            f"lattice_programs={lat.compile_count}",
        )

    # per-N steady-state per-tick: each lattice cell vs its dedicated
    # engine (both warm from the traces above)
    def tick_us(eng, t, s):
        best = float("inf")
        for _ in range(3):
            req = VigRequest(uid=uid_box[0], image=images[t, s], tenant=t)
            uid_box[0] += 1
            t0 = time.perf_counter()
            eng.submit(req)
            eng.step()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    from repro.models.vig import _resolution_k

    for s in sizes:
        t = "A" if s == s0 else "C"
        k_lat = _resolution_k(cfg.k, s // cfg.patch, cfg.base_grid)
        lat_us = tick_us(lat, t, s)
        ded_us = tick_us(dedicated[s], t, s)
        emit(
            f"serve/multires_n{ns[s]}_warm_us", lat_us,
            f"N={ns[s]};B=1;cluster tier;lattice cell ({s}, 1), "
            f"per-tick steady state, k={k_lat}",
        )
        emit(
            f"serve/multires_n{ns[s]}_speedup_warm", ded_us / lat_us,
            f"N={ns[s]};x_dedicated_over_lattice;dedicated {s}px "
            f"engine (k={cfg.k}) vs the (B, N) lattice cell "
            f"(k={k_lat}: above native the ramp buys recall, so the "
            "bar is ~1.0 only at native size)",
        )


def _run_sharded(smoke):
    """Sharded-trace rows: the same ragged multi-tenant trace served by
    the mesh-native ring engine on a 1-device vs a 4-device (forced
    host) mesh. On CPU fake devices this measures the shard_map
    orchestration overhead, not ICI overlap — the row exists so the
    perf record tracks the sharded serving path (DESIGN.md §10) and a
    real-TPU run lands in the same rows. Runs in a subprocess because
    the forced device count must be set before jax initializes."""
    ndev = 4
    res, waves = (32, (1, 3, 2, 4)) if smoke else (64, (1, 3, 4, 2, 4, 1))
    code = _SHARDED_SNIPPET.format(res=res, waves=tuple(waves), ndev=ndev)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (
        "src" + os.pathsep + env.get("PYTHONPATH", "")
    ).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=1200,
    )
    if proc.returncode != 0:
        # No row on failure: a NaN us_per_call would make the dumped
        # BENCH_digc.json invalid per-spec JSON. Comment lines are the
        # established skip idiom (bench_strategies' ring row).
        tail = (proc.stderr.strip().splitlines()[-1][:160]
                if proc.stderr.strip() else "subprocess failed")
        print(f"# serve/sharded: skipped ({tail})", flush=True)
        return
    payload = next(
        line for line in proc.stdout.splitlines()
        if line.startswith("SHARDED_JSON ")
    )
    rows = json.loads(payload[len("SHARDED_JSON "):])
    for ndev_s, r in sorted(rows.items(), key=lambda kv: int(kv[0])):
        total = r["requests"]
        for phase in ("cold", "warm"):
            emit(
                f"serve/sharded_mesh{ndev_s}_{phase}_us",
                r[phase] / total * 1e6,
                f"N={r['n']};requests={total};programs={r['programs']};"
                f"ring mesh={ndev_s} forced-host dev;per-request"
                + (";incl. compiles" if phase == "cold" else ";steady"),
            )


def _run_sched(smoke):
    """SLO-bounded admission scheduling rows (DESIGN.md §14): the
    auto-selected bucketed policy vs exact-size programs on a replayed
    ragged arrival trace (the shared seeded Poisson+burst generator,
    ``serve.sched.arrival_trace``).

    The baseline is a fixed-cadence exact-size server: one tick per
    ``window_ms`` of arrivals, ``buckets=None`` — each distinct tick
    size compiles its own program and sub-width windows dispatch as-is.
    The scheduled engine replays the same trace per-arrival under a
    ``VirtualClock`` with ``buckets="auto"``: singletons wait up to the
    SLO and coalesce into fuller bucketed ticks, with the bucket set
    picked by the arrival-histogram optimizer from a (stub-program)
    profiling pass over this very trace — the tick structure under a
    virtual clock is scheduler-only, so the profiling replay costs no
    compiles and its live-lane histogram is exactly the real engine's.
    Cold rows include compiles (cap'd program count vs one per distinct
    size); warm rows re-replay through compiled programs, where the
    win is per-tick fixed cost amortized over coalesced lanes.
    """
    from repro.core.state import DigcState
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigRequest, VigServeEngine
    from repro.serve.sched import VirtualClock, arrival_trace, replay

    # The scheduler's win regime is dispatch-bound serving: at N=256
    # eight warm singleton ticks cost ~1.5x one coalesced 8-tick
    # (per-tick fixed cost dominates), while at N=3136 the blocked
    # tier's per-lane cost grows with B on CPU (the superlinear-B
    # question, ROADMAP) and coalescing pays — so the rows measure the
    # regime the policy targets.
    if smoke:
        res, tenants, slots = 32, 4, 4
        trace_kw = dict(seed=0, tenants=4, poisson_ms=25.0, poisson_n=8,
                        burst_every_ms=120.0, burst_n=1, burst_size=3)
    else:
        res, tenants, slots = 64, 8, 8
        trace_kw = dict(seed=0, tenants=8, poisson_ms=25.0, poisson_n=48,
                        burst_every_ms=400.0, burst_n=3, burst_size=6)
    # slo ~ slots * poisson_ms: budget for a full slot width of
    # arrivals to coalesce, so steady-state ticks run full and the
    # live-lane histogram concentrates on few buckets (fewer compiles)
    window_ms, slo_ms, cap = 50.0, 300.0, 4
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=res, patch=4, embed_dims=(96,), depths=(2,),
        num_classes=10, k=9, digc_impl="blocked",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    n = (res // 4) ** 2
    rng = np.random.default_rng(0)
    images = {
        f"t{i}": rng.standard_normal((res, res, 3)).astype(np.float32)
        for i in range(tenants)
    }
    arrivals = arrival_trace(**trace_kw)
    total = len(arrivals)

    # -- exact-size fixed-cadence baseline ------------------------------
    win: dict[int, list] = {}
    for a in arrivals:
        win.setdefault(int(a.t_ms // window_ms), []).append(a.tenant)
    waves = [win[k] for k in sorted(win)]

    def serve_windows(eng):
        uid = 0
        t0 = time.perf_counter()
        for wave in waves:
            for tenant in wave:
                eng.submit(VigRequest(uid=uid, image=images[tenant],
                                      tenant=tenant))
                uid += 1
            while eng.queue:  # a repeated tenant takes an extra tick
                eng.step()
        return time.perf_counter() - t0

    exact = VigServeEngine(cfg, params, digc_impl="blocked",
                           autotune=False, buckets=None, batch=slots)
    exact_cold = serve_windows(exact)
    exact_ticks = sum(exact.bucket_ticks.values())
    # warm: min of 3 steady-state passes (per-request times are ms-
    # scale here, so scheduler noise would otherwise dominate the row)
    exact_warm = min(serve_windows(exact) for _ in range(3))

    # -- profiling pass (stub programs) -> tuned bucket set -------------
    class _StubSched(VigServeEngine):
        def _build_program(self, bucket):
            def fake_fwd(params, imgs, state):
                new = DigcState(entries={
                    k: e.bump() for k, e in state.entries.items()
                })
                return (jnp.zeros((imgs.shape[0], self.cfg.num_classes),
                                  jnp.float32), new)

            return fake_fwd

    tuner_path = TUNE_CACHE if not smoke else os.path.join(
        tempfile.mkdtemp(prefix="digc_sched_smoke"), "tune.json")
    clock = VirtualClock()
    # buckets=None: slots == batch (the auto engine's serving shape)
    # and the live-lane histogram is bucket-independent regardless
    prof = _StubSched(cfg, params, digc_impl="blocked", autotune=False,
                      buckets=None, batch=slots, slo_ms=slo_ms,
                      clock=clock, bucket_cap=cap, tuner_path=tuner_path)
    replay(prof, arrivals, images, clock=clock)
    tuned = prof.retune_buckets()

    # -- scheduled engine on the tuned (auto) bucket set ----------------
    def sched_pass(eng, clk):
        # re-anchor the trace at the clock's current time so the warm
        # pass replays the same *relative* timing (the clock is
        # monotonic; absolute times from the cold pass are in its past)
        shift = clk.now() * 1e3
        shifted = [dataclasses.replace(a, t_ms=a.t_ms + shift)
                   for a in arrivals]
        t0 = time.perf_counter()
        ticks = replay(eng, shifted, images, clock=clk)
        return time.perf_counter() - t0, ticks

    clock = VirtualClock()
    auto = VigServeEngine(cfg, params, digc_impl="blocked",
                          autotune=False, buckets="auto", batch=slots,
                          bucket_cap=cap, slo_ms=slo_ms, clock=clock,
                          tuner_path=tuner_path)
    assert auto.buckets == tuned, (auto.buckets, tuned)
    auto_cold, cold_ticks = sched_pass(auto, clock)
    auto_warm = min(sched_pass(auto, clock)[0] for _ in range(3))
    util = auto.stats()["util"]

    emit(
        "serve/sched_exact_cold_us", exact_cold / total * 1e6,
        f"N={n};requests={total};programs={exact.compile_count};"
        f"ticks={exact_ticks};window_ms={window_ms:g};exact-size "
        "fixed-cadence baseline, per-request incl. compiles",
    )
    emit(
        "serve/sched_exact_warm_us", exact_warm / total * 1e6,
        f"N={n};requests={total};steady state, programs compiled",
    )
    emit(
        "serve/sched_auto_cold_us", auto_cold / total * 1e6,
        f"N={n};requests={total};programs={auto.compile_count};"
        f"ticks={len(cold_ticks)};buckets={tuned};slo_ms={slo_ms:g};"
        f"deferrals={auto.deferrals};auto-tuned bucketed scheduler, "
        "per-request incl. compiles",
    )
    emit(
        "serve/sched_auto_warm_us", auto_warm / total * 1e6,
        f"N={n};requests={total};util={util:.3f};steady state",
    )
    for phase, ex, au in (("cold", exact_cold, auto_cold),
                          ("warm", exact_warm, auto_warm)):
        emit(
            f"serve/sched_speedup_{phase}", ex / au,
            f"N={n};requests={total};x_exact_over_auto;"
            f"auto_programs={auto.compile_count};"
            f"exact_programs={exact.compile_count} "
            "(>=1 means the SLO-scheduled auto-bucketed policy wins)",
        )


if __name__ == "__main__":
    run()
