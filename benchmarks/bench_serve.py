"""Serving-path benchmark: the jitted functional-state ``VigServeEngine``
vs the legacy eager ``DigcCache`` shim per request, plus the
multi-tenant ragged-arrival trace (bucketed vs the PR-3 fixed-batch
policy).

The acceptance workload is the ViG N=3136 regime (224^2 / patch 4 —
the grid where PR-2 measured the eager cache-aware cluster tier): the
jitted path must serve the cluster tier with **no eager fallback** at
per-request latency <= the eager shim's. Rows record both modes plus
the speedup, per tier, so the jit-vs-eager gap is part of the perf
trajectory.

The multi-tenant rows serve one ragged trace (arrival waves of 1-8
interleaved tenants) through the request path twice: ``buckets=
(1,2,4,8)`` (pad to the smallest fitting bucket, <= 4 compiled
programs) and ``buckets=None`` (the PR-3 baseline: exact-size ticks,
one program per distinct batch size). The cold rows include program
compilation — exactly what the one-program-per-batch-size engine pays
on a ragged stream — and the warm rows re-serve the same trace through
the already-compiled programs (steady state).

The ``serve/guarded_*`` rows price the fault-tolerance guards
(DESIGN.md §11) on the fault-free path: the same ragged trace with
the admission/state screening armed vs ``guards=False``, with the
warm overhead ratio pinned by the acceptance bar (<= 1.05x).
"""

import json
import os
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

TUNE_CACHE = ".digc_tune.json"


def _engine(cfg, params, impl, mode, batch, smoke):
    from repro.serve.engine import VigServeEngine

    return VigServeEngine(
        cfg, params, digc_impl=impl, batch=batch, mode=mode,
        # blocked autotunes through the committed host-keyed cache;
        # smoke keeps its toy workloads out of it (in-memory tuner).
        autotune=(impl == "blocked"),
        tuner_path=None if smoke else TUNE_CACHE,
    )


def run(smoke: bool = False, res: int = 224, batch: int = 2, iters: int = 3):
    from repro.models import vig
    from repro.models.module import init_params

    if smoke:
        res, iters = 32, 1
    # res=224 / patch 4 -> grid 56 -> N=3136 (the PR-2 cluster-tier
    # measurement workload), one isotropic stage of two blocks.
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=res, patch=4, embed_dims=(96,), depths=(2,),
        num_classes=10, k=9,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(
        rng.standard_normal((batch, res, res, 3)), jnp.float32
    )
    n = cfg.base_grid ** 2
    for impl in ("cluster", "blocked"):
        per_mode = {}
        for mode in ("jit", "eager"):
            eng = _engine(cfg, params, impl, mode, batch, smoke)
            # Two warmup calls: compile + engage the warm start, so the
            # measured steady state is what a serving replica sees.
            t = timeit(lambda: eng.infer(imgs), warmup=2, iters=iters)
            per_mode[mode] = t
            emit(
                f"serve/{impl}_{mode}_us", t * 1e6,
                f"B={batch};N={n};per-request forward;mode={mode};"
                f"requests_served={eng.requests_served}",
            )
        emit(
            f"serve/{impl}_jit_speedup", per_mode["eager"] / per_mode["jit"],
            f"B={batch};N={n};eager_us={per_mode['eager'] * 1e6:.0f};"
            f"jit_us={per_mode['jit'] * 1e6:.0f};x_eager_over_jit "
            "(>=1 means the jitted functional-state path wins)",
        )
    _run_multitenant(cfg, params, n, res, smoke)
    _run_guarded(cfg, params, n, res, smoke)
    _run_sharded(smoke)
    return True


def _serve_trace(engine, waves, images):
    """Submit the ragged trace wave by wave and drain; returns wall
    seconds for the full trace (one engine tick per wave)."""
    from repro.serve.engine import VigRequest

    uid = 0
    t0 = time.perf_counter()
    for wave in waves:
        for tenant in wave:
            engine.submit(VigRequest(uid=uid, image=images[tenant],
                                     tenant=tenant))
            uid += 1
        engine.step()
    assert not engine.queue
    return time.perf_counter() - t0


def _run_multitenant(cfg, params, n, res, smoke):
    """Ragged multi-tenant trace: bucket policies vs the PR-3
    fixed-batch (one program per batch size) baseline.

    The bucket set is a compile-count vs padding-waste dial: the
    coarse ``{8}`` policy compiles one program and pads everything
    (best cold-trace throughput — ragged streams are compile-
    dominated), ``{1,2,4,8}`` compiles four and pads by at most 2x
    (best steady-state latency among the bucketed policies), and the
    PR-3 baseline compiles one program per distinct tick size. Rows
    record cold (incl. compiles) and warm (steady) per policy.
    """
    from repro.serve.engine import VigServeEngine

    impl = "cluster"  # the stateful showcase tier (per-slot warm starts)
    if smoke:
        wave_sizes = (1, 3, 2, 4)
        policies = (("b1_2_4", (1, 2, 4)), ("b4", (4,)), ("fixed", None))
        slots = 4
    else:
        wave_sizes = (1, 3, 8, 2, 5, 4, 7, 6)
        policies = (("b1_2_4_8", (1, 2, 4, 8)), ("b8", (8,)),
                    ("fixed", None))
        slots = 8
    # tenants cycle through the slots; wave w serves tenants
    # w, w+1, ... (mod slots) so arrivals interleave raggedly
    waves = [
        [(w + i) % slots for i in range(size)]
        for w, size in enumerate(wave_sizes)
    ]
    total = sum(wave_sizes)
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(slots)]

    results = {}
    for policy, bconf in policies:
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=bconf, batch=slots)
        cold = _serve_trace(eng, waves, images)  # includes compiles
        cold_ticks = sorted(eng.bucket_ticks.items())  # before warm pass
        warm = _serve_trace(eng, waves, images)  # steady state
        results[policy] = (cold, warm, eng)
        emit(
            f"serve/multitenant_{policy}_cold_us", cold / total * 1e6,
            f"N={n};requests={total};waves={list(wave_sizes)};"
            f"programs={eng.compile_count};"
            f"bucket_ticks={cold_ticks};"
            "per-request incl. compiles (ragged trace, cluster tier)",
        )
        emit(
            f"serve/multitenant_{policy}_warm_us", warm / total * 1e6,
            f"N={n};requests={total};steady state, programs compiled",
        )
    for policy, _ in policies[:-1]:  # each bucketed policy vs PR-3
        for phase, idx in (("cold", 0), ("warm", 1)):
            emit(
                f"serve/multitenant_{policy}_speedup_{phase}",
                results["fixed"][idx] / results[policy][idx],
                f"N={n};requests={total};x_fixed_over_{policy};"
                f"{policy}_programs={results[policy][2].compile_count};"
                f"fixed_programs={results['fixed'][2].compile_count}",
            )


def _run_guarded(cfg, params, n, res, smoke):
    """Guard overhead on the fault-free path (DESIGN.md §11).

    The same ragged trace as the multitenant rows, served with the
    fault-tolerance guards armed (admission finiteness screen, per-row
    integrity fingerprints, state finiteness checks — the engine
    default) vs ``guards=False`` (the unguarded PR-6 path). The
    guarded warm row is the number the acceptance bar pins: steady-
    state overhead must stay within a few percent, since every healthy
    tick pays the screening whether or not a fault ever occurs. No
    fault plan is attached — injection costs nothing when absent; this
    measures detection, not injection.
    """
    from repro.serve.engine import VigServeEngine

    impl = "cluster"
    if smoke:
        wave_sizes, bconf, slots = (1, 3, 2, 4), (1, 2, 4), 4
    else:
        wave_sizes, bconf, slots = (1, 3, 8, 2, 5, 4, 7, 6), (1, 2, 4, 8), 8
    waves = [
        [(w + i) % slots for i in range(size)]
        for w, size in enumerate(wave_sizes)
    ]
    total = sum(wave_sizes)
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(slots)]
    engines, cold_s, warm_s = {}, {}, {}
    for label, guards in (("unguarded", False), ("guarded", True)):
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=bconf, batch=slots, guards=guards)
        engines[label] = eng
        cold_s[label] = _serve_trace(eng, waves, images)  # incl. compiles
        warm_s[label] = float("inf")
    # Interleaved best-of-5 warm passes: the overhead row divides two
    # small numbers, so back-to-back measurement (all passes of one
    # engine, then the other) would bake clock/cache drift into the
    # ratio; alternating engines cancels it.
    for _ in range(5):
        for label, eng in engines.items():
            warm_s[label] = min(warm_s[label],
                                _serve_trace(eng, waves, images))
    for eng in engines.values():
        assert eng.stats()["quarantines"] == 0  # fault-free by design
    results = {label: (cold_s[label], warm_s[label]) for label in engines}
    for phase, idx in (("cold", 0), ("warm", 1)):
        emit(
            f"serve/guarded_{phase}_us", results["guarded"][idx] / total * 1e6,
            f"N={n};requests={total};guards on, no fault plan;"
            f"unguarded_us={results['unguarded'][idx] / total * 1e6:.0f};"
            + ("per-request incl. compiles" if phase == "cold"
               else "steady state"),
        )
        emit(
            f"serve/guarded_overhead_{phase}",
            results["guarded"][idx] / results["unguarded"][idx],
            f"N={n};requests={total};x_guarded_over_unguarded "
            "(1.0 = free; acceptance bar: warm <= 1.05)",
        )


_SHARDED_SNIPPET = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigRequest, VigServeEngine

res, waves = {res}, {waves}
cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
    image_size=res, patch=4, embed_dims=(32,), depths=(2,),
    num_classes=10, k=9, digc_impl="ring")
params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
slots = 4
images = [rng.standard_normal((res, res, 3)).astype(np.float32)
          for _ in range(slots)]
wave_t = [[(w + i) % slots for i in range(size)]
          for w, size in enumerate(waves)]

def trace(eng):
    uid = 0
    t0 = time.perf_counter()
    for wave in wave_t:
        for tenant in wave:
            eng.submit(VigRequest(uid=uid, image=images[tenant],
                                  tenant=tenant))
            uid += 1
        eng.step()
    return time.perf_counter() - t0

out = {{}}
for ndev in (1, {ndev}):
    mesh = jax.make_mesh((ndev,), ("ring",))
    eng = VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                         buckets=(1, 2, 4), mesh=mesh, mesh_axis="ring")
    cold = trace(eng)
    warm = trace(eng)
    out[ndev] = dict(cold=cold, warm=warm, programs=eng.compile_count,
                     n=cfg.base_grid ** 2, requests=sum(waves))
print("SHARDED_JSON " + json.dumps(out))
"""


def _run_sharded(smoke):
    """Sharded-trace rows: the same ragged multi-tenant trace served by
    the mesh-native ring engine on a 1-device vs a 4-device (forced
    host) mesh. On CPU fake devices this measures the shard_map
    orchestration overhead, not ICI overlap — the row exists so the
    perf record tracks the sharded serving path (DESIGN.md §10) and a
    real-TPU run lands in the same rows. Runs in a subprocess because
    the forced device count must be set before jax initializes."""
    ndev = 4
    res, waves = (32, (1, 3, 2, 4)) if smoke else (64, (1, 3, 4, 2, 4, 1))
    code = _SHARDED_SNIPPET.format(res=res, waves=tuple(waves), ndev=ndev)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (
        "src" + os.pathsep + env.get("PYTHONPATH", "")
    ).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=1200,
    )
    if proc.returncode != 0:
        # No row on failure: a NaN us_per_call would make the dumped
        # BENCH_digc.json invalid per-spec JSON. Comment lines are the
        # established skip idiom (bench_strategies' ring row).
        tail = (proc.stderr.strip().splitlines()[-1][:160]
                if proc.stderr.strip() else "subprocess failed")
        print(f"# serve/sharded: skipped ({tail})", flush=True)
        return
    payload = next(
        line for line in proc.stdout.splitlines()
        if line.startswith("SHARDED_JSON ")
    )
    rows = json.loads(payload[len("SHARDED_JSON "):])
    for ndev_s, r in sorted(rows.items(), key=lambda kv: int(kv[0])):
        total = r["requests"]
        for phase in ("cold", "warm"):
            emit(
                f"serve/sharded_mesh{ndev_s}_{phase}_us",
                r[phase] / total * 1e6,
                f"N={r['n']};requests={total};programs={r['programs']};"
                f"ring mesh={ndev_s} forced-host dev;per-request"
                + (";incl. compiles" if phase == "cold" else ";steady"),
            )


if __name__ == "__main__":
    run()
