"""Serving-path benchmark: the jitted functional-state ``VigServeEngine``
vs the legacy eager ``DigcCache`` shim, per request.

The acceptance workload is the ViG N=3136 regime (224^2 / patch 4 —
the grid where PR-2 measured the eager cache-aware cluster tier): the
jitted path must serve the cluster tier with **no eager fallback** at
per-request latency <= the eager shim's. Rows record both modes plus
the speedup, per tier, so the jit-vs-eager gap is part of the perf
trajectory.
"""

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

TUNE_CACHE = ".digc_tune.json"


def _engine(cfg, params, impl, mode, batch, smoke):
    from repro.serve.engine import VigServeEngine

    return VigServeEngine(
        cfg, params, digc_impl=impl, batch=batch, mode=mode,
        # blocked autotunes through the committed host-keyed cache;
        # smoke keeps its toy workloads out of it (in-memory tuner).
        autotune=(impl == "blocked"),
        tuner_path=None if smoke else TUNE_CACHE,
    )


def run(smoke: bool = False, res: int = 224, batch: int = 2, iters: int = 3):
    from repro.models import vig
    from repro.models.module import init_params

    if smoke:
        res, iters = 32, 1
    # res=224 / patch 4 -> grid 56 -> N=3136 (the PR-2 cluster-tier
    # measurement workload), one isotropic stage of two blocks.
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=res, patch=4, embed_dims=(96,), depths=(2,),
        num_classes=10, k=9,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(
        rng.standard_normal((batch, res, res, 3)), jnp.float32
    )
    n = cfg.base_grid ** 2
    for impl in ("cluster", "blocked"):
        per_mode = {}
        for mode in ("jit", "eager"):
            eng = _engine(cfg, params, impl, mode, batch, smoke)
            # Two warmup calls: compile + engage the warm start, so the
            # measured steady state is what a serving replica sees.
            t = timeit(lambda: eng.infer(imgs), warmup=2, iters=iters)
            per_mode[mode] = t
            emit(
                f"serve/{impl}_{mode}_us", t * 1e6,
                f"B={batch};N={n};per-request forward;mode={mode};"
                f"requests_served={eng.requests_served}",
            )
        emit(
            f"serve/{impl}_jit_speedup", per_mode["eager"] / per_mode["jit"],
            f"B={batch};N={n};eager_us={per_mode['eager'] * 1e6:.0f};"
            f"jit_us={per_mode['jit'] * 1e6:.0f};x_eager_over_jit "
            "(>=1 means the jitted functional-state path wins)",
        )
    return True


if __name__ == "__main__":
    run()
