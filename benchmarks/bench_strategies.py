"""Construction-strategy ablation (paper §VI: the modular architecture
"supports diverse graph construction strategies" — ClusterViG-family
clustering and GreedyViG-family axial). Runtime + recall vs Algorithm 1
at the ViG pyramid stage-1 workload (N=3136 grid 56x56)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.digc import digc_blocked
from repro.core.strategies import axial_digc, cluster_digc, recall_vs_exact
from benchmarks.common import emit, timeit


def _clustered(rng, n, d, c=16, spread=0.15):
    centers = rng.standard_normal((c, d)) * 4
    pts = centers[rng.integers(0, c, n)] + spread * rng.standard_normal((n, d))
    return jnp.asarray(pts, jnp.float32)


def run():
    rng = np.random.default_rng(0)
    h = w = 56
    n, d, k = h * w, 96, 9
    x_rand = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    x_clus = _clustered(rng, n, d)  # the ViG-feature regime ClusterViG assumes

    exact = jax.jit(lambda a: digc_blocked(a, a, k=k))
    t = timeit(exact, x_rand, iters=2)
    emit("strategies/exact_knn_us", t * 1e6,
         f"recall=1.00 (Algorithm 1); distance work = N*M*D = {n*n*d/1e9:.2f} GFLOP-pairs")

    for probes in (2, 8):
        fn = jax.jit(lambda a: cluster_digc(a, k=k, n_clusters=56, n_probe=probes))
        t = timeit(fn, x_clus, iters=2)
        rec_c = recall_vs_exact(x_clus, x_clus, fn(x_clus), k)
        rec_r = recall_vs_exact(x_rand, x_rand, fn(x_rand), k)
        work = probes / 56  # probed fraction of co-nodes
        emit(f"strategies/cluster_p{probes}_us", t * 1e6,
             f"recall_clustered={rec_c:.3f};recall_random={rec_r:.3f};"
             f"distance_work={work:.2f}x_of_exact (ClusterViG family; random "
             "features are the IVF worst case — CPU gathers dominate wall-time)")

    fn = jax.jit(lambda a: axial_digc(a, grid_h=h, grid_w=w, k=k))
    t = timeit(fn, x_rand, iters=2)
    rec = recall_vs_exact(x_rand, x_rand, fn(x_rand), k)
    emit("strategies/axial_us", t * 1e6,
         f"recall_vs_full_knn={rec:.3f};distance_work={(h+w)/n:.3f}x_of_exact "
         "(GreedyViG family; different graph family, not a KNN approximation)")
    return True


if __name__ == "__main__":
    run()
