"""Construction-strategy ablation (paper §VI: the modular architecture
"supports diverse graph construction strategies"). The impl list comes
from the GraphBuilder registry — a newly registered strategy shows up
here with zero benchmark edits. Runtime + recall vs Algorithm 1 on a
ViG-style square grid, batched (B, N, D) as the serving path runs it."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DigcSpec, digc, list_builders
from repro.core.strategies import recall_vs_exact
from benchmarks.common import emit, timeit

# Per-impl workload scale: the interpret-mode Pallas kernel emulates the
# TPU grid on CPU, so it benchmarks at a smaller grid than the XLA tiers.
GRID_SIDE = {"default": 56, "pallas": 16}
BATCH = 2


def _clustered(rng, b, n, d, c=16, spread=0.15):
    centers = rng.standard_normal((c, d)) * 4
    pts = centers[rng.integers(0, c, b * n)] + spread * rng.standard_normal(
        (b * n, d)
    )
    return jnp.asarray(pts.reshape(b, n, d), jnp.float32)


def _spec_for(builder, h, w, k):
    # Default knobs everywhere (cluster gets its workload-adaptive
    # heuristic here; the explicit n_clusters/n_probe sweep lives in
    # _cluster_probe_ablation) — only the grid geometry is required.
    knobs = {}
    if "grid_h" in builder.knobs:
        knobs = {"grid_h": h, "grid_w": w}
    return DigcSpec(impl=builder.name, k=k, **knobs)


def _cluster_probe_ablation(rng, d, k):
    """ClusterViG knob ablation: recall on clustered features (the
    ViG regime) AND on random features — the IVF worst case, where a
    recall regression would otherwise be invisible."""
    h = GRID_SIDE["default"]
    n = h * h
    x_clus = _clustered(rng, BATCH, n, d)
    x_rand = jnp.asarray(rng.standard_normal((BATCH, n, d)), jnp.float32)
    for probes in (2, 8):
        spec = DigcSpec(impl="cluster", k=k, n_clusters=h, n_probe=probes)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x_clus, iters=2)
        rec_c = recall_vs_exact(x_clus, x_clus, fn(x_clus), k)
        rec_r = recall_vs_exact(x_rand, x_rand, fn(x_rand), k)
        emit(f"strategies/cluster_p{probes}_us", t * 1e6,
             f"recall_clustered={rec_c:.3f};recall_random={rec_r:.3f};"
             f"distance_work={probes/h:.2f}x_of_exact (random features "
             "are the IVF worst case)")


def run():
    rng = np.random.default_rng(0)
    d, k = 96, 9
    for builder in list_builders():
        if builder.distributed:
            # No fake 0-us row in the perf record: distributed builders
            # need a device mesh (exactness covered in tests/test_ring.py).
            print(f"# strategies/{builder.name}: skipped, needs a device mesh",
                  flush=True)
            continue
        h = w = GRID_SIDE.get(builder.name, GRID_SIDE["default"])
        n = h * w
        x = (_clustered(rng, BATCH, n, d) if not builder.exact
             else jnp.asarray(rng.standard_normal((BATCH, n, d)), jnp.float32))
        spec = _spec_for(builder, h, w, k)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x, iters=2)
        idx = fn(x)
        rec = recall_vs_exact(x, x, idx, k)
        work = 1.0
        if builder.name == "cluster":
            from repro.core.strategies import default_cluster_params

            nc, npr = default_cluster_params(n, spec.n_clusters, spec.n_probe)
            work = npr / nc
        elif builder.name == "axial":
            work = (h + w) / n
        emit(f"strategies/{builder.name}_us", t * 1e6,
             f"recall_vs_exact={rec:.3f};distance_work={work:.2f}x;"
             f"B={BATCH};N={n};D={d};exact={builder.exact}")
    _cluster_probe_ablation(rng, d, k)
    return True


if __name__ == "__main__":
    run()
