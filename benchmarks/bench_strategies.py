"""Construction-strategy ablation (paper §VI: the modular architecture
"supports diverse graph construction strategies"). The impl list comes
from the GraphBuilder registry — a newly registered strategy shows up
here with zero benchmark edits. Runtime + recall vs Algorithm 1 on a
ViG-style square grid, batched (B, N, D) as the serving path runs it.

The blocked tier runs with the workload-autotuned engine schedule
(core/tuner.py; the chosen tile config is recorded per row and
persisted to TUNE_CACHE), every row carries speedup_vs_reference, and a
high-resolution scenario (N=12544 — the paper's 95%-of-latency regime)
exercises the two-level tiling where the single-level path would
materialize 600+ MB distance rows."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DigcSpec, digc, list_builders
from repro.core.strategies import recall_vs_exact
from repro.core.tuner import DigcTuner
from benchmarks.common import emit, timeit

# Every tier benchmarks the same ViG-224 grid (N=3136) — including the
# Pallas kernel, which runs in interpret mode on CPU: its wall-clock row
# is the emulation floor, and the derived text carries the perfmodel's
# compiled-TPU projection for the same config (PR 6).
GRID_SIDE = {"default": 56}
HIGH_RES_SIDE = 112  # N = 12544: ViG @ 1792^2 / patch 16
BATCH = 2
TUNE_CACHE = ".digc_tune.json"


def _clustered(rng, b, n, d, c=16, spread=0.15):
    centers = rng.standard_normal((c, d)) * 4
    pts = centers[rng.integers(0, c, b * n)] + spread * rng.standard_normal(
        (b * n, d)
    )
    return jnp.asarray(pts.reshape(b, n, d), jnp.float32)


def _spec_for(builder, h, w, k):
    # Default knobs everywhere (cluster gets its workload-adaptive
    # heuristic here; the explicit n_clusters/n_probe sweep lives in
    # _cluster_probe_ablation) — only the grid geometry is required.
    knobs = {}
    if "grid_h" in builder.knobs:
        knobs = {"grid_h": h, "grid_w": w}
    return DigcSpec(impl=builder.name, k=k, **knobs)


def _tuned_blocked_spec(tuner, x, k):
    """Autotune the engine schedule for this workload; describe it."""
    spec, result = tuner.tune(x, spec=DigcSpec(impl="blocked", k=k))
    c = result.config
    desc = (f"tile=bn{c.block_n or 'N'}xbm{c.block_m};merge={c.merge};"
            f"fuse_norms={int(c.fuse_norms)};tune_source={result.source}")
    return spec, desc


def _cluster_probe_ablation(rng, d, k):
    """ClusterViG knob ablation: recall on clustered features (the
    ViG regime) AND on random features — the IVF worst case, where a
    recall regression would otherwise be invisible."""
    h = GRID_SIDE["default"]
    n = h * h
    x_clus = _clustered(rng, BATCH, n, d)
    x_rand = jnp.asarray(rng.standard_normal((BATCH, n, d)), jnp.float32)
    for probes in (2, 8):
        spec = DigcSpec(impl="cluster", k=k, n_clusters=h, n_probe=probes)
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        t = timeit(fn, x_clus, iters=2)
        rec_c = recall_vs_exact(x_clus, x_clus, fn(x_clus), k)
        rec_r = recall_vs_exact(x_rand, x_rand, fn(x_rand), k)
        emit(f"strategies/cluster_p{probes}_us", t * 1e6,
             f"recall_clustered={rec_c:.3f};recall_random={rec_r:.3f};"
             f"distance_work={probes/h:.2f}x_of_exact (random features "
             "are the IVF worst case)")


def _high_res_scenario(rng, tuner, d, k, iters=1):
    """N=12544: the regime where the paper reports DIGC at 95% of ViG
    latency. Exercises the engine's two-level tiling (a single-level
    sweep would hold B*N*block_m distance rows; reference materializes
    a 12544^2 matrix). Axial is excluded: its batched candidate gather
    is O(N*(H+W)*D) live — ~2 GB here."""
    h = HIGH_RES_SIDE
    n = h * h
    b = 1
    x = _clustered(rng, b, n, d)
    ref_spec = DigcSpec(impl="reference", k=k)
    f_ref = jax.jit(lambda a: digc(a, spec=ref_spec))
    t_ref = timeit(f_ref, x, iters=iters)
    emit(f"strategies/highres_reference_us", t_ref * 1e6,
         f"B={b};N={n};D={d};speedup_vs_reference=1.00x")
    spec, tile_desc = _tuned_blocked_spec(tuner, x, k)
    f_blk = jax.jit(lambda a, s=spec: digc(a, spec=s))
    t_blk = timeit(f_blk, x, iters=iters)
    rec = recall_vs_exact(x, x, f_blk(x), k)
    emit(f"strategies/highres_blocked_us", t_blk * 1e6,
         f"recall_vs_exact={rec:.3f};B={b};N={n};D={d};"
         f"speedup_vs_reference={t_ref/t_blk:.2f}x;{tile_desc}")
    cl_spec = DigcSpec(impl="cluster", k=k)
    f_cl = jax.jit(lambda a, s=cl_spec: digc(a, spec=s))
    t_cl = timeit(f_cl, x, iters=iters)
    rec = recall_vs_exact(x, x, f_cl(x), k)
    emit(f"strategies/highres_cluster_us", t_cl * 1e6,
         f"recall_vs_exact={rec:.3f};B={b};N={n};D={d};"
         f"speedup_vs_reference={t_ref/t_cl:.2f}x")


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    d, k = 96, 9
    # Smoke runs tune toy workloads: keep them out of the committed
    # tune cache (in-memory tuner; DigcTuner(None) never persists).
    tuner = DigcTuner(None if smoke else TUNE_CACHE)
    iters = 1 if smoke else 2
    grid_default = 14 if smoke else GRID_SIDE["default"]

    # Reference timings per workload scale, for speedup_vs_reference.
    ref_t: dict[int, float] = {}

    def reference_time(x):
        n = x.shape[1]
        if n not in ref_t:
            f = jax.jit(lambda a: digc(a, k=k, impl="reference"))
            ref_t[n] = timeit(f, x, iters=iters)
        return ref_t[n]

    for builder in list_builders():
        if builder.distributed:
            # No fake 0-us row in the perf record: distributed builders
            # need a device mesh (exactness covered in tests/test_ring.py).
            print(f"# strategies/{builder.name}: skipped, needs a device mesh",
                  flush=True)
            continue
        h = w = (grid_default if smoke
                 else GRID_SIDE.get(builder.name, GRID_SIDE["default"]))
        n = h * w
        x = (_clustered(rng, BATCH, n, d) if not builder.exact
             else jnp.asarray(rng.standard_normal((BATCH, n, d)), jnp.float32))
        tile_desc = ""
        if builder.name == "blocked":
            spec, tile_desc = _tuned_blocked_spec(tuner, x, k)
            tile_desc = ";" + tile_desc
        else:
            spec = _spec_for(builder, h, w, k)
        if builder.name == "pallas":
            # The kernel's production pipeline (PR 6): packed keys
            # through the bitonic LSM+GMM, padding-free divisor tiles.
            # Interpret wall-clock is an emulation floor, not a TPU
            # number, so the derived fields attach the perfmodel's
            # compiled projection (bitonic vs the legacy kd-pass).
            from repro.core.perfmodel import tpu_digc_estimate

            bn, bm = min(448, n), min(1568, n)
            spec = spec.replace(packed=True, block_n=bn, block_m=bm)
            kw = dict(n=n, m=n, d=d, k=k, dilation=1, packed=True,
                      block_n=bn, block_m=bm)
            bit = tpu_digc_estimate(**kw, kernel_merge="bitonic")
            leg = tpu_digc_estimate(**kw, kernel_merge="legacy")
            tile_desc = (
                f";interpret=1;packed=1;tile=bn{bn}xbm{bm};"
                f"tpu_model_us={bit['latency_s'] * BATCH * 1e6:.0f};"
                f"model_speedup_vs_legacy_merge="
                f"{leg['latency_s'] / bit['latency_s']:.2f}x")
        fn = jax.jit(lambda a, s=spec: digc(a, spec=s))
        # the reference row IS the speedup denominator: time it once
        t = reference_time(x) if builder.name == "reference" else timeit(
            fn, x, iters=iters)
        idx = fn(x)
        rec = recall_vs_exact(x, x, idx, k)
        work = 1.0
        if builder.name == "cluster":
            from repro.core.strategies import default_cluster_params

            nc, npr = default_cluster_params(n, spec.n_clusters, spec.n_probe)
            work = npr / nc
        elif builder.name == "axial":
            work = (h + w) / n
        speedup = reference_time(x) / t
        emit(f"strategies/{builder.name}_us", t * 1e6,
             f"recall_vs_exact={rec:.3f};distance_work={work:.2f}x;"
             f"B={BATCH};N={n};D={d};exact={builder.exact};"
             f"speedup_vs_reference={speedup:.2f}x{tile_desc}")
    if not smoke:
        _cluster_probe_ablation(rng, d, k)
        _high_res_scenario(rng, tuner, d, k)
    return True


if __name__ == "__main__":
    run()
