"""Paper Table I: per-module cycle estimates from the performance model.

Reproduces the paper's numbers for the ViG-Tiny reference config
(N=M=196, D=192, k=8) and extends the model across resolutions; derives
the modeled FPGA latency @600 MHz and the TPU-kernel estimate."""

from repro.core.perfmodel import (
    FPGAConfig,
    fpga_cycles,
    fpga_latency_ms,
    tpu_digc_estimate,
    vig_resolution_to_nodes,
)
from benchmarks.common import emit

PAPER_TABLE1 = {"DCM": 4704, "LSM": 3920, "GMM": 4704, "NSM": 224}


def run():
    cyc = fpga_cycles(196, 196, 192, 8)
    match = cyc == PAPER_TABLE1
    for mod, c in cyc.items():
        emit(f"table1/cycles_{mod}", float(c),
             f"paper={PAPER_TABLE1[mod]};match={c == PAPER_TABLE1[mod]}")
    emit("table1/model_matches_paper", 1.0 if match else 0.0,
         "exact reproduction of Table I")

    for res in (256, 512, 1024, 2048):
        n = vig_resolution_to_nodes(res)
        lat_ms = fpga_latency_ms(n, n, 192, 8)
        est = tpu_digc_estimate(n, n, 192, 8, 2)
        emit(f"table1/fpga_model_latency_ms_res{res}", lat_ms * 1e3,
             f"N={n}")
        emit(f"table1/tpu_kernel_est_us_res{res}", est["latency_s"] * 1e6,
             f"bound={est['bound']};traffic_saving={est['traffic_saving']:.1f}x")
    return True


if __name__ == "__main__":
    run()
