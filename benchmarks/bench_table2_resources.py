"""Paper Table II analogue: resource occupancy of the DIGC kernel.

The paper reports DSP/LUT/BRAM usage on the U280; the TPU analogue is
the VMEM working set per (block_n, block_m, D, kd) tile configuration
vs the 128 MB VMEM budget, plus MXU occupancy (tile dims vs 128x128
systolic array alignment)."""

from repro.core.perfmodel import TPUConfig
from benchmarks.common import emit


def vmem_bytes(block_n: int, block_m: int, d: int, kd: int,
               with_pos: bool = False) -> int:
    f = 4  # fp32 in-kernel
    x_tile = block_n * d * f
    y_tile = block_m * d * f
    dist = block_n * block_m * f
    run = 2 * block_n * kd * f  # (dist, idx) running buffers
    pos = block_n * block_m * f if with_pos else 0
    # double buffering on the streamed operands (Pallas pipeline)
    return 2 * (x_tile + y_tile + pos) + dist + run


def run():
    cfg = TPUConfig()
    for (bn, bm, d, kd) in [
        (128, 256, 192, 16),   # paper's ViG-Ti workload on our tiles
        (128, 512, 192, 16),
        (256, 512, 192, 16),
        (128, 256, 640, 18),   # ViG-B feature dim
        (512, 1024, 192, 16),  # large-tile variant
        (8, 128, 192, 16),     # minimum aligned tile
    ]:
        used = vmem_bytes(bn, bm, d, kd)
        frac = used / cfg.vmem_bytes
        mxu_aligned = (bn % 8 == 0) and (bm % 128 == 0) and (d % 8 == 0)
        emit(f"table2/vmem_kb_bn{bn}_bm{bm}_d{d}", used / 1024,
             f"vmem_frac={frac:.4f};mxu_aligned={mxu_aligned};fits={used < cfg.vmem_bytes}")
    return True


if __name__ == "__main__":
    run()
