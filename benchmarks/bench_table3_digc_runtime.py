"""Paper Table III: DIGC runtime for one image across resolutions.

The paper times CPU/GPU baselines vs its FPGA streaming design; here
the *naive* full-matrix Algorithm 1 (the CPU/GPU baseline) is timed
against the *blocked streaming* implementation (the accelerator
dataflow) on the same XLA:CPU backend — apples-to-apples evidence for
the streaming claim. At 2048x2048 the naive path needs a >1 GB distance
matrix (the paper's GPU baselines OOM there); we report it as SKIP
above the budget, mirroring the paper's N/A entries."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.digc import digc_blocked, digc_reference
from repro.core.perfmodel import vig_resolution_to_nodes
from benchmarks.common import emit, timeit

# (name, D, k) — ViG variants' graph workloads (isotropic; pyramid has
# its own stage mix exercised in bench_fig1).
VARIANTS = {
    "vig_ti_iso": (192, 9),
    "vig_s_iso": (320, 9),
    "vig_b_iso": (640, 9),
}

NAIVE_BYTE_BUDGET = 600e6  # mimic the baseline's memory wall


def run(resolutions=(256, 512, 1024, 2048), iters=3):
    rng = np.random.default_rng(0)
    for vname, (d, k) in VARIANTS.items():
        for res in resolutions:
            n = vig_resolution_to_nodes(res)
            x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

            blocked = jax.jit(lambda a: digc_blocked(a, a, k=k, block_m=512))
            t_blk = timeit(blocked, x, iters=iters)
            emit(f"table3/{vname}_res{res}_blocked_us", t_blk * 1e6,
                 f"N={n}")

            naive_bytes = n * n * 4 * 2  # D_XY + sort copies
            if naive_bytes > NAIVE_BYTE_BUDGET:
                emit(f"table3/{vname}_res{res}_naive_us", -1.0,
                     f"SKIP naive needs {naive_bytes/1e9:.1f}GB (paper GPU OOM analogue)")
                continue
            naive = jax.jit(lambda a: digc_reference(a, a, k=k))
            t_ref = timeit(naive, x, iters=iters)
            emit(f"table3/{vname}_res{res}_naive_us", t_ref * 1e6,
                 f"speedup_streaming={t_ref / t_blk:.2f}x")
    return True


if __name__ == "__main__":
    run()
