"""Paper Table IV: end-to-end inference speedup from accelerating DIGC.

The paper offloads DIGC to the FPGA and reports 2.1-4.6x end-to-end
gains. Analogue: end-to-end ViG forward with the naive full-matrix DIGC
(baseline platform) vs with the streaming blocked DIGC (accelerator
dataflow), same backend. Includes an Amdahl consistency check against
the measured DIGC share."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import vig
from repro.models.module import init_params
from benchmarks.common import emit, timeit


def run(res=512, depth=4):
    rng = np.random.default_rng(0)
    for vname in ("vig_ti_iso", "vig_s_iso"):
        cfg = vig.VIG_VARIANTS[vname].replace(
            image_size=res, depths=(depth,), num_classes=100
        )
        params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
        imgs = jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)

        f_naive = jax.jit(
            lambda p, im: vig.vig_forward(p, im, cfg, digc_impl="reference")
        )
        f_stream = jax.jit(
            lambda p, im: vig.vig_forward(p, im, cfg, digc_impl="blocked")
        )
        t_naive = timeit(f_naive, params, imgs, iters=2)
        t_stream = timeit(f_stream, params, imgs, iters=2)
        speedup = t_naive / t_stream
        emit(f"table4/{vname}_e2e_naive_us", t_naive * 1e6, f"res={res}")
        emit(f"table4/{vname}_e2e_streaming_us", t_stream * 1e6,
             f"e2e_speedup={speedup:.2f}x")
    return True


if __name__ == "__main__":
    run()
