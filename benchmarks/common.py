"""Benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn(*args) in seconds (jit-compatible)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)
