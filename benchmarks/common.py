"""Benchmark utilities: timing + CSV emission + JSON dump."""

from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

ROWS: list[tuple] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn(*args) in seconds (jit-compatible)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)


def dump_json(path: str | Path, *, suites=None) -> Path:
    """Write every emitted row to ``path`` so the perf trajectory is
    recorded run over run (BENCH_digc.json).

    A partial run (``--only kernel serve``) merges: rows from suites
    *not* re-run (identified by their ``suite/`` name prefix) are
    preserved from the existing file, so the perf record never loses
    suites just because one was refreshed."""
    path = Path(path)
    new_rows = [
        {"name": n, "us_per_call": u, "derived": d} for n, u, d in ROWS
    ]
    ran = {r["name"].split("/")[0] for r in new_rows} | set(suites or ())
    kept = []
    if path.exists():
        try:
            prev = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            prev = {}
        kept = [
            r for r in prev.get("rows", [])
            if r["name"].split("/")[0] not in ran
        ]
    rows = kept + new_rows
    out = {
        "bench": "digc",
        "schema": 1,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "suites": sorted({r["name"].split("/")[0] for r in rows} | ran),
        "rows": rows,
    }
    path.write_text(json.dumps(out, indent=2) + "\n")
    return path
