# One function per paper table. Print ``name,us_per_call,derived`` CSV
# and dump the rows to BENCH_digc.json (perf trajectory record).
import argparse
import json
import re
import sys
from pathlib import Path

from benchmarks.common import ROWS, dump_json, header
from benchmarks import (
    bench_table1_cycles,
    bench_table2_resources,
    bench_table3_digc_runtime,
    bench_table4_e2e,
    bench_fig1_fraction,
    bench_kernel,
    bench_serve,
    bench_strategies,
)

SUITES = {
    "table1": bench_table1_cycles.run,
    "table2": bench_table2_resources.run,
    "table3": bench_table3_digc_runtime.run,
    "table4": bench_table4_e2e.run,
    "fig1": bench_fig1_fraction.run,
    "kernel": bench_kernel.run,
    "serve": bench_serve.run,
    "strategies": bench_strategies.run,
}


# Per-suite kwargs for the CI smoke mode: exercise every harness code
# path (timing loops, tuner, JSON dump) at toy workloads in ~a minute.
SMOKE_ARGS = {
    "table3": dict(resolutions=(256,), iters=1),
    "table4": dict(res=128, depth=1),
    "fig1": dict(resolutions=(256,), depth=1),
    "kernel": dict(smoke=True),
    "serve": dict(smoke=True),
    "strategies": dict(smoke=True),
}


# Rows the regression gate watches: the guard-overhead ratio and every
# stale-graph, multi-resolution and admission-scheduler warm row
# (absolute us and speedup ratios alike).
_REGRESS_RE = re.compile(
    r"^serve/(guarded_overhead_warm$"
    r"|(stale|multires|sched)(_.*)?(_warm_us|_warm)$)"
)
_REGRESS_RATIO = 1.15


def _workload_n(derived: str):
    m = re.search(r"\bN=(\d+)", derived or "")
    return m.group(1) if m else None


def check_regress(baseline_path: str) -> list[str]:
    """Compare this run's watched rows against the committed record.

    A ``*_us`` row regresses when it got slower by more than
    ``_REGRESS_RATIO``; a speedup/overhead ratio row regresses when the
    speedup shrank (or overhead grew) past the same ratio. Rows only
    compare against a baseline row at the *same workload* (the ``N=``
    tag in the derived column) — smoke runs use toy shapes, so their
    rows exercise the gate's mechanics without false alarms against
    the committed full-resolution record."""
    path = Path(baseline_path)
    if not path.exists():
        print(f"# check-regress: no baseline at {path}, skipped",
              flush=True)
        return []
    base = {
        r["name"]: r for r in
        json.loads(path.read_text()).get("rows", [])
    }
    failures = []
    for name, value, derived in ROWS:
        if not _REGRESS_RE.match(name) or name not in base:
            continue
        ref = base[name]
        if _workload_n(derived) != _workload_n(ref.get("derived", "")):
            continue
        want = float(ref["us_per_call"])
        if name.endswith("_us"):
            bad = value > want * _REGRESS_RATIO
            direction = "slower"
        elif "overhead" in name:
            bad = value > want * _REGRESS_RATIO
            direction = "more overhead"
        else:  # speedup rows: smaller is worse
            bad = value < want / _REGRESS_RATIO
            direction = "less speedup"
        if bad:
            failures.append(
                f"{name}: {value:.3f} vs baseline {want:.3f} "
                f"({direction} than the {_REGRESS_RATIO}x gate)"
            )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=list(SUITES))
    ap.add_argument("--fast", action="store_true",
                    help="smaller resolutions for quick runs")
    ap.add_argument("--smoke", action="store_true",
                    help="toy workloads, 1 iter: CI harness exercise "
                         "(not a perf record)")
    ap.add_argument("--json", default="BENCH_digc.json",
                    help="output JSON path ('' disables)")
    ap.add_argument("--check-regress", action="store_true",
                    help="fail if serve/guarded_overhead_warm or any "
                         "serve/{stale,multires,sched}_* warm row "
                         "regresses >"
                         f"{_REGRESS_RATIO}x vs the committed "
                         "BENCH_digc.json (same-workload rows only)")
    args = ap.parse_args()
    if args.smoke and args.json == "BENCH_digc.json":
        args.json = ""  # never overwrite the perf record with smoke rows
    header()
    for name in args.only:
        fn = SUITES[name]
        if args.smoke:
            fn(**SMOKE_ARGS.get(name, {}))
        elif args.fast and name == "table3":
            fn(resolutions=(256, 512), iters=1)
        elif args.fast and name == "fig1":
            fn(resolutions=(256,))
        else:
            fn()
    if args.check_regress:
        failures = check_regress("BENCH_digc.json")
        if failures:
            for f in failures:
                print(f"# REGRESSION {f}", flush=True)
            sys.exit(1)
        print("# check-regress: ok", flush=True)
    if args.json:
        path = dump_json(args.json, suites=args.only)
        print(f"# wrote {path}", flush=True)


if __name__ == '__main__':
    main()
