# One function per paper table. Print ``name,us_per_call,derived`` CSV
# and dump the rows to BENCH_digc.json (perf trajectory record).
import argparse

from benchmarks.common import dump_json, header
from benchmarks import (
    bench_table1_cycles,
    bench_table2_resources,
    bench_table3_digc_runtime,
    bench_table4_e2e,
    bench_fig1_fraction,
    bench_kernel,
    bench_serve,
    bench_strategies,
)

SUITES = {
    "table1": bench_table1_cycles.run,
    "table2": bench_table2_resources.run,
    "table3": bench_table3_digc_runtime.run,
    "table4": bench_table4_e2e.run,
    "fig1": bench_fig1_fraction.run,
    "kernel": bench_kernel.run,
    "serve": bench_serve.run,
    "strategies": bench_strategies.run,
}


# Per-suite kwargs for the CI smoke mode: exercise every harness code
# path (timing loops, tuner, JSON dump) at toy workloads in ~a minute.
SMOKE_ARGS = {
    "table3": dict(resolutions=(256,), iters=1),
    "table4": dict(res=128, depth=1),
    "fig1": dict(resolutions=(256,), depth=1),
    "kernel": dict(smoke=True),
    "serve": dict(smoke=True),
    "strategies": dict(smoke=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=list(SUITES))
    ap.add_argument("--fast", action="store_true",
                    help="smaller resolutions for quick runs")
    ap.add_argument("--smoke", action="store_true",
                    help="toy workloads, 1 iter: CI harness exercise "
                         "(not a perf record)")
    ap.add_argument("--json", default="BENCH_digc.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()
    if args.smoke and args.json == "BENCH_digc.json":
        args.json = ""  # never overwrite the perf record with smoke rows
    header()
    for name in args.only:
        fn = SUITES[name]
        if args.smoke:
            fn(**SMOKE_ARGS.get(name, {}))
        elif args.fast and name == "table3":
            fn(resolutions=(256, 512), iters=1)
        elif args.fast and name == "fig1":
            fn(resolutions=(256,))
        else:
            fn()
    if args.json:
        path = dump_json(args.json, suites=args.only)
        print(f"# wrote {path}", flush=True)


if __name__ == '__main__':
    main()
