"""Beyond-paper: the paper's DIGC as the neighbor-list engine for
KNN-sparse attention (sub-quadratic long-context attention).

Compares dense causal attention vs DIGC-KNN attention on a long
sequence: output agreement on early positions, wall-time, and the
asymptotic memory argument.

    PYTHONPATH=src python examples/knn_attention_longctx.py --seq 2048
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.knn_attention import knn_attention_mha


def dense_causal(q, k, v):
    s = q.shape[0]
    logits = jnp.einsum("shd,thd->hst", q, k) / np.sqrt(q.shape[-1])
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None], logits, -jnp.inf)
    return jnp.einsum("hst,thd->shd", jax.nn.softmax(logits, -1), v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--dh", type=int, default=32)
    ap.add_argument("--neighbors", type=int, default=32)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    s, h, dh = args.seq, args.heads, args.dh
    q, k, v = (jnp.asarray(rng.standard_normal((s, h, dh)), jnp.float32)
               for _ in range(3))

    dense = jax.jit(dense_causal)
    knn = jax.jit(lambda a, b, c: knn_attention_mha(
        a, b, c, num_neighbors=args.neighbors, causal=True))

    out_d = jax.block_until_ready(dense(q, k, v))
    out_k = jax.block_until_ready(knn(q, k, v))

    t0 = time.perf_counter(); jax.block_until_ready(dense(q, k, v))
    td = time.perf_counter() - t0
    t0 = time.perf_counter(); jax.block_until_ready(knn(q, k, v))
    tk = time.perf_counter() - t0

    nn = args.neighbors
    early = float(jnp.max(jnp.abs(out_d[:nn] - out_k[:nn])))
    cos = float(jnp.mean(jnp.sum(out_d * out_k, -1) /
                         (jnp.linalg.norm(out_d, axis=-1)
                          * jnp.linalg.norm(out_k, axis=-1) + 1e-9)))
    print(f"seq={s} heads={h} neighbors={nn}")
    print(f"  early rows (full history covered) max err: {early:.2e}")
    print(f"  mean cosine similarity dense vs knn: {cos:.3f}")
    print(f"  dense: {td*1e3:.0f}ms (O(S^2) scores = {s*s*h*4/1e6:.0f} MB)")
    print(f"  knn:   {tk*1e3:.0f}ms (O(S*k) gathered = {s*nn*h*4/1e6:.1f} MB)")
    print("  decode cost per token: dense O(S) vs knn top-k over cache;")
    print("  cache memory identical, attention compute k/S =",
          f"{nn/s:.3%} of dense")


if __name__ == "__main__":
    main()
