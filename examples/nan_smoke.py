"""NaN-debug smoke of the reference tier (DESIGN.md §11, CI fast job).

Runs the reference DIGC builder and a tiny ViG forward (cold and warm
ticks through the functional state) with well-conditioned inputs.
Executed under ``JAX_DEBUG_NANS=1`` in CI, it proves the fault-free
reference path manufactures no NaN/Inf anywhere in its compute — the
baseline the serving guards' finiteness screens are calibrated
against: any non-finite value they catch came from the *input or
corruption*, never from healthy reference-tier arithmetic.

    JAX_DEBUG_NANS=1 PYTHONPATH=src python examples/nan_smoke.py
"""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DigcSpec, digc
from repro.models import vig
from repro.models.module import init_params


def main():
    debug_nans = jax.config.jax_debug_nans
    print(f"jax_debug_nans={debug_nans} "
          f"(JAX_DEBUG_NANS={os.environ.get('JAX_DEBUG_NANS', '<unset>')})")
    rng = np.random.default_rng(0)

    # --- reference DIGC, eager and jitted -----------------------------
    feats = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)
    spec = DigcSpec(impl="reference", k=4, dilation=2)
    idx = digc(feats, spec=spec)
    idx_jit = jax.jit(lambda f: digc(f, spec=spec))(feats)
    assert bool(jnp.all(idx == idx_jit))
    print(f"reference DIGC: idx {idx.shape}, eager == jit")

    # --- tiny ViG forward, cold then warm state tick ------------------
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3, digc_impl="reference",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    state = vig.init_vig_state(cfg, 2, "reference")
    images = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    fwd = jax.jit(lambda p, im, s: vig.vig_forward(
        p, im, cfg, digc_impl="reference", state=s))
    for tick in (1, 2):
        logits, state = fwd(params, images, state)
        assert bool(jnp.isfinite(logits).all())
        print(f"ViG tick {tick}: logits {logits.shape} all finite")
    print("NAN_SMOKE_OK")


if __name__ == "__main__":
    main()
