"""Quickstart: build a dynamic image graph with DIGC (all three
implementation tiers), inspect it, then run a tiny ViG forward pass.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import digc, edge_list, degree_histogram, fpga_cycles
from repro.models import vig
from repro.models.module import init_params


def main():
    rng = np.random.default_rng(0)

    # --- 1. DIGC on the paper's ViG-Tiny workload: N=M=196, D=192 -----
    n, d, k, dil = 196, 192, 8, 2
    feats = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

    idx_ref = digc(feats, k=k, dilation=dil, impl="reference")
    idx_blk = digc(feats, k=k, dilation=dil, impl="blocked")
    idx_pl = digc(feats, k=k, dilation=dil, impl="pallas")
    assert bool(jnp.all(idx_ref == idx_blk)) and bool(jnp.all(idx_ref == idx_pl))
    print(f"DIGC: {n} nodes, k={k}, dilation={dil}")
    print(f"  neighbor lists agree across reference/blocked/pallas: True")
    edges = edge_list(idx_blk)
    deg = degree_histogram(idx_blk, n)
    print(f"  edges={edges.shape[1]}, in-degree mean={float(deg.mean()):.1f} "
          f"max={int(deg.max())}")
    print(f"  paper Table I cycle model @ this workload: {fpga_cycles(n, n, d, k)}")

    # --- 2. tiny ViG classifier forward --------------------------------
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=64, embed_dims=(48,), depths=(2,), num_classes=10, k=5
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    images = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)
    logits = jax.jit(lambda p, im: vig.vig_forward(p, im, cfg))(params, images)
    print(f"ViG forward: images {images.shape} -> logits {logits.shape}")
    print(f"  predictions: {jnp.argmax(logits, -1).tolist()}")


if __name__ == "__main__":
    main()
