"""Quickstart: build dynamic image graphs with DIGC through the
GraphBuilder registry (every implementation tier), batched, then run a
tiny ViG forward pass.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DigcSpec,
    available_impls,
    digc,
    degree_histogram,
    edge_list,
    fpga_cycles,
)
from repro.models import vig
from repro.models.module import init_params


def main():
    rng = np.random.default_rng(0)

    # --- 1. DIGC on the paper's ViG-Tiny workload: N=M=196, D=192 -----
    # Batched-first: a (B, N, D) batch of images goes through every
    # registered builder in one call — no per-sample vmap.
    b, n, d, k, dil = 2, 196, 192, 8, 2
    feats = jnp.asarray(rng.standard_normal((b, n, d)), jnp.float32)

    print(f"registered DIGC builders: {available_impls()}")
    idx_ref = digc(feats, spec=DigcSpec(impl="reference", k=k, dilation=dil))
    idx_blk = digc(feats, spec=DigcSpec(impl="blocked", k=k, dilation=dil))
    idx_pl = digc(feats, spec=DigcSpec(impl="pallas", k=k, dilation=dil))
    assert bool(jnp.all(idx_ref == idx_blk)) and bool(jnp.all(idx_ref == idx_pl))
    print(f"DIGC: batch={b}, {n} nodes, k={k}, dilation={dil}")
    print(f"  neighbor lists agree across reference/blocked/pallas: True")
    edges = edge_list(idx_blk[0])
    deg = degree_histogram(idx_blk[0], n)
    print(f"  edges={edges.shape[1]}, in-degree mean={float(deg.mean()):.1f} "
          f"max={int(deg.max())}")
    print(f"  paper Table I cycle model @ this workload: {fpga_cycles(n, n, d, k)}")

    # single-image (N, D) still works — promoted to B=1 internally
    idx_one = digc(feats[0], k=k, dilation=dil, impl="blocked")
    assert bool(jnp.all(idx_one == idx_blk[0]))

    # --- 2. tiny ViG classifier forward --------------------------------
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=64, embed_dims=(48,), depths=(2,), num_classes=10, k=5
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    images = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)
    logits = jax.jit(lambda p, im: vig.vig_forward(p, im, cfg))(params, images)
    print(f"ViG forward: images {images.shape} -> logits {logits.shape}")
    print(f"  predictions: {jnp.argmax(logits, -1).tolist()}")


if __name__ == "__main__":
    main()
