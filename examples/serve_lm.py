"""Serve a small LM with batched requests through the slot-based
continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 6
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import get_smoke
from repro.launch.api import get_api
from repro.models.module import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots,
                         max_len=args.prompt_len + args.new_tokens + 4)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.new_tokens))
    finished = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    for r in sorted(finished, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} -> "
              f"out={r.out_tokens}")
    print(f"{len(finished)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU, {args.slots} slots)")


if __name__ == "__main__":
    main()
