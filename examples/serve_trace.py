"""Trace-replay driver for the SLO-bounded admission scheduler
(DESIGN.md §14).

Replays the seeded Poisson+burst arrival trace (the same generator
``benchmarks/bench_serve.py`` measures) through a ``VigServeEngine``
under a ``VirtualClock``, twice:

* the exact-size baseline (``buckets=None``, ``slo_ms=0``): every
  arrival wave dispatches immediately at its own batch size;
* the scheduled engine (bucketed, ``slo_ms``): sub-width arrivals
  wait up to their SLO and coalesce into fuller ticks, then the
  served trace re-tunes the bucket set via the arrival-histogram
  optimizer.

Prints per-engine tick/utilization/compile stats and the tuned bucket
set — a deterministic smoke of the whole §14 path (no wall-clock
sleeps: the virtual clock jumps straight to deadlines).

    PYTHONPATH=src python examples/serve_trace.py
    PYTHONPATH=src python examples/serve_trace.py --slo-ms 80 --seed 3
"""

import argparse

import numpy as np
import jax

from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigServeEngine
from repro.serve.sched import VirtualClock, arrival_trace, replay


def _model(image_size, patch):
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=image_size, patch=patch, embed_dims=(32,), depths=(2,),
        num_classes=10, k=4, digc_impl="blocked",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _report(tag, eng, ticks):
    s = eng.stats()
    served = sum(t[0] for t in ticks)
    print(f"{tag}:")
    print(f"  requests {served}  ticks {len(ticks)}  "
          f"deferrals {s['deferrals']}")
    print(f"  live lanes {s['live_lanes']}  padded {s['padded_lanes']}  "
          f"util {s['util']:.3f}")
    print(f"  compiled programs {s['compiled_programs']}  "
          f"buckets {s['buckets']}")
    print(f"  prefetch issued/hits {s['prefetch_issued']}"
          f"/{s['prefetch_hits']}  park hits {s['park_hits']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--image-size", type=int, default=32)
    ap.add_argument("--patch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--arrivals", type=int, default=48)
    ap.add_argument("--slo-ms", type=float, default=120.0)
    ap.add_argument("--bucket-cap", type=int, default=4)
    args = ap.parse_args(argv)

    cfg, params = _model(args.image_size, args.patch)
    rng = np.random.default_rng(args.seed)
    images = {f"t{i}": rng.standard_normal(
        (args.image_size, args.image_size, 3)).astype(np.float32)
        for i in range(args.tenants)}
    arrivals = arrival_trace(seed=args.seed, tenants=args.tenants,
                             poisson_n=args.arrivals)
    print(f"trace: {len(arrivals)} arrivals over "
          f"{arrivals[-1].t_ms:.0f} ms, {args.tenants} tenants")

    clock = VirtualClock()
    exact = VigServeEngine(cfg, params, digc_impl="blocked",
                           autotune=False, buckets=None, clock=clock)
    _report("exact-size baseline (slo_ms=0)",
            exact, replay(exact, arrivals, images, clock=clock))

    clock = VirtualClock()
    sched = VigServeEngine(cfg, params, digc_impl="blocked",
                           autotune=False, slo_ms=args.slo_ms,
                           clock=clock, bucket_cap=args.bucket_cap)
    _report(f"scheduled (slo_ms={args.slo_ms:g}, buckets={sched.buckets})",
            sched, replay(sched, arrivals, images, clock=clock))
    tuned = sched.retune_buckets()
    print(f"  retuned bucket set for this trace: {tuned}")


if __name__ == "__main__":
    main()
