"""End-to-end driver: train a ViG image classifier with dynamic graph
construction in every block, on the synthetic class-conditional image
stream, with checkpoint/resume.

Default config is CPU-sized; --full trains the real ViG-Ti (~10M params
at 224x224) for --steps steps.

    PYTHONPATH=src python examples/train_vig.py --steps 100
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import available_impls, get_builder
from repro.data.pipeline import DataConfig, image_pipeline
from repro.models import vig
from repro.models.module import init_params
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=64)
    ap.add_argument("--num-classes", type=int, default=10)
    ap.add_argument("--full", action="store_true", help="real ViG-Ti config")
    # choices from the registry by name only (no eager builder imports);
    # distributed builders are rejected after parsing, importing just
    # the selected one.
    ap.add_argument("--digc-impl", default="blocked",
                    choices=list(available_impls()))
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)
    if get_builder(args.digc_impl).distributed:
        ap.error(f"--digc-impl {args.digc_impl} needs a device mesh; "
                 "this single-host example cannot drive it")

    if args.full:
        cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
            num_classes=args.num_classes, digc_impl=args.digc_impl
        )
        args.image_size = cfg.image_size
    else:
        cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
            image_size=args.image_size, embed_dims=(48,), depths=(4,), k=5,
            num_classes=args.num_classes, digc_impl=args.digc_impl,
        )

    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"ViG ({'full' if args.full else 'reduced'}): {n_params/1e6:.1f}M params, "
          f"grid {cfg.base_grid}x{cfg.base_grid}, digc={args.digc_impl}")

    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps, weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, oc, loss_fn=vig.vig_loss_fn,
                                      param_dtype=jnp.float32))
    opt = init_train_state(params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        restored, start = ckpt.restore(args.ckpt_dir, {"p": params, "o": opt})
        params, opt = restored["p"], restored["o"]
        print(f"resumed from step {start}")

    dc = DataConfig(seq_len=1, global_batch=args.batch, vocab_size=1, seed=0)
    pipe = image_pipeline(dc, args.image_size, args.num_classes, start_step=start)
    losses, accs = [], []
    try:
        for step, raw in pipe:
            if step >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
            logits = vig.vig_forward(params, batch["images"], cfg)
            accs.append(float(jnp.mean(jnp.argmax(logits, -1) == batch["labels"])))
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {losses[-1]:.4f} acc {accs[-1]:.2f}")
            if args.ckpt_dir and (step + 1) % 50 == 0:
                ckpt.save(args.ckpt_dir, step + 1, {"p": params, "o": opt})
    finally:
        pipe.close()
    k = max(len(losses) // 5, 1)
    print(f"loss {np.mean(losses[:k]):.3f} -> {np.mean(losses[-k:]):.3f}; "
          f"acc {np.mean(accs[:k]):.2f} -> {np.mean(accs[-k:]):.2f}")


if __name__ == "__main__":
    main()
