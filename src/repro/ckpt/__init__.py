# Fault-tolerant sharded checkpointing (atomic, elastic restore).
