"""Fault-tolerant sharded checkpointing.

Layout: <dir>/step_<N>/
    manifest.json        tree structure + shapes + dtypes + step
    shard_<host>.npz     this host's param/opt leaves (device-sharded
                         arrays are saved as the host-local addressable
                         shards + their index offsets)
    COMMITTED            empty marker written last (atomic commit)

Properties:
  * atomic: readers only trust directories with the COMMITTED marker;
    a crash mid-write leaves a garbage dir that restore ignores and
    cleanup deletes.
  * auto-resume: ``latest_step`` scans for the newest committed step.
  * elastic: ``restore`` reassembles full logical arrays from shards
    and re-shards onto the *current* mesh — device count may change
    between save and restore (ZeRO re-sharding on restart).
  * keep-last-N garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{SEP}{k}" if prefix else str(k), v)
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(f"{prefix}{SEP}{i}" if prefix else str(i), v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def save(ckpt_dir: str | Path, step: int, tree, *, host_id: int = 0,
         keep: int = 3) -> Path:
    """Synchronous sharded save with atomic commit."""
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    arrays = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        # npz cannot serialize ml_dtypes (bfloat16 etc.): store raw bytes
        arrays[key] = np.frombuffer(arr.tobytes(), np.uint8)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    np.savez(tmp / f"shard_{host_id}.npz", **{k: v for k, v in arrays.items()})
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    # atomic publish: rename tmp -> final, then COMMITTED marker
    if out.exists():
        shutil.rmtree(out)
    os.replace(tmp, out)
    (out / "COMMITTED").touch()
    _gc(ckpt_dir, keep)
    return out


class AsyncCheckpointer:
    """Overlap checkpoint writes with training: `save` returns after
    snapshotting to host memory; the disk write happens on a worker
    thread. `wait()` joins outstanding writes (call before exit)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree):
        snapshot = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree
        )
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, snapshot),
            kwargs={"keep": self.keep}, daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, tree_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of `tree_like` (arrays or
    ShapeDtypeStructs). If `shardings` (a matching pytree of
    NamedSharding) is given, leaves are placed sharded onto the current
    mesh — independent of the mesh at save time (elastic restore)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    src = ckpt_dir / f"step_{step:08d}"
    with open(src / "manifest.json") as f:
        manifest = json.load(f)
    data = {}
    for shard in sorted(src.glob("shard_*.npz")):
        with np.load(shard) as z:
            for k in z.files:
                meta = manifest["leaves"][k]
                data[k] = np.frombuffer(
                    z[k].tobytes(), dtype=np.dtype(meta["dtype"])
                ).reshape(meta["shape"])

    flat_like = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out_flat = {}
    for key, like in flat_like.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want = tuple(like.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want}")
        if key in flat_sh and flat_sh[key] is not None:
            out_flat[key] = jax.device_put(arr, flat_sh[key])
        else:
            out_flat[key] = jax.device_put(arr.astype(like.dtype))
    return _unflatten_like(tree_like, out_flat), step


def _unflatten_like(tree_like, flat: dict[str, Any]):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {
                k: walk(f"{prefix}{SEP}{k}" if prefix else str(k), v)
                for k, v in node.items()
            }
        if isinstance(node, (tuple, list)):
            vals = [
                walk(f"{prefix}{SEP}{i}" if prefix else str(i), v)
                for i, v in enumerate(node)
            ]
            return type(node)(vals) if not hasattr(node, "_fields") else type(node)(*vals)
        return flat[prefix]

    return walk("", tree_like)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(
        d for d in ckpt_dir.iterdir()
        if d.name.startswith("step_") and (d / "COMMITTED").exists()
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(d, ignore_errors=True)
    # clean aborted tmp dirs
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d, ignore_errors=True)
