"""Architecture registry: the 10 assigned archs + ViG variants.

``get_config(name)`` / ``get_smoke(name)`` select by --arch id.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# arch id -> module path (one file per assigned architecture)
_ARCH_MODULES = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "granite-34b": "repro.configs.granite_34b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

ARCH_IDS = tuple(_ARCH_MODULES)

# (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.SMOKE


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Is (arch, shape) runnable? long_500k needs sub-quadratic attention
    (DESIGN.md §Arch-applicability)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode is quadratic — skipped (see DESIGN.md); opt-in via attention='knn'"
    return True, ""
