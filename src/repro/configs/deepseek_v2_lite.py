"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 64 routed experts
top-6 + 2 shared experts.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400. [arXiv:2405.04434]
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    mla=MLAConfig(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256,
    mla=MLAConfig(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=64, num_shared=1,
                  capacity_factor=2.0),
    remat="none",
)
