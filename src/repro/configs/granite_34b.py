"""granite-34b [dense]: llama-arch code model, MQA (kv=1).

88L d_model=6144 48H d_ff=24576 vocab=49152. [arXiv:2405.04324]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, remat="none",
)
