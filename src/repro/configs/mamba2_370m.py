"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1024, vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,  # d_inner / head_dim = 2048 / 64
    num_kv_heads=32,
    d_ff=0,  # attention-free, no separate channel mixer
    vocab_size=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)

SMOKE = CONFIG.replace(
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=8,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
    remat="none",
)
