"""olmo-1b [dense]: non-parametric LayerNorm, tied embeddings.

16L d_model=2048 16H d_ff=8192 vocab=50304. [arXiv:2402.00838]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    norm="nonparam_ln",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, remat="none",
)
