"""qwen1.5-4b [dense]: GQA kv=20 (MHA-equal), QKV bias.

40L d_model=2560 20H d_ff=6912 vocab=151936. [hf:Qwen/Qwen1.5-0.5B]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1e6,
    # 20 heads on a 16-way TP axis: batch-over-model sharding (see
    # ModelConfig.shard_batch_over_model and EXPERIMENTS.md §Perf T3)
    shard_batch_over_model=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, remat="none",
)
