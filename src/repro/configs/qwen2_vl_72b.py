"""qwen2-vl-72b [vlm]: M-RoPE (temporal/height/width sections), dynamic
resolution; vision frontend stubbed (input_specs supplies position ids).

80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064. [arXiv:2409.12191]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),  # sums to head_dim // 2
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3), remat="none",
)
