"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
[arXiv:2402.19427]
"""

from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    norm="rmsnorm",
    activation="swiglu",
    attention="local",
    window=2048,
    rope_theta=10_000.0,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=4096,
                        window=2048, d_conv=4),
)

SMOKE = CONFIG.replace(
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    window=8,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=64,
                        window=8, d_conv=4),
    remat="none",
)
