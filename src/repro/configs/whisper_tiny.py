"""whisper-tiny [audio]: encoder-decoder, conv frontend stubbed
(input_specs supplies precomputed frame embeddings).

4L d_model=384 6H d_ff=1536 vocab=51865. [arXiv:2212.04356]
"""

from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers; encoder in encdec config
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    norm="layernorm",
    activation="gelu",
    qkv_bias=True,
    rope_theta=0.0,  # absolute positions (sinusoidal enc / learned dec)
    tie_embeddings=True,
    encdec=EncDecConfig(enc_layers=4, max_source_positions=1500),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    encdec=EncDecConfig(enc_layers=2, max_source_positions=64),
    remat="none",
)
