# The paper's primary contribution: Dynamic Image Graph Construction
# (DIGC) as a composable JAX feature — reference / blocked-streaming /
# fused-Pallas / distributed-ring implementations plus the graph ops and
# the paper's analytical performance model.

from repro.core.digc import (
    BIG,
    digc,
    digc_blocked,
    digc_reference,
    dilate,
    merge_topk,
    pairwise_sq_dists,
)
from repro.core.graph import (
    AGGREGATORS,
    degree_histogram,
    edge_list,
    grid_pos_bias,
    knn_gather,
    mean_aggregate,
    mr_aggregate,
    sum_aggregate,
)
from repro.core.perfmodel import (
    FPGAConfig,
    TPUConfig,
    digc_flops,
    digc_hbm_bytes,
    fpga_cycles,
    fpga_latency_ms,
    tpu_digc_estimate,
    vig_resolution_to_nodes,
)
