# The paper's primary contribution: Dynamic Image Graph Construction
# (DIGC) as a composable JAX feature — reference / blocked-streaming /
# fused-Pallas / distributed-ring / cluster / axial implementations
# behind one GraphBuilder registry, plus the graph ops and the paper's
# analytical performance model. Everything is batched-first: (B, N, D)
# in, (B, N, k) out, with (N, D) promoted to B=1.

from repro.core.builder import (
    DEGRADATION_LADDER,
    DigcSpec,
    GraphBuilder,
    available_impls,
    degraded_spec,
    fallback_chain,
    get_builder,
    list_builders,
    register,
    resolve_spec,
)
from repro.core.faults import (
    SITES,
    FaultError,
    FaultInfo,
    FaultPlan,
)
from repro.core.digc import (
    BIG,
    digc,
    digc_blocked,
    digc_reference,
    dilate,
    merge_topk,
    pairwise_sq_dists,
)
from repro.core.engine import (
    MERGE_STRATEGIES,
    DigcCache,
    select_topkd,
    stream_topk,
)
from repro.core.packedkey import (
    INT_BIG,
    idx_bits_for,
    pack_keys,
    unpack_keys,
)
from repro.core.state import (
    DigcState,
    DigcStateEntry,
    entry_row_fingerprint,
    entry_row_finite,
    state_entry,
)
from repro.core.tuner import (
    DigcTuner,
    TileConfig,
    VigSchedule,
    autotune_spec,
    host_key,
    workload_key,
)
from repro.core.graph import (
    AGGREGATORS,
    degree_histogram,
    edge_list,
    grid_pos_bias,
    knn_gather,
    mean_aggregate,
    mr_aggregate,
    sum_aggregate,
)
from repro.core.perfmodel import (
    FPGAConfig,
    TPUConfig,
    digc_flops,
    digc_hbm_bytes,
    fpga_cycles,
    fpga_latency_ms,
    tpu_digc_estimate,
    vig_resolution_to_nodes,
)
