"""DigcSpec + GraphBuilder registry (DESIGN.md §4).

The paper's modularity claim — "the graph construction approach can be
generalized by adjusting the mechanism used to compute similarity" — is
realized here as first-class objects instead of stringly-typed if/elif
chains:

  * ``DigcSpec``     — a frozen dataclass naming the implementation plus
    every tunable knob (k, dilation, block shapes, strategy-specific
    parameters). Unknown knobs for a given builder *raise* instead of
    being silently dropped.
  * ``GraphBuilder`` — one registered entry per implementation tier or
    strategy: a batched build function, the set of knobs it accepts,
    capability flags (pos_bias / causal / exact / distributed) and an
    optional fused aggregation kernel.
  * the registry    — ``register`` / ``get_builder`` / ``list_builders``.
    Builders self-register at import time; ``_LAZY`` maps names to the
    module that registers them so ``get_builder("pallas")`` works without
    eagerly importing the kernel package.

Every build function is **batched-first**: it receives x (B, N, D),
y (B, M, D) and optional pos_bias (B, N, M) and returns (idx, dist),
each (B, N, k). ``promote_batch`` lifts single-image (N, D) inputs to
B=1 so the public ``digc`` entry point accepts both ranks.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class DigcSpec:
    """Complete specification of one DIGC invocation.

    ``impl``, ``k``, ``dilation`` and ``causal`` are common to every
    builder; the remaining fields are strategy-specific knobs that
    default to None (= builder default). Setting a knob the selected
    builder does not accept is a ``ValueError`` at dispatch time.
    ``k`` has no default on purpose (None = unset): consumers that own
    a k (e.g. the ViG config) fill it in, so a spec passed only to pick
    an impl can never silently override the model's neighbor count.
    """

    impl: str = "blocked"
    k: Optional[int] = None
    dilation: int = 1
    causal: bool = False
    # --- blocked / pallas tiling
    block_n: Optional[int] = None
    block_m: Optional[int] = None
    # --- streaming-engine merge strategy (core/engine.py)
    merge: Optional[str] = None
    fuse_norms: Optional[bool] = None
    # selection group width for merge="select": 32 (one int32 lane
    # mask word, the default) or up to 64 (two mask words)
    group_w: Optional[int] = None
    # --- pallas kernel variants (§Perf iterations)
    interpret: Optional[bool] = None
    packed: Optional[bool] = None
    mxu_bf16: Optional[bool] = None
    bucket_rounds: Optional[int] = None
    # LSM/GMM realization inside the fused kernel: "bitonic" (default,
    # sorted two-level merge) or "legacy" (kd-pass extraction)
    kernel_merge: Optional[str] = None
    # --- cluster (ClusterViG family)
    n_clusters: Optional[int] = None
    n_probe: Optional[int] = None
    capacity_factor: Optional[float] = None
    seed: Optional[int] = None
    # --- axial (GreedyViG family)
    grid_h: Optional[int] = None
    grid_w: Optional[int] = None
    # --- stale-graph serving (DESIGN.md §12): drift-gated reuse of the
    # cached graph carried in a DigcStateEntry. Policies:
    #   "off"     — rebuild every call (the default; None means off)
    #   "layer"   — every call may serve the cached graph when the
    #               per-row feature drift is below drift_tau and the
    #               graph is younger than max_stale gated calls
    #   "tick"    — only the first call per forward (per stage) gates;
    #               later layers of the same tick reuse unconditionally
    #   "overlap" — always serve the cached (one-call-stale) graph and
    #               issue the refresh build data-independently of the
    #               convolution (pipelined double-buffer)
    reuse: Optional[str] = None
    drift_tau: Optional[float] = None
    max_stale: Optional[int] = None
    # --- ring (distributed): mesh + co-node ring axis, plus an
    # optional second mesh axis sharding the batch rows data-parallel
    # (serving slot rows x ring-sharded co-nodes, DESIGN.md §10)
    mesh: Optional[Any] = None
    axis_name: Optional[str] = None
    batch_axis: Optional[str] = None

    def mesh_shape(self) -> Optional[tuple[int, ...]]:
        """Device counts of the spec's mesh (None when unsharded) —
        part of the tuner's workload identity: a schedule measured on
        an N-way ring is not a single-device schedule."""
        if self.mesh is None:
            return None
        return tuple(int(s) for s in self.mesh.shape.values())

    def replace(self, **kw) -> "DigcSpec":
        return dataclasses.replace(self, **kw)

    def with_grid(self, grid_h: int, grid_w: int) -> "DigcSpec":
        """Fill grid-geometry knobs if this spec's builder accepts them.

        Models re-derive geometry per stage (pyramid stages shrink the
        grid), so any user-supplied grid knobs are replaced by the
        actual stage grid; a no-op for builders without grid knobs.
        """
        builder = get_builder(self.impl)
        updates = {
            f: v
            for f, v in (("grid_h", grid_h), ("grid_w", grid_w))
            if f in builder.knobs
        }
        return self.replace(**updates) if updates else self

    def knobs(self) -> dict[str, Any]:
        """The non-None strategy-specific knobs of this spec."""
        return {
            f: getattr(self, f)
            for f in KNOB_FIELDS
            if getattr(self, f) is not None
        }


_COMMON_FIELDS = ("impl", "k", "dilation", "causal")
KNOB_FIELDS: tuple[str, ...] = tuple(
    f.name for f in dataclasses.fields(DigcSpec) if f.name not in _COMMON_FIELDS
)

# -- stale-graph reuse policy (DESIGN.md §12) ------------------------------

REUSE_POLICIES: tuple[str, ...] = ("off", "layer", "tick", "overlap")
REUSE_KNOBS: frozenset = frozenset({"reuse", "drift_tau", "max_stale"})
DEFAULT_DRIFT_TAU = 0.05
DEFAULT_MAX_STALE = 4


def reuse_params(spec: DigcSpec) -> tuple[Optional[str], float, int]:
    """The spec's effective (policy, drift_tau, max_stale) triple.

    Policy is None when reuse is off ("off" and unset collapse — both
    mean every call rebuilds). Unset knobs take the serving defaults;
    the values themselves are validated by ``GraphBuilder.validate``.
    """
    policy = spec.reuse if spec.reuse not in (None, "off") else None
    tau = (
        float(spec.drift_tau) if spec.drift_tau is not None
        else DEFAULT_DRIFT_TAU
    )
    stale = (
        int(spec.max_stale) if spec.max_stale is not None
        else DEFAULT_MAX_STALE
    )
    return policy, tau, stale


@dataclasses.dataclass(frozen=True)
class GraphBuilder:
    """One registered graph-construction implementation.

    ``build(x, y, pos_bias, spec) -> (idx, dist)`` with batched inputs
    x (B, N, D), y (B, M, D), pos_bias (B, N, M) | None; outputs are
    (B, N, k) each, distances ascending, BIG-sentinel for invalid lanes.

    ``y`` is None for a self-graph call (the caller passed no co-nodes)
    — the explicit marker object identity cannot provide under jit.
    Builders that differentiate the self-graph case (axial) key on it;
    everyone else treats None as "co-nodes = x".
    """

    name: str
    build: Callable
    knobs: frozenset
    exact: bool = True
    supports_pos_bias: bool = False
    supports_causal: bool = False
    distributed: bool = False
    # Builders that can reuse DigcCache state (co-node norms, cluster
    # centroids) accept build(..., cache=, cache_key=) keywords.
    supports_cache: bool = False
    # Builders that thread functional DigcState (core/state.py) accept
    # build(..., state_entry=) and return (idx, dist, new_entry); for
    # everyone else digc() passes the state through unchanged.
    supports_state: bool = False
    # Builders that accept build(..., m_valid=) — a (M,) or (B, M) bool
    # mask marking live co-nodes. Masked co-nodes take the ring tier's
    # BIG-norm treatment (distance >= BIG/2, can never enter a top-k),
    # which is what lets serving pad ragged patch counts up to a static
    # N-bucket with inert pad nodes (DESIGN.md §13).
    supports_pad: bool = False
    # Optional fused neighbor aggregation (x, y, idx) -> (B, N, D);
    # None means the consumer uses the generic mr_aggregate.
    aggregate: Optional[Callable] = None
    doc: str = ""

    def validate(self, spec: DigcSpec, *, has_pos_bias: bool = False) -> None:
        """Reject knobs this builder does not accept (no silent drops)."""
        bad = [
            f
            for f in KNOB_FIELDS
            if getattr(spec, f) is not None and f not in self.knobs
        ]
        if bad:
            raise ValueError(
                f"DIGC impl {self.name!r} does not accept knob(s) {bad}; "
                f"accepted: {sorted(self.knobs) or '(none)'}"
            )
        if spec.causal and not self.supports_causal:
            raise ValueError(f"DIGC impl {self.name!r} does not support causal")
        if has_pos_bias and not self.supports_pos_bias:
            raise ValueError(f"DIGC impl {self.name!r} does not support pos_bias")
        # Reuse-policy values (the knob *names* were screened above):
        # malformed policies must fail at dispatch, not three ticks into
        # a serving loop as a silent always-rebuild.
        if spec.reuse is not None and spec.reuse not in REUSE_POLICIES:
            raise ValueError(
                f"DigcSpec.reuse={spec.reuse!r} is not a reuse policy; "
                f"valid: {REUSE_POLICIES}"
            )
        if spec.drift_tau is not None:
            if spec.drift_tau < 0:
                raise ValueError(
                    f"DigcSpec.drift_tau must be >= 0, got {spec.drift_tau}"
                )
            if spec.reuse in (None, "off"):
                raise ValueError(
                    "DigcSpec.drift_tau is set but reuse is off; pass "
                    "reuse='layer'|'tick'|'overlap' (a gate threshold "
                    "without a gate is a config error)"
                )
        if spec.max_stale is not None:
            if spec.max_stale < 1:
                raise ValueError(
                    f"DigcSpec.max_stale must be >= 1, got {spec.max_stale}"
                )
            if spec.reuse in (None, "off"):
                raise ValueError(
                    "DigcSpec.max_stale is set but reuse is off; pass "
                    "reuse='layer'|'tick'|'overlap'"
                )


_REGISTRY: dict[str, GraphBuilder] = {}

# name -> module whose import registers it (keeps the import graph light:
# asking for "pallas" is what pulls in the kernel package).
_LAZY: dict[str, str] = {
    "reference": "repro.core.digc",
    "blocked": "repro.core.digc",
    "pallas": "repro.kernels.ops",
    "ring": "repro.core.ring",
    "cluster": "repro.core.strategies",
    "axial": "repro.core.strategies",
}


def register(builder: GraphBuilder, *, overwrite: bool = False) -> GraphBuilder:
    if builder.name in _REGISTRY and not overwrite:
        raise ValueError(f"GraphBuilder {builder.name!r} already registered")
    _REGISTRY[builder.name] = builder
    return builder


def available_impls() -> tuple[str, ...]:
    """Names of every registered (or lazily registrable) builder."""
    return tuple(sorted(set(_REGISTRY) | set(_LAZY)))


def get_builder(name: str) -> GraphBuilder:
    if name not in _REGISTRY and name in _LAZY:
        importlib.import_module(_LAZY[name])
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown DIGC impl: {name!r}; available: {available_impls()}"
        )
    return _REGISTRY[name]


def list_builders() -> tuple[GraphBuilder, ...]:
    """All builders, lazily importing their defining modules."""
    return tuple(get_builder(n) for n in available_impls())


def resolve_spec(
    spec: Optional[DigcSpec] = None,
    *,
    impl: Optional[str] = None,
    k: Optional[int] = None,
    dilation: Optional[int] = None,
    causal: Optional[bool] = None,
    **knobs,
) -> DigcSpec:
    """Build (or refine) a DigcSpec from keyword-style arguments.

    With ``spec=None`` this is the legacy ``digc(x, k=.., impl=..)``
    path; with a spec, any explicitly passed common field or knob
    overrides the spec's value. Unknown knob *names* raise immediately.
    """
    unknown = set(knobs) - set(KNOB_FIELDS)
    if unknown:
        raise ValueError(
            f"unknown DIGC knob(s) {sorted(unknown)}; valid knobs: "
            f"{list(KNOB_FIELDS)}"
        )
    if spec is None:
        if k is None:
            raise TypeError("digc() requires k= (or a full spec=)")
        return DigcSpec(
            impl=impl or "blocked",
            k=k,
            dilation=1 if dilation is None else dilation,
            causal=bool(causal),
            **knobs,
        )
    overrides: dict[str, Any] = dict(knobs)
    if impl is not None:
        overrides["impl"] = impl
    if k is not None:
        overrides["k"] = k
    if dilation is not None:
        overrides["dilation"] = dilation
    if causal is not None:
        overrides["causal"] = causal
    spec = spec.replace(**overrides) if overrides else spec
    if spec.k is None:
        raise TypeError("DigcSpec.k is unset: pass k= or spec.replace(k=...)")
    return spec


# -- degradation ladder (fault-tolerant serving, DESIGN.md §11) ------------
#
# The paper's tiers are interchangeable by construction (same contract,
# "scales seamlessly across image resolutions, ViG layer types, and
# model sizes") — which gives serving a principled degraded mode: when
# a tier's program fails to build (a Pallas compile failure on an
# untested shape) or blows its tick deadline, serve the *same* request
# through the next-less-specialized tier instead of dying.
#
# Ordering rules: each rung must (1) accept the common spec fields
# (k / dilation / causal) with no tier-specific knobs, (2) depend on
# strictly less machinery than the rung above (pallas needs a working
# Mosaic lowering; blocked needs only XLA; reference needs only
# jnp.top_k and O(N*M) memory), and (3) never be *less* exact than the
# rung above — degrading must trade speed, not correctness. Approximate
# tiers (cluster, axial) and the distributed ring therefore degrade
# *into* the exact chain (blocked -> reference), never out of it.

DEGRADATION_LADDER: tuple[str, ...] = ("pallas", "blocked", "reference")


def fallback_chain(impl: str) -> tuple[str, ...]:
    """Ordered degraded impls to serve through when ``impl`` is
    unhealthy; empty for the last-resort tier (reference)."""
    if impl in DEGRADATION_LADDER:
        return DEGRADATION_LADDER[DEGRADATION_LADDER.index(impl) + 1:]
    # tiers outside the ladder (cluster / axial / ring) degrade into
    # the exact single-device chain
    return DEGRADATION_LADDER[1:]


def degraded_spec(spec: DigcSpec, impl: str) -> DigcSpec:
    """A clean spec serving ``spec``'s common fields through a
    degraded impl: strategy knobs are dropped — they belong to the
    tier that just failed, and the fallback must not inherit, say, a
    Pallas tile shape as a blocked block size. The stale-graph reuse
    knobs drop too: a degraded engine rebuilds every graph — trading
    speed is the ladder's contract, trading graph freshness is not."""
    return DigcSpec(
        impl=impl, k=spec.k, dilation=spec.dilation, causal=spec.causal
    )


def promote_batch(x, y=None, pos_bias=None):
    """Lift (N, D) [+ (N, M) pos_bias] to B=1; pass (B, N, D) through.

    Returns (x3, y3, pos3, squeeze) where squeeze records whether the
    caller should drop the batch axis from the outputs.
    """
    import jax.numpy as jnp

    if x.ndim not in (2, 3):
        raise ValueError(f"DIGC nodes must be (N, D) or (B, N, D); got {x.shape}")
    squeeze = x.ndim == 2
    x3 = x[None] if squeeze else x
    if y is None:
        y3 = x3
    else:
        if y.ndim not in (2, 3):
            raise ValueError(
                f"DIGC co-nodes must be (M, D) or (B, M, D); got {y.shape}"
            )
        y3 = y[None] if y.ndim == 2 else y
    if y3.shape[0] != x3.shape[0]:
        raise ValueError(
            f"batch mismatch: nodes {x3.shape[0]} vs co-nodes {y3.shape[0]}"
        )
    p3 = None
    if pos_bias is not None:
        if pos_bias.ndim not in (2, 3):
            raise ValueError(
                f"pos_bias must be (N, M) or (B, N, M); got {pos_bias.shape}"
            )
        p3 = pos_bias[None] if pos_bias.ndim == 2 else pos_bias
        n, m = x3.shape[1], y3.shape[1]
        if p3.shape[1:] != (n, m):
            raise ValueError(
                f"pos_bias shape {pos_bias.shape} does not match "
                f"N={n} nodes x M={m} co-nodes"
            )
        if p3.shape[0] not in (1, x3.shape[0]):
            raise ValueError(
                f"pos_bias batch {p3.shape[0]} does not match nodes batch "
                f"{x3.shape[0]} (or 1 for shared)"
            )
        if p3.shape[0] != x3.shape[0]:
            p3 = jnp.broadcast_to(p3, (x3.shape[0],) + p3.shape[1:])
    return x3, y3, p3, squeeze
