"""jax version-compat shims (the container pins an older jax than the
newest APIs some modules were written against).

  * ``shard_map``       — jax >= 0.5 exposes ``jax.shard_map(check_vma=)``;
    older versions have ``jax.experimental.shard_map.shard_map(check_rep=)``.
  * ``CompilerParams``  — jax >= 0.5 renamed ``pltpu.TPUCompilerParams``
    to ``pltpu.CompilerParams``.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, *, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
