"""Dynamic Image Graph Construction (DIGC).

The paper's Algorithm 1: given node features X (N, D), co-node features
Y (M, D), optional relative positional bias P (N, M), a neighbor count k
and dilation d, return for every node the indices of its dilated
k-nearest co-nodes under squared euclidean distance:

    D_XY = ||x||^2 - 2 X Y^T + ||y||^2  (+ P)
    I'   = argsort(D_XY)[:, :k*d]
    I    = I'[:, ::d]

Three implementation tiers (see DESIGN.md §3):

  * ``digc_reference``   -- Algorithm 1 verbatim. Materializes the full
    N x M distance matrix (this is the paper's CPU/GPU baseline and the
    oracle for every test).
  * ``digc_blocked``     -- the paper's streaming insight at the XLA
    level: co-nodes are processed in uniform blocks; a running, sorted
    top-(k*d) candidate list is merged with each block (LSM+GMM as an
    online reduction). Live memory is O(N * block_m), never O(N * M).
  * ``digc_pallas``      -- the fused Pallas TPU kernel
    (``repro.kernels.digc_topk``): distance + selection in one pass with
    the running candidate buffer resident in VMEM.

``digc`` is the public entry point; ``impl`` selects the tier.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# Large-but-finite sentinel: inf would produce nan under (inf - inf) when a
# positional bias is added to a padded lane.
BIG = float(1e30)

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array, pos_bias: Optional[Array] = None) -> Array:
    """Full N x M squared-euclidean distance matrix (Algorithm 1 lines 3-7)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    inner = -2.0 * (x @ y.T)
    sq_x = jnp.sum(x * x, axis=-1, keepdims=True)  # (N, 1)
    sq_y = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, M)
    d = inner + sq_x + sq_y
    if pos_bias is not None:
        d = d + pos_bias
    return d


def dilate(idx_sorted: Array, dilation: int) -> Array:
    """Neighbor Selection Module: every d-th entry of the top k*d list."""
    if dilation == 1:
        return idx_sorted
    return idx_sorted[..., ::dilation]


def digc_reference(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
):
    """Algorithm 1, verbatim (materializes the N x M distance matrix).

    Entries reported with distance >= BIG/2 are invalid placeholders
    (causally excluded / padding); their indices are unspecified and
    consumers must mask on the distance. This matches the blocked and
    Pallas tiers.
    """
    if y is None:
        y = x
    kd = k * dilation
    m = y.shape[0]
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    d_xy = pairwise_sq_dists(x, y, pos_bias)
    if causal:
        n = x.shape[0]
        keep = jnp.arange(m)[None, :] <= jnp.arange(n)[:, None]
        d_xy = jnp.where(keep, d_xy, BIG)
    neg_top, idx = lax.top_k(-d_xy, kd)  # sorted ascending by distance
    idx = dilate(idx.astype(jnp.int32), dilation)
    if return_dists:
        return idx, dilate(-neg_top, dilation)
    return idx


def merge_topk(
    run_d: Array, run_i: Array, blk_d: Array, blk_i: Array, kd: int
) -> tuple[Array, Array]:
    """Merge a running sorted top-kd list with a new candidate block.

    This is the TPU analogue of the paper's GMM k-way heap merge: the
    running list plays the role of the heap contents, the block plays the
    role of a freshly-sorted local stream. Output is sorted ascending.

    run_d/run_i: (N, kd); blk_d/blk_i: (N, B). Returns new (N, kd) pair.
    """
    cand_d = jnp.concatenate([run_d, blk_d], axis=-1)
    cand_i = jnp.concatenate([run_i, blk_i], axis=-1)
    neg_top, sel = lax.top_k(-cand_d, kd)
    new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
    return -neg_top, new_i


def digc_blocked(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    block_m: int = 256,
    return_dists: bool = False,
    causal: bool = False,
):
    """Streaming DIGC: scan over co-node blocks with a running top-kd merge.

    Paper-faithful dataflow (DCM block -> local candidates -> global
    merge -> dilated selection) expressed in pure XLA so it runs on any
    backend; the Pallas kernel implements the same dataflow fused.
    """
    if y is None:
        y = x
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    n, feat = x.shape
    m = y.shape[0]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    block_m = min(block_m, _ceil_to(m, 1))
    m_pad = _ceil_to(m, block_m)
    nb = m_pad // block_m

    y_p = jnp.pad(y, ((0, m_pad - m), (0, 0)))
    sq_y = jnp.sum(y_p * y_p, axis=-1)
    # Mask padded co-nodes out via their squared norm term.
    sq_y = jnp.where(jnp.arange(m_pad) < m, sq_y, BIG)
    y_blocks = y_p.reshape(nb, block_m, feat)
    sqy_blocks = sq_y.reshape(nb, block_m)
    offsets = jnp.arange(nb, dtype=jnp.int32) * block_m

    if pos_bias is not None:
        p_pad = jnp.pad(pos_bias.astype(jnp.float32), ((0, 0), (0, m_pad - m)))
        p_blocks = jnp.transpose(p_pad.reshape(n, nb, block_m), (1, 0, 2))
    else:
        p_blocks = None

    sq_x = jnp.sum(x * x, axis=-1, keepdims=True)  # (N, 1)

    def step(carry, blk):
        run_d, run_i = carry
        if p_blocks is None:
            y_blk, sqy_blk, off = blk
            p_blk = None
        else:
            y_blk, sqy_blk, off, p_blk = blk
        d_blk = sq_x - 2.0 * (x @ y_blk.T) + sqy_blk[None, :]
        if p_blk is not None:
            d_blk = d_blk + p_blk
        blk_i = off + lax.broadcasted_iota(jnp.int32, d_blk.shape, 1)
        if causal:
            rows = lax.broadcasted_iota(jnp.int32, d_blk.shape, 0)
            d_blk = jnp.where(blk_i <= rows, d_blk, BIG)
        run_d, run_i = merge_topk(run_d, run_i, d_blk, blk_i, kd)
        return (run_d, run_i), None

    init = (
        jnp.full((n, kd), BIG, jnp.float32),
        jnp.zeros((n, kd), jnp.int32),
    )
    xs = (y_blocks, sqy_blocks, offsets)
    if p_blocks is not None:
        xs = xs + (p_blocks,)
    (run_d, run_i), _ = lax.scan(step, init, xs)

    idx = dilate(run_i, dilation)
    if return_dists:
        return idx, dilate(run_d, dilation)
    return idx


def digc(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    impl: str = "blocked",
    return_dists: bool = False,
    causal: bool = False,
    **kwargs,
):
    """Public DIGC API. ``impl``: reference | blocked | pallas | ring."""
    if impl == "reference":
        return digc_reference(
            x,
            y,
            k=k,
            dilation=dilation,
            pos_bias=pos_bias,
            return_dists=return_dists,
            causal=causal,
        )
    if impl == "blocked":
        return digc_blocked(
            x,
            y,
            k=k,
            dilation=dilation,
            pos_bias=pos_bias,
            return_dists=return_dists,
            causal=causal,
            **kwargs,
        )
    if impl == "pallas":
        from repro.kernels import ops as _kops

        return _kops.digc_topk(
            x,
            y if y is not None else x,
            k=k,
            dilation=dilation,
            pos_bias=pos_bias,
            return_dists=return_dists,
            causal=causal,
            **kwargs,
        )
    if impl == "ring":
        from repro.core import ring as _ring

        return _ring.ring_digc(
            x,
            y if y is not None else x,
            k=k,
            dilation=dilation,
            return_dists=return_dists,
            **kwargs,
        )
    raise ValueError(f"unknown DIGC impl: {impl!r}")


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("k", "dilation"))
def digc_blocked_jit(x, y, k: int, dilation: int = 1):
    return digc_blocked(x, y, k=k, dilation=dilation)
