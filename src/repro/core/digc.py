"""Dynamic Image Graph Construction (DIGC).

The paper's Algorithm 1: given node features X (N, D), co-node features
Y (M, D), optional relative positional bias P (N, M), a neighbor count k
and dilation d, return for every node the indices of its dilated
k-nearest co-nodes under squared euclidean distance:

    D_XY = ||x||^2 - 2 X Y^T + ||y||^2  (+ P)
    I'   = argsort(D_XY)[:, :k*d]
    I    = I'[:, ::d]

Every implementation is **batched-first**: inputs may be (B, N, D) /
(B, M, D) (a batch of images, the serving case) or (N, D) / (M, D)
(promoted to B=1, outputs squeezed back).

Implementation tiers (see DESIGN.md §3):

  * ``digc_reference``   -- Algorithm 1 verbatim. Materializes the full
    B x N x M distance matrix (this is the paper's CPU/GPU baseline and
    the oracle for every test).
  * ``digc_blocked``     -- the paper's streaming insight at the XLA
    level: co-nodes are processed in uniform blocks; a running, sorted
    top-(k*d) candidate list is merged with each block (LSM+GMM as an
    online reduction). Live memory is O(B * N * block_m), never
    O(B * N * M).
  * ``digc_pallas``      -- the fused Pallas TPU kernel
    (``repro.kernels.digc_topk``): distance + selection in one pass with
    the running candidate buffer resident in VMEM and batch as the
    leading grid dimension.

``digc`` is the public entry point: a thin lookup into the GraphBuilder
registry (``repro.core.builder``, DESIGN.md §4). Select a tier with a
``DigcSpec`` (``digc(x, y, spec=...)``) or the legacy ``impl=`` keyword.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.builder import (
    DigcSpec,
    GraphBuilder,
    get_builder,
    promote_batch,
    register,
    resolve_spec,
)

# Large-but-finite sentinel: inf would produce nan under (inf - inf) when a
# positional bias is added to a padded lane.
BIG = float(1e30)

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array, pos_bias: Optional[Array] = None) -> Array:
    """Squared-euclidean distance matrix (Algorithm 1 lines 3-7).

    x (..., N, D), y (..., M, D) -> (..., N, M); leading batch dims
    broadcast through the einsum.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    inner = -2.0 * jnp.einsum("...nd,...md->...nm", x, y)
    sq_x = jnp.sum(x * x, axis=-1)[..., :, None]
    sq_y = jnp.sum(y * y, axis=-1)[..., None, :]
    d = inner + sq_x + sq_y
    if pos_bias is not None:
        d = d + pos_bias
    return d


def dilate(idx_sorted: Array, dilation: int) -> Array:
    """Neighbor Selection Module: every d-th entry of the top k*d list."""
    if dilation == 1:
        return idx_sorted
    return idx_sorted[..., ::dilation]


def digc_reference(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
):
    """Algorithm 1, verbatim (materializes the full distance matrix).

    Accepts (N, D) or (B, N, D). Entries reported with distance >=
    BIG/2 are invalid placeholders (causally excluded / padding); their
    indices are unspecified and consumers must mask on the distance.
    This matches the blocked and Pallas tiers.
    """
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    kd = k * dilation
    _, n, _ = x3.shape
    m = y3.shape[1]
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    d_xy = pairwise_sq_dists(x3, y3, p3)
    if causal:
        keep = jnp.arange(m)[None, :] <= jnp.arange(n)[:, None]
        d_xy = jnp.where(keep[None], d_xy, BIG)
    neg_top, idx = lax.top_k(-d_xy, kd)  # sorted ascending by distance
    idx = dilate(idx.astype(jnp.int32), dilation)
    dist = dilate(-neg_top, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def merge_topk(
    run_d: Array, run_i: Array, blk_d: Array, blk_i: Array, kd: int
) -> tuple[Array, Array]:
    """Merge a running sorted top-kd list with a new candidate block.

    This is the TPU analogue of the paper's GMM k-way heap merge: the
    running list plays the role of the heap contents, the block plays the
    role of a freshly-sorted local stream. Output is sorted ascending.

    run_d/run_i: (..., N, kd); blk_d/blk_i: (..., N, B). Returns the new
    (..., N, kd) pair; leading batch dims pass through.
    """
    cand_d = jnp.concatenate([run_d, blk_d], axis=-1)
    cand_i = jnp.concatenate([run_i, blk_i], axis=-1)
    neg_top, sel = lax.top_k(-cand_d, kd)
    new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
    return -neg_top, new_i


def digc_blocked(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    block_m: int = 256,
    return_dists: bool = False,
    causal: bool = False,
):
    """Streaming DIGC: scan over co-node blocks with a running top-kd merge.

    Paper-faithful dataflow (DCM block -> local candidates -> global
    merge -> dilated selection) expressed in pure XLA so it runs on any
    backend; the Pallas kernel implements the same dataflow fused. The
    whole batch advances through each co-node block together, so live
    memory is O(B * N * block_m).
    """
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    x3 = x3.astype(jnp.float32)
    y3 = y3.astype(jnp.float32)
    b, n, feat = x3.shape
    m = y3.shape[1]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    block_m = min(block_m, m)
    m_pad = _ceil_to(m, block_m)
    nb = m_pad // block_m

    y_p = jnp.pad(y3, ((0, 0), (0, m_pad - m), (0, 0)))
    sq_y = jnp.sum(y_p * y_p, axis=-1)  # (B, m_pad)
    # Mask padded co-nodes out via their squared norm term.
    sq_y = jnp.where(jnp.arange(m_pad)[None, :] < m, sq_y, BIG)
    y_blocks = y_p.reshape(b, nb, block_m, feat).transpose(1, 0, 2, 3)
    sqy_blocks = sq_y.reshape(b, nb, block_m).transpose(1, 0, 2)
    offsets = jnp.arange(nb, dtype=jnp.int32) * block_m

    if p3 is not None:
        p_pad = jnp.pad(p3.astype(jnp.float32), ((0, 0), (0, 0), (0, m_pad - m)))
        p_blocks = p_pad.reshape(b, n, nb, block_m).transpose(2, 0, 1, 3)
    else:
        p_blocks = None

    sq_x = jnp.sum(x3 * x3, axis=-1)[..., None]  # (B, N, 1)

    def step(carry, blk):
        run_d, run_i = carry
        if p_blocks is None:
            y_blk, sqy_blk, off = blk
            p_blk = None
        else:
            y_blk, sqy_blk, off, p_blk = blk
        d_blk = (
            sq_x
            - 2.0 * jnp.einsum("bnd,bmd->bnm", x3, y_blk)
            + sqy_blk[:, None, :]
        )
        if p_blk is not None:
            d_blk = d_blk + p_blk
        blk_i = off + lax.broadcasted_iota(jnp.int32, d_blk.shape, 2)
        if causal:
            rows = lax.broadcasted_iota(jnp.int32, d_blk.shape, 1)
            d_blk = jnp.where(blk_i <= rows, d_blk, BIG)
        run_d, run_i = merge_topk(run_d, run_i, d_blk, blk_i, kd)
        return (run_d, run_i), None

    init = (
        jnp.full((b, n, kd), BIG, jnp.float32),
        jnp.zeros((b, n, kd), jnp.int32),
    )
    xs = (y_blocks, sqy_blocks, offsets)
    if p_blocks is not None:
        xs = xs + (p_blocks,)
    (run_d, run_i), _ = lax.scan(step, init, xs)

    idx = dilate(run_i, dilation)
    dist = dilate(run_d, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def digc(
    x: Array,
    y: Optional[Array] = None,
    *,
    spec: Optional[DigcSpec] = None,
    k: Optional[int] = None,
    dilation: Optional[int] = None,
    impl: Optional[str] = None,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: Optional[bool] = None,
    **knobs,
):
    """Public DIGC API: a thin GraphBuilder-registry lookup.

    Either pass a full ``spec=DigcSpec(...)`` or the legacy keywords
    (``k``, ``dilation``, ``impl``, plus builder knobs). Unknown knobs
    for the selected builder raise instead of being silently dropped.
    Accepts (N, D) or (B, N, D) nodes; outputs match the input rank.
    ``y=None`` is the self-graph spelling — builders that distinguish it
    (axial) see None; passing x explicitly as y counts as external
    co-nodes (so eager and jitted calls agree).
    """
    spec = resolve_spec(
        spec, impl=impl, k=k, dilation=dilation, causal=causal, **knobs
    )
    builder = get_builder(spec.impl)
    builder.validate(spec, has_pos_bias=pos_bias is not None)
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    idx, dist = builder.build(x3, None if y is None else y3, p3, spec)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("k", "dilation"))
def digc_blocked_jit(x, y, k: int, dilation: int = 1):
    return digc_blocked(x, y, k=k, dilation=dilation)


# --------------------------------------------------------------------------
# Registry entries (DESIGN.md §4). Build fns take batched (B, N, D) /
# (B, M, D) / (B, N, M) and return ((B, N, k) idx, (B, N, k) dist).


def _build_reference(x, y, pos_bias, spec: DigcSpec):
    return digc_reference(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
    )


def _build_blocked(x, y, pos_bias, spec: DigcSpec):
    return digc_blocked(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
        block_m=spec.block_m if spec.block_m is not None else 256,
    )


register(GraphBuilder(
    name="reference",
    build=_build_reference,
    knobs=frozenset(),
    exact=True,
    supports_pos_bias=True,
    supports_causal=True,
    doc="Algorithm 1 verbatim; full distance matrix (oracle tier)",
))

register(GraphBuilder(
    name="blocked",
    build=_build_blocked,
    knobs=frozenset({"block_m"}),
    exact=True,
    supports_pos_bias=True,
    supports_causal=True,
    doc="streaming XLA tier: co-node blocks + running top-kd merge",
))
