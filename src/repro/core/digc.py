"""Dynamic Image Graph Construction (DIGC).

The paper's Algorithm 1: given node features X (N, D), co-node features
Y (M, D), optional relative positional bias P (N, M), a neighbor count k
and dilation d, return for every node the indices of its dilated
k-nearest co-nodes under squared euclidean distance:

    D_XY = ||x||^2 - 2 X Y^T + ||y||^2  (+ P)
    I'   = argsort(D_XY)[:, :k*d]
    I    = I'[:, ::d]

Every implementation is **batched-first**: inputs may be (B, N, D) /
(B, M, D) (a batch of images, the serving case) or (N, D) / (M, D)
(promoted to B=1, outputs squeezed back).

Implementation tiers (see DESIGN.md §3):

  * ``digc_reference``   -- Algorithm 1 verbatim. Materializes the full
    B x N x M distance matrix (this is the paper's CPU/GPU baseline and
    the oracle for every test).
  * ``digc_blocked``     -- the paper's streaming insight at the XLA
    level, routed through the unified engine (``repro.core.engine``,
    DESIGN.md §5): a two-level (block_n x block_m) tile grid with a
    pluggable LSM/GMM merge (exact grouped selection by default). Live
    memory is O(B * block_n * block_m), never O(B * N * M).
  * ``digc_pallas``      -- the fused Pallas TPU kernel
    (``repro.kernels.digc_topk``): distance + selection in one pass with
    the running candidate buffer resident in VMEM and batch as the
    leading grid dimension.

A fourth, distributed tier (``repro.core.ring``, DESIGN.md §10) runs
the same contract mesh-sharded: co-node shards rotate a device ring,
the whole batch rides one shard_map program, and a ``DigcState`` entry
carries the sharded co-node norms across requests.

``digc`` is the public entry point: a thin lookup into the GraphBuilder
registry (``repro.core.builder``, DESIGN.md §4). Select a tier with a
``DigcSpec`` (``digc(x, y, spec=...)``) or the legacy ``impl=`` keyword.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

import dataclasses

from repro.core.builder import (
    REUSE_KNOBS,
    DigcSpec,
    GraphBuilder,
    get_builder,
    promote_batch,
    register,
    resolve_spec,
    reuse_params,
)

# Large-but-finite sentinel: inf would produce nan under (inf - inf) when a
# positional bias is added to a padded lane.
BIG = float(1e30)

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array, pos_bias: Optional[Array] = None) -> Array:
    """Squared-euclidean distance matrix (Algorithm 1 lines 3-7).

    x (..., N, D), y (..., M, D) -> (..., N, M); leading batch dims
    broadcast through the einsum.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    inner = -2.0 * jnp.einsum("...nd,...md->...nm", x, y)
    sq_x = jnp.sum(x * x, axis=-1)[..., :, None]
    sq_y = jnp.sum(y * y, axis=-1)[..., None, :]
    d = inner + sq_x + sq_y
    if pos_bias is not None:
        d = d + pos_bias
    return d


def dilate(idx_sorted: Array, dilation: int) -> Array:
    """Neighbor Selection Module: every d-th entry of the top k*d list."""
    if dilation == 1:
        return idx_sorted
    return idx_sorted[..., ::dilation]


def digc_reference(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
    m_valid: Optional[Array] = None,
):
    """Algorithm 1, verbatim (materializes the full distance matrix).

    Accepts (N, D) or (B, N, D). Entries reported with distance >=
    BIG/2 are invalid placeholders (causally excluded / padding); their
    indices are unspecified and consumers must mask on the distance.
    This matches the blocked and Pallas tiers. ``m_valid`` ((M,) or
    (B, M) bool) BIG-masks pad co-node columns — the ring tier's pad
    idiom, so live rows' top-k is exactly the top-k over live co-nodes.
    """
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    kd = k * dilation
    _, n, _ = x3.shape
    m = y3.shape[1]
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    d_xy = pairwise_sq_dists(x3, y3, p3)
    if m_valid is not None:
        mask = jnp.asarray(m_valid, bool)
        mask = mask[None, None, :] if mask.ndim == 1 else mask[:, None, :]
        d_xy = jnp.where(mask, d_xy, BIG)
    if causal:
        keep = jnp.arange(m)[None, :] <= jnp.arange(n)[:, None]
        d_xy = jnp.where(keep[None], d_xy, BIG)
    neg_top, idx = lax.top_k(-d_xy, kd)  # sorted ascending by distance
    idx = dilate(idx.astype(jnp.int32), dilation)
    dist = dilate(-neg_top, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def merge_topk(
    run_d: Array, run_i: Array, blk_d: Array, blk_i: Array, kd: int
) -> tuple[Array, Array]:
    """Merge a running sorted top-kd list with a new candidate block.

    This is the TPU analogue of the paper's GMM k-way heap merge: the
    running list plays the role of the heap contents, the block plays the
    role of a freshly-sorted local stream. Output is sorted ascending.

    run_d/run_i: (..., N, kd); blk_d/blk_i: (..., N, B). Returns the new
    (..., N, kd) pair; leading batch dims pass through.
    """
    cand_d = jnp.concatenate([run_d, blk_d], axis=-1)
    cand_i = jnp.concatenate([run_i, blk_i], axis=-1)
    neg_top, sel = lax.top_k(-cand_d, kd)
    new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
    return -neg_top, new_i


def digc_blocked(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    block_m: int = 256,
    block_n: Optional[int] = None,
    merge: Optional[str] = None,
    fuse_norms: bool = False,
    mxu_bf16: bool = False,
    sq_y: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
    group_w: Optional[int] = None,
    m_valid: Optional[Array] = None,
):
    """Streaming DIGC through the unified engine (``core/engine.py``).

    Paper-faithful dataflow (DCM tile -> local selection -> global
    merge -> dilated selection) expressed in pure XLA so it runs on any
    backend; the Pallas kernel implements the same dataflow fused.
    Two-level tiling: the whole batch advances through each
    (block_n x block_m) tile together, so live memory is
    O(B * block_n * block_m) — never O(B * N * M). ``merge`` selects
    the LSM/GMM realization ("select" exact grouped extraction,
    "topk" concat+top_k, "packed" tie-tolerant packed keys);
    ``fuse_norms`` folds the norm terms into the distance matmul
    (tie-tolerant), ``mxu_bf16`` runs the contraction in bf16.
    """
    from repro.core.engine import stream_topk

    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    kd = k * dilation
    dist, idx = stream_topk(
        x3,
        None if y is None else y3,
        p3,
        kd=kd,
        block_m=block_m,
        block_n=block_n,
        merge=merge,
        fuse_norms=fuse_norms,
        mxu_bf16=mxu_bf16,
        causal=causal,
        sq_y=sq_y,
        group_w=group_w,
        m_valid=m_valid,
    )
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


# --------------------------------------------------------------------------
# Drift-gated stale-graph reuse (DESIGN.md §12).
#
# The graph index is a cached, versioned artifact living in the
# DigcStateEntry (graph_idx/graph_dist + the graph_snap drift snapshot
# and graph_age staleness counter). The gate below is impl-agnostic: it
# wraps any supports_state builder's build, so every stateful tier
# (blocked, cluster, ring) serves through the same policy machinery.
# Everything is a runtime lax.cond inside the one donated jit program —
# warm serving stays a single dispatch — and per batch row, so
# co-batched tenants gate independently.


def drift_stat(x: Array) -> Array:
    """The cheap per-row feature statistic the reuse gate compares:
    mean |x|^2 over nodes and channels, (B, N, D) -> (B,) float32.
    Vision GNN's observation that patch features evolve smoothly across
    layers is what makes this scalar a usable drift proxy; the
    recall-vs-drift_tau bench rows measure how far it can be trusted."""
    return jnp.mean(jnp.square(x.astype(jnp.float32)), axis=(1, 2))


def _mix_rows(sel_row, kept, built):
    """Per-row select between two pytree-aligned buffers: ``sel_row``
    (B,) True keeps ``kept``'s row. None passes ``built`` through."""
    if kept is None or built is None:
        return built
    sel = sel_row.reshape(sel_row.shape + (1,) * (built.ndim - 1))
    return jnp.where(sel, kept, built)


def _stateful_build(builder, x3, y_arg, p3, spec, entry, m_valid=None):
    kw = {} if m_valid is None else {"m_valid": m_valid}
    idx, dist, new_entry = builder.build(x3, y_arg, p3, spec,
                                         state_entry=entry, **kw)
    return idx, dist, new_entry


def _reuse_build(builder, x3, y_arg, p3, spec, entry, *, reuse_first,
                 m_valid=None):
    """The drift-gated reuse path around a stateful builder's build.

    Returns (idx, dist, new_entry). Falls back to the plain stateful
    build (bit-identical to ``reuse="off"``) whenever the policy cannot
    engage *statically*: no cached-graph buffers in the entry, a cached
    shape from another workload, or ``drift_tau == 0`` (the documented
    "reuse disabled" verification setting — a zero threshold admits no
    drift, including none at all).
    """
    policy, tau, max_stale = reuse_params(spec)
    b, n, _ = x3.shape
    if (
        policy is None
        or entry.graph_idx is None
        or entry.graph_idx.shape != (b, n, spec.k)
    ):
        return _stateful_build(builder, x3, y_arg, p3, spec, entry, m_valid)
    if policy in ("layer", "tick") and tau == 0.0:
        return _stateful_build(builder, x3, y_arg, p3, spec, entry, m_valid)

    valid = (
        entry.row_warm if entry.row_step is not None
        else jnp.broadcast_to(entry.warm, (b,))
    )
    stat = drift_stat(x3)

    if policy == "overlap":
        return _overlap_build(
            builder, x3, y_arg, p3, spec, entry, valid=valid, stat=stat,
            m_valid=m_valid,
        )

    drift = jnp.abs(stat - entry.graph_snap) / jnp.maximum(
        jnp.abs(entry.graph_snap), 1e-9
    )
    if policy == "tick" and not reuse_first:
        # Within a tick, layers after the stage's gated first call reuse
        # whatever that call left (fresh or reused) unconditionally and
        # without aging — the graph is per-tick in this policy, so
        # staleness is counted in ticks, not layers.
        reuse_row = valid
        age_inc = 0
    else:
        reuse_row = valid & (entry.graph_age < max_stale) & (drift < tau)
        age_inc = 1

    def serve_cached():
        return (
            entry.graph_idx,
            entry.graph_dist,
            entry.bump(graph_age=entry.graph_age + age_inc),
        )

    def rebuild_mixed():
        f_idx, f_dist, built = _stateful_build(
            builder, x3, y_arg, p3, spec, entry, m_valid
        )
        idx = _mix_rows(reuse_row, entry.graph_idx, f_idx)
        dist = _mix_rows(reuse_row, entry.graph_dist, f_dist)
        # Per-row independence: a reused row must carry exactly the
        # builder state its solo replay (which never built) would —
        # keep its centroids/norms, not the mixed batch's rebuild.
        return idx, dist, dataclasses.replace(
            built,
            centroids=_mix_rows(reuse_row, entry.centroids, built.centroids),
            sq_y=_mix_rows(reuse_row, entry.sq_y, built.sq_y),
            graph_idx=idx,
            graph_dist=dist,
            graph_snap=jnp.where(reuse_row, entry.graph_snap, stat),
            graph_age=jnp.where(
                reuse_row, entry.graph_age + age_inc, jnp.int32(0)
            ),
        )

    # All-reuse is the serving steady state: the cond's true branch
    # touches no distance compute at all — the whole build is skipped,
    # which is where the warm per-tick speedup comes from.
    return lax.cond(jnp.all(reuse_row), serve_cached, rebuild_mixed)


def _overlap_build(builder, x3, y_arg, p3, spec, entry, *, valid, stat,
                   m_valid=None):
    """Double-buffered overlap (DESIGN.md §12): serve the cached
    (one-call-stale) graph unconditionally for warm rows, and issue the
    refresh build so that the *served* outputs never depend on it — the
    fresh graph flows only into the returned entry (next call's cache),
    so XLA's scheduler is free to run it concurrently with the MRConv/
    FFN compute consuming the cached graph. Cold rows take a build
    inside the mixed branch (a second build that tick — cold only)."""

    def serve_cached():
        return entry.graph_idx, entry.graph_dist

    def serve_mixed():
        f_idx, f_dist, _ = _stateful_build(
            builder, x3, y_arg, p3, spec, entry, m_valid
        )
        return (
            _mix_rows(valid, entry.graph_idx, f_idx),
            _mix_rows(valid, entry.graph_dist, f_dist),
        )

    idx, dist = lax.cond(jnp.all(valid), serve_cached, serve_mixed)
    # The refresh build: data-independent of (idx, dist) by
    # construction — it is captured only by the state update.
    f_idx, f_dist, built = _stateful_build(
        builder, x3, y_arg, p3, spec, entry, m_valid
    )
    new_entry = dataclasses.replace(
        built,
        graph_idx=f_idx,
        graph_dist=f_dist,
        graph_snap=stat,
        graph_age=jnp.zeros_like(entry.graph_age),
    )
    return idx, dist, new_entry


def digc(
    x: Array,
    y: Optional[Array] = None,
    *,
    spec: Optional[DigcSpec] = None,
    k: Optional[int] = None,
    dilation: Optional[int] = None,
    impl: Optional[str] = None,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: Optional[bool] = None,
    cache=None,
    cache_key=None,
    state=None,
    state_key=None,
    reuse_first: bool = True,
    fault_plan=None,
    m_valid: Optional[Array] = None,
    **knobs,
):
    """Public DIGC API: a thin GraphBuilder-registry lookup.

    Either pass a full ``spec=DigcSpec(...)`` or the legacy keywords
    (``k``, ``dilation``, ``impl``, plus builder knobs). Unknown knobs
    for the selected builder raise instead of being silently dropped.
    Accepts (N, D) or (B, N, D) nodes; outputs match the input rank.
    ``y=None`` is the self-graph spelling — builders that distinguish it
    (axial) see None; passing x explicitly as y counts as external
    co-nodes (so eager and jitted calls agree).

    ``state``/``state_key`` (a functional ``repro.core.state.DigcState``
    pytree plus the key naming this call's entry) select the
    **functional form**: the call returns ``(idx[, dist], new_state)``
    and works *under jit* — stateful builders (cluster centroids,
    frozen-gallery norms) read their entry's buffers gated on its step
    counter and return an updated entry; builders without state (or a
    state with no entry for the key) pass the state through unchanged.
    When the spec carries a ``reuse`` policy and the entry carries
    cached-graph buffers, the call serves through the drift gate
    (DESIGN.md §12); ``reuse_first=False`` marks a non-first call of
    the same forward pass (the ``"tick"`` policy reuses those
    unconditionally instead of re-gating).

    ``cache``/``cache_key`` (a ``repro.core.engine.DigcCache`` plus a
    caller-chosen identity for the reusable state, e.g. a model layer
    name or a gallery version) are the legacy **eager shim** for the
    same reuse: host-side, bypassed entirely under tracing. Mutually
    exclusive with ``state``.

    ``fault_plan`` (a ``repro.core.faults.FaultPlan``) is the
    fault-injection hook (DESIGN.md §11): when set, the node features
    pass through the plan's ``digc.x`` site before construction —
    zero-overhead and a no-op when ``None``, and host-side only
    (bypassed under tracing, like the eager cache).

    ``m_valid`` ((M,) or (B, M) bool) marks live co-nodes; pad columns
    are BIG-norm-masked so they can never enter a live row's top-k
    (the multi-resolution pad-node contract, DESIGN.md §13). Raises for
    builders without the ``supports_pad`` capability.
    """
    spec = resolve_spec(
        spec, impl=impl, k=k, dilation=dilation, causal=causal, **knobs
    )
    builder = get_builder(spec.impl)
    builder.validate(spec, has_pos_bias=pos_bias is not None)
    if m_valid is not None and not builder.supports_pad:
        raise ValueError(
            f"DIGC impl {spec.impl!r} does not support pad-node masking "
            f"(m_valid); pad-capable impls: "
            f"{[b.name for b in _pad_capable()]}"
        )
    if fault_plan is not None and not isinstance(x, jax.core.Tracer):
        x = jnp.asarray(fault_plan.fire("digc.x", value=x, impl=spec.impl))
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    y_arg = None if y is None else y3
    if state is not None:
        if cache is not None:
            raise ValueError(
                "digc() takes either functional state= or the legacy "
                "eager cache=, not both"
            )
        entry = state.get(state_key)
        if builder.supports_state and entry is not None:
            # The stale-graph reuse gate (DESIGN.md §12) wraps every
            # stateful builder uniformly; with reuse off it *is* the
            # plain build. ``reuse_first`` marks the first call of a
            # forward pass for this entry (the tick-policy gate point).
            idx, dist, new_entry = _reuse_build(
                builder, x3, y_arg, p3, spec, entry,
                reuse_first=reuse_first, m_valid=m_valid,
            )
            state = state.set(state_key, new_entry)
        else:
            idx, dist = builder.build(x3, y_arg, p3, spec, **_pad_kw(m_valid))
        if squeeze:
            idx, dist = idx[0], dist[0]
        if return_dists:
            return idx, dist, state
        return idx, state
    if cache is not None and builder.supports_cache:
        idx, dist = builder.build(
            x3, y_arg, p3, spec, cache=cache, cache_key=cache_key,
            **_pad_kw(m_valid),
        )
    else:
        idx, dist = builder.build(x3, y_arg, p3, spec, **_pad_kw(m_valid))
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def _pad_kw(m_valid):
    """Keyword dict for a build call: empty when unmasked so builders
    without the ``m_valid`` keyword keep their signatures."""
    return {} if m_valid is None else {"m_valid": m_valid}


def _pad_capable():
    from repro.core.builder import available_impls

    out = []
    for name in available_impls():
        try:
            b = get_builder(name)
        except Exception:
            continue
        if b.supports_pad:
            out.append(b)
    return out


@functools.partial(jax.jit, static_argnames=("k", "dilation"))
def digc_blocked_jit(x, y, k: int, dilation: int = 1):
    return digc_blocked(x, y, k=k, dilation=dilation)


# --------------------------------------------------------------------------
# Registry entries (DESIGN.md §4). Build fns take batched (B, N, D) /
# (B, M, D) / (B, N, M) and return ((B, N, k) idx, (B, N, k) dist).


def _build_reference(x, y, pos_bias, spec: DigcSpec, m_valid=None):
    return digc_reference(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True, m_valid=m_valid,
    )


def _build_blocked(x, y, pos_bias, spec: DigcSpec, state_entry=None,
                   m_valid=None):
    # Exact tier: no implicit cache reads. Per-call norm reuse
    # (self-graph ||x||^2 == ||y||^2) happens inside the engine; a
    # caller serving a *fixed* co-node gallery passes precomputed norms
    # explicitly via digc_blocked(sq_y=cache.norms(gallery_key, y)) or
    # through a functional state entry carrying sq_y — an implicit
    # cache keyed by call-site would silently serve stale norms once
    # the co-node contents change (e.g. per-layer pooled features),
    # corrupting an exact tier.
    sq_y = None
    new_entry = None
    if state_entry is not None:
        new_entry = state_entry.bump()
        if (
            y is not None
            and state_entry.sq_y is not None
            and state_entry.sq_y.shape == y.shape[:-1]
        ):
            # The entry asserts this gallery is frozen (state.py
            # invalidation rules): compute the norms on the cold call
            # only, then carry them — jit-compatible because the cold
            # branch is a lax.cond on the runtime step counter. With
            # per-row counters (multi-tenant serving) the gate is per
            # batch row: warm rows read their carried norms, rows just
            # reset for a new tenant recompute theirs — norms are cheap
            # enough that the mixed batch computes them unconditionally
            # and selects.
            if state_entry.row_step is not None:
                sq_y = jnp.where(
                    state_entry.row_warm[:, None],
                    state_entry.sq_y,
                    jnp.sum(y.astype(jnp.float32) ** 2, axis=-1),
                )
            else:
                sq_y = lax.cond(
                    state_entry.warm,
                    lambda: state_entry.sq_y,
                    lambda: jnp.sum(y.astype(jnp.float32) ** 2, axis=-1),
                )
            new_entry = state_entry.bump(sq_y=sq_y)
    out = digc_blocked(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
        block_m=spec.block_m if spec.block_m is not None else 256,
        block_n=spec.block_n,
        merge=spec.merge,
        fuse_norms=bool(spec.fuse_norms),
        mxu_bf16=bool(spec.mxu_bf16),
        sq_y=sq_y,
        group_w=spec.group_w,
        m_valid=m_valid,
    )
    if state_entry is not None:
        return (*out, new_entry)
    return out


register(GraphBuilder(
    name="reference",
    build=_build_reference,
    knobs=frozenset(),
    exact=True,
    supports_pos_bias=True,
    supports_causal=True,
    supports_pad=True,  # BIG-masked pad co-node columns (m_valid)
    doc="Algorithm 1 verbatim; full distance matrix (oracle tier)",
))

register(GraphBuilder(
    name="blocked",
    build=_build_blocked,
    knobs=frozenset({
        "block_n", "block_m", "merge", "fuse_norms", "mxu_bf16", "group_w",
    }) | REUSE_KNOBS,
    exact=True,  # merge="packed" / fuse_norms / mxu_bf16 opt into tie-tolerance
    supports_pos_bias=True,
    supports_causal=True,
    supports_state=True,  # frozen-gallery norms via DigcState entries
    supports_pad=True,  # BIG-norm pad masking folded into sq_y
    doc="streaming XLA engine: two-level (block_n x block_m) tiling + "
        "pluggable LSM/GMM merge (select | topk | packed)",
))
