"""Dynamic Image Graph Construction (DIGC).

The paper's Algorithm 1: given node features X (N, D), co-node features
Y (M, D), optional relative positional bias P (N, M), a neighbor count k
and dilation d, return for every node the indices of its dilated
k-nearest co-nodes under squared euclidean distance:

    D_XY = ||x||^2 - 2 X Y^T + ||y||^2  (+ P)
    I'   = argsort(D_XY)[:, :k*d]
    I    = I'[:, ::d]

Every implementation is **batched-first**: inputs may be (B, N, D) /
(B, M, D) (a batch of images, the serving case) or (N, D) / (M, D)
(promoted to B=1, outputs squeezed back).

Implementation tiers (see DESIGN.md §3):

  * ``digc_reference``   -- Algorithm 1 verbatim. Materializes the full
    B x N x M distance matrix (this is the paper's CPU/GPU baseline and
    the oracle for every test).
  * ``digc_blocked``     -- the paper's streaming insight at the XLA
    level, routed through the unified engine (``repro.core.engine``,
    DESIGN.md §5): a two-level (block_n x block_m) tile grid with a
    pluggable LSM/GMM merge (exact grouped selection by default). Live
    memory is O(B * block_n * block_m), never O(B * N * M).
  * ``digc_pallas``      -- the fused Pallas TPU kernel
    (``repro.kernels.digc_topk``): distance + selection in one pass with
    the running candidate buffer resident in VMEM and batch as the
    leading grid dimension.

A fourth, distributed tier (``repro.core.ring``, DESIGN.md §10) runs
the same contract mesh-sharded: co-node shards rotate a device ring,
the whole batch rides one shard_map program, and a ``DigcState`` entry
carries the sharded co-node norms across requests.

``digc`` is the public entry point: a thin lookup into the GraphBuilder
registry (``repro.core.builder``, DESIGN.md §4). Select a tier with a
``DigcSpec`` (``digc(x, y, spec=...)``) or the legacy ``impl=`` keyword.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.builder import (
    DigcSpec,
    GraphBuilder,
    get_builder,
    promote_batch,
    register,
    resolve_spec,
)

# Large-but-finite sentinel: inf would produce nan under (inf - inf) when a
# positional bias is added to a padded lane.
BIG = float(1e30)

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array, pos_bias: Optional[Array] = None) -> Array:
    """Squared-euclidean distance matrix (Algorithm 1 lines 3-7).

    x (..., N, D), y (..., M, D) -> (..., N, M); leading batch dims
    broadcast through the einsum.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    inner = -2.0 * jnp.einsum("...nd,...md->...nm", x, y)
    sq_x = jnp.sum(x * x, axis=-1)[..., :, None]
    sq_y = jnp.sum(y * y, axis=-1)[..., None, :]
    d = inner + sq_x + sq_y
    if pos_bias is not None:
        d = d + pos_bias
    return d


def dilate(idx_sorted: Array, dilation: int) -> Array:
    """Neighbor Selection Module: every d-th entry of the top k*d list."""
    if dilation == 1:
        return idx_sorted
    return idx_sorted[..., ::dilation]


def digc_reference(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
):
    """Algorithm 1, verbatim (materializes the full distance matrix).

    Accepts (N, D) or (B, N, D). Entries reported with distance >=
    BIG/2 are invalid placeholders (causally excluded / padding); their
    indices are unspecified and consumers must mask on the distance.
    This matches the blocked and Pallas tiers.
    """
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    kd = k * dilation
    _, n, _ = x3.shape
    m = y3.shape[1]
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    d_xy = pairwise_sq_dists(x3, y3, p3)
    if causal:
        keep = jnp.arange(m)[None, :] <= jnp.arange(n)[:, None]
        d_xy = jnp.where(keep[None], d_xy, BIG)
    neg_top, idx = lax.top_k(-d_xy, kd)  # sorted ascending by distance
    idx = dilate(idx.astype(jnp.int32), dilation)
    dist = dilate(-neg_top, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def merge_topk(
    run_d: Array, run_i: Array, blk_d: Array, blk_i: Array, kd: int
) -> tuple[Array, Array]:
    """Merge a running sorted top-kd list with a new candidate block.

    This is the TPU analogue of the paper's GMM k-way heap merge: the
    running list plays the role of the heap contents, the block plays the
    role of a freshly-sorted local stream. Output is sorted ascending.

    run_d/run_i: (..., N, kd); blk_d/blk_i: (..., N, B). Returns the new
    (..., N, kd) pair; leading batch dims pass through.
    """
    cand_d = jnp.concatenate([run_d, blk_d], axis=-1)
    cand_i = jnp.concatenate([run_i, blk_i], axis=-1)
    neg_top, sel = lax.top_k(-cand_d, kd)
    new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
    return -neg_top, new_i


def digc_blocked(
    x: Array,
    y: Optional[Array] = None,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[Array] = None,
    block_m: int = 256,
    block_n: Optional[int] = None,
    merge: Optional[str] = None,
    fuse_norms: bool = False,
    mxu_bf16: bool = False,
    sq_y: Optional[Array] = None,
    return_dists: bool = False,
    causal: bool = False,
    group_w: Optional[int] = None,
):
    """Streaming DIGC through the unified engine (``core/engine.py``).

    Paper-faithful dataflow (DCM tile -> local selection -> global
    merge -> dilated selection) expressed in pure XLA so it runs on any
    backend; the Pallas kernel implements the same dataflow fused.
    Two-level tiling: the whole batch advances through each
    (block_n x block_m) tile together, so live memory is
    O(B * block_n * block_m) — never O(B * N * M). ``merge`` selects
    the LSM/GMM realization ("select" exact grouped extraction,
    "topk" concat+top_k, "packed" tie-tolerant packed keys);
    ``fuse_norms`` folds the norm terms into the distance matmul
    (tie-tolerant), ``mxu_bf16`` runs the contraction in bf16.
    """
    from repro.core.engine import stream_topk

    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    kd = k * dilation
    dist, idx = stream_topk(
        x3,
        None if y is None else y3,
        p3,
        kd=kd,
        block_m=block_m,
        block_n=block_n,
        merge=merge,
        fuse_norms=fuse_norms,
        mxu_bf16=mxu_bf16,
        causal=causal,
        sq_y=sq_y,
        group_w=group_w,
    )
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def digc(
    x: Array,
    y: Optional[Array] = None,
    *,
    spec: Optional[DigcSpec] = None,
    k: Optional[int] = None,
    dilation: Optional[int] = None,
    impl: Optional[str] = None,
    pos_bias: Optional[Array] = None,
    return_dists: bool = False,
    causal: Optional[bool] = None,
    cache=None,
    cache_key=None,
    state=None,
    state_key=None,
    fault_plan=None,
    **knobs,
):
    """Public DIGC API: a thin GraphBuilder-registry lookup.

    Either pass a full ``spec=DigcSpec(...)`` or the legacy keywords
    (``k``, ``dilation``, ``impl``, plus builder knobs). Unknown knobs
    for the selected builder raise instead of being silently dropped.
    Accepts (N, D) or (B, N, D) nodes; outputs match the input rank.
    ``y=None`` is the self-graph spelling — builders that distinguish it
    (axial) see None; passing x explicitly as y counts as external
    co-nodes (so eager and jitted calls agree).

    ``state``/``state_key`` (a functional ``repro.core.state.DigcState``
    pytree plus the key naming this call's entry) select the
    **functional form**: the call returns ``(idx[, dist], new_state)``
    and works *under jit* — stateful builders (cluster centroids,
    frozen-gallery norms) read their entry's buffers gated on its step
    counter and return an updated entry; builders without state (or a
    state with no entry for the key) pass the state through unchanged.

    ``cache``/``cache_key`` (a ``repro.core.engine.DigcCache`` plus a
    caller-chosen identity for the reusable state, e.g. a model layer
    name or a gallery version) are the legacy **eager shim** for the
    same reuse: host-side, bypassed entirely under tracing. Mutually
    exclusive with ``state``.

    ``fault_plan`` (a ``repro.core.faults.FaultPlan``) is the
    fault-injection hook (DESIGN.md §11): when set, the node features
    pass through the plan's ``digc.x`` site before construction —
    zero-overhead and a no-op when ``None``, and host-side only
    (bypassed under tracing, like the eager cache).
    """
    spec = resolve_spec(
        spec, impl=impl, k=k, dilation=dilation, causal=causal, **knobs
    )
    builder = get_builder(spec.impl)
    builder.validate(spec, has_pos_bias=pos_bias is not None)
    if fault_plan is not None and not isinstance(x, jax.core.Tracer):
        x = jnp.asarray(fault_plan.fire("digc.x", value=x, impl=spec.impl))
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    y_arg = None if y is None else y3
    if state is not None:
        if cache is not None:
            raise ValueError(
                "digc() takes either functional state= or the legacy "
                "eager cache=, not both"
            )
        entry = state.get(state_key)
        if builder.supports_state and entry is not None:
            idx, dist, new_entry = builder.build(
                x3, y_arg, p3, spec, state_entry=entry
            )
            state = state.set(state_key, new_entry)
        else:
            idx, dist = builder.build(x3, y_arg, p3, spec)
        if squeeze:
            idx, dist = idx[0], dist[0]
        if return_dists:
            return idx, dist, state
        return idx, state
    if cache is not None and builder.supports_cache:
        idx, dist = builder.build(
            x3, y_arg, p3, spec, cache=cache, cache_key=cache_key,
        )
    else:
        idx, dist = builder.build(x3, y_arg, p3, spec)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


@functools.partial(jax.jit, static_argnames=("k", "dilation"))
def digc_blocked_jit(x, y, k: int, dilation: int = 1):
    return digc_blocked(x, y, k=k, dilation=dilation)


# --------------------------------------------------------------------------
# Registry entries (DESIGN.md §4). Build fns take batched (B, N, D) /
# (B, M, D) / (B, N, M) and return ((B, N, k) idx, (B, N, k) dist).


def _build_reference(x, y, pos_bias, spec: DigcSpec):
    return digc_reference(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
    )


def _build_blocked(x, y, pos_bias, spec: DigcSpec, state_entry=None):
    # Exact tier: no implicit cache reads. Per-call norm reuse
    # (self-graph ||x||^2 == ||y||^2) happens inside the engine; a
    # caller serving a *fixed* co-node gallery passes precomputed norms
    # explicitly via digc_blocked(sq_y=cache.norms(gallery_key, y)) or
    # through a functional state entry carrying sq_y — an implicit
    # cache keyed by call-site would silently serve stale norms once
    # the co-node contents change (e.g. per-layer pooled features),
    # corrupting an exact tier.
    sq_y = None
    new_entry = None
    if state_entry is not None:
        new_entry = state_entry.bump()
        if (
            y is not None
            and state_entry.sq_y is not None
            and state_entry.sq_y.shape == y.shape[:-1]
        ):
            # The entry asserts this gallery is frozen (state.py
            # invalidation rules): compute the norms on the cold call
            # only, then carry them — jit-compatible because the cold
            # branch is a lax.cond on the runtime step counter. With
            # per-row counters (multi-tenant serving) the gate is per
            # batch row: warm rows read their carried norms, rows just
            # reset for a new tenant recompute theirs — norms are cheap
            # enough that the mixed batch computes them unconditionally
            # and selects.
            if state_entry.row_step is not None:
                sq_y = jnp.where(
                    state_entry.row_warm[:, None],
                    state_entry.sq_y,
                    jnp.sum(y.astype(jnp.float32) ** 2, axis=-1),
                )
            else:
                sq_y = lax.cond(
                    state_entry.warm,
                    lambda: state_entry.sq_y,
                    lambda: jnp.sum(y.astype(jnp.float32) ** 2, axis=-1),
                )
            new_entry = state_entry.bump(sq_y=sq_y)
    out = digc_blocked(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
        block_m=spec.block_m if spec.block_m is not None else 256,
        block_n=spec.block_n,
        merge=spec.merge,
        fuse_norms=bool(spec.fuse_norms),
        mxu_bf16=bool(spec.mxu_bf16),
        sq_y=sq_y,
        group_w=spec.group_w,
    )
    if state_entry is not None:
        return (*out, new_entry)
    return out


register(GraphBuilder(
    name="reference",
    build=_build_reference,
    knobs=frozenset(),
    exact=True,
    supports_pos_bias=True,
    supports_causal=True,
    doc="Algorithm 1 verbatim; full distance matrix (oracle tier)",
))

register(GraphBuilder(
    name="blocked",
    build=_build_blocked,
    knobs=frozenset({
        "block_n", "block_m", "merge", "fuse_norms", "mxu_bf16", "group_w",
    }),
    exact=True,  # merge="packed" / fuse_norms / mxu_bf16 opt into tie-tolerance
    supports_pos_bias=True,
    supports_causal=True,
    supports_state=True,  # frozen-gallery norms via DigcState entries
    doc="streaming XLA engine: two-level (block_n x block_m) tiling + "
        "pluggable LSM/GMM merge (select | topk | packed)",
))
