"""Unified streaming DIGC engine: two-level tiling + pluggable merges.

Every exact XLA tier routes through ``stream_topk``, the engine's one
entry point. It reproduces the paper's module split at the XLA level —
DCM (a distance tile per grid step), LSM (``select_topkd``, a grouped
local selection), GMM (a global merge of per-tile survivors) — with
two structural upgrades over the PR-1 ``digc_blocked``:

* **Two-level tiling.** The query dimension N tiles as well as the
  co-node dimension M (``block_n`` x ``block_m`` grid, outer scan over
  query blocks, inner scan over co-node blocks), so live memory is
  O(B * block_n * block_m) instead of O(B * N * block_m). High
  resolution ViG stages (N = 12544+) stream through a cache-sized
  working set instead of materializing 100+ MB of distance rows.
* **Merge strategies.** The LSM/GMM realization is a knob
  (``DigcSpec.merge``), because the best selection algorithm is
  backend-dependent (measured, see ``core/tuner.py``):

    - ``"select"`` (default) — grouped two-level extraction: each
      distance tile is reshaped to (groups, width<=32) lanes, a
      per-group running min is maintained, and each of the kd rounds
      touches only the winning group (one gather + O(G + w) lane ops)
      instead of the full tile. Exact, ties to the lowest index —
      bit-identical indices to ``lax.top_k``. This replaces the
      concat + ``lax.top_k`` merge whose cost is a scalar selection
      sweep over every candidate (~kd * M per query row, independent
      of block size — why PR-1's block_m sweep was flat).
    - ``"topk"`` — the PR-1 merge (concatenate + ``lax.top_k``), kept
      as the oracle merge and for backends where fused top_k wins.
    - ``"packed"`` — single-int32 packed-key min/mask merge
      (``core/packedkey.py``), the XLA mirror of the Pallas kernel's
      packed path. Tie-tolerant (truncated distances), halves merge
      operand traffic.

* **Norm reuse.** ``||y||^2`` is computed once per call, shared with
  the self-graph ``||x||^2`` when y is None, accepted precomputed via
  ``sq_y=`` (the ``DigcCache`` serving hook), and optionally folded
  into the distance matmul itself (``fuse_norms``: operands augmented
  to [-2x, 1, ||x||^2] / [y, ||y||^2, 1] so the whole distance tile is
  one contraction — no separate broadcast-add passes over the tile).
  ``fuse_norms`` changes fp32 summation order, so it is tie-tolerant
  rather than bit-exact; it is off unless the tuner measures it a win.

``DigcCache`` carries reusable graph-construction state across layers
and requests (co-node norms, cluster centroids/assignments). It is a
host-side cache: it only engages on concrete arrays (never under
tracing, where a cached value would be baked in as a stale constant).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.packedkey import (
    INT_BIG,
    idx_bits_for,
    merge_sorted,
    next_pow2,
    pack_keys,
    topk_keys,
    unpack_keys,
)

BIG = float(1e30)

MERGE_STRATEGIES = ("select", "topk", "packed")

# Default group width for the two-level selection: 32 keeps the
# per-group extracted-lane set in one int32 bitmask word. Widths up to
# 64 are supported with a two-word mask (``DigcSpec.group_w``): fewer
# groups to reduce over per round, at the price of a second mask word
# and a wider per-round gather — whether that wins is workload- and
# backend-dependent (measured in benchmarks/bench_kernel.py).
_SELECT_GROUP_W = 32
_SELECT_GROUP_W_MAX = 64


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# LSM: grouped two-level selection


def select_topkd(d_blk: jax.Array, kd: int, group_w: int = _SELECT_GROUP_W):
    """Exact top-kd of each row of ``d_blk`` (..., N, W), ascending.

    Two-level extraction: columns fold into G = ceil(W / w) groups of
    w <= 64 lanes; a per-group running min (and a bitmask of
    already-extracted lanes, one int32 word per 32 lanes) is
    maintained, so each of the kd rounds reduces over G group-mins plus
    the single winning group — O(G + w) lane ops — instead of sweeping
    all W candidates. Total cost is one full pass (the group-min build)
    plus kd tiny rounds, vs the kd-passes-over-W of ``lax.top_k``-style
    selection.

    Ties resolve to the lowest column (group-major order), matching
    ``lax.top_k``. Returns (dist (..., N, kd), col (..., N, kd)) where
    ``col`` indexes into W; rows with fewer than kd finite candidates
    pad with BIG-distance lanes (indices unspecified, mask on dist).
    """
    *lead, n, W = d_blk.shape
    w = max(1, min(group_w, _SELECT_GROUP_W_MAX, W))
    G = -(-W // w)
    pad = G * w - W
    if pad:
        d_blk = jnp.pad(
            d_blk,
            [(0, 0)] * len(lead) + [(0, 0), (0, pad)],
            constant_values=BIG,
        )
    resh = d_blk.reshape(*lead, n, G, w)
    gmin = jnp.min(resh, axis=-1)  # (..., N, G)
    nw = -(-w // 32)  # mask words per group (1 for w<=32, 2 for w<=64)
    bits = jnp.zeros((*gmin.shape, nw), jnp.int32)
    gcol = lax.broadcasted_iota(jnp.int32, gmin.shape, gmin.ndim - 1)
    wcol = jnp.arange(w, dtype=jnp.int32)
    wword = wcol // 32  # static lane -> mask-word map
    wbit = wcol % 32
    word_iota = jnp.arange(nw, dtype=jnp.int32)
    out_shape = (*lead, n, kd)
    out_col = lax.broadcasted_iota(jnp.int32, out_shape, len(out_shape) - 1)

    def body(t, state):
        gmin, bits, od, oi = state
        gstar = jnp.argmin(gmin, axis=-1)  # (..., N)
        grp = jnp.take_along_axis(resh, gstar[..., None, None], axis=-2)
        grp = jnp.squeeze(grp, -2)  # (..., N, w)
        mask = jnp.take_along_axis(bits, gstar[..., None, None], axis=-2)
        mask = jnp.squeeze(mask, -2)  # (..., N, nw)
        live = jnp.bitwise_and(
            jnp.right_shift(mask[..., wword], wbit), 1
        ) == 0  # (..., N, w)
        grp_m = jnp.where(live, grp, BIG)
        pos = jnp.argmin(grp_m, axis=-1)  # (..., N)
        val = jnp.min(grp_m, axis=-1)
        col = gstar.astype(jnp.int32) * w + pos.astype(jnp.int32)
        od = jnp.where(out_col == t, val[..., None], od)
        oi = jnp.where(out_col == t, col[..., None], oi)
        setbit = jnp.where(
            word_iota == (pos[..., None] // 32),
            jnp.left_shift(jnp.int32(1), pos[..., None] % 32),
            0,
        )  # (..., N, nw)
        newbits = mask | setbit
        hitg = gcol == gstar[..., None]
        bits = jnp.where(hitg[..., None], newbits[..., None, :], bits)
        newmin = jnp.min(jnp.where(wcol == pos[..., None], BIG, grp_m), -1)
        gmin = jnp.where(hitg, newmin[..., None], gmin)
        return gmin, bits, od, oi

    init = (
        gmin,
        bits,
        jnp.full(out_shape, BIG, jnp.float32),
        jnp.zeros(out_shape, jnp.int32),
    )
    _, _, od, oi = lax.fori_loop(0, kd, body, init)
    return od, oi


# ---------------------------------------------------------------------------
# GMM merge bodies


def merge_topk_xla(run_d, run_i, blk_d, blk_i, kd: int):
    """Concat + ``lax.top_k`` merge (the PR-1 GMM analogue)."""
    cand_d = jnp.concatenate([run_d, blk_d], axis=-1)
    cand_i = jnp.concatenate([run_i, blk_i], axis=-1)
    neg_top, sel = lax.top_k(-cand_d, kd)
    return -neg_top, jnp.take_along_axis(cand_i, sel, axis=-1)


def merge_packed_xla(run_k, blk_k, kd: int):
    """Packed-key sorted two-level merge — the XLA mirror of the Pallas
    kernel's bitonic LSM+GMM, built from the same ``core/packedkey``
    networks: reduce the tile to its sorted top-kd_pad
    (``topk_keys``), then one O(log kd_pad) ``merge_sorted`` against
    the running buffer. ``run_k`` must be sorted ascending (the scan
    invariant: the INT_BIG init is sorted, and this returns sorted).
    Keys are unique (index bits), so the result is exactly the kd
    lexicographically-smallest (dist, idx) pairs of the union."""
    kd_pad = next_pow2(kd)
    if run_k.shape[-1] < kd_pad:
        run_k = jnp.concatenate(
            [run_k, jnp.full(run_k.shape[:-1] + (kd_pad - run_k.shape[-1],),
                             INT_BIG, jnp.int32)],
            axis=-1,
        )
    merged = merge_sorted(run_k[..., :kd_pad], topk_keys(blk_k, kd_pad))
    return merged[..., :kd]


# ---------------------------------------------------------------------------
# The engine


def stream_topk(
    x3: jax.Array,
    y3: Optional[jax.Array] = None,
    pos_bias: Optional[jax.Array] = None,
    *,
    kd: int,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    merge: Optional[str] = None,
    fuse_norms: bool = False,
    mxu_bf16: bool = False,
    causal: bool = False,
    sq_y: Optional[jax.Array] = None,
    group_w: Optional[int] = None,
    m_valid: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Streaming top-kd over a (block_n x block_m) tile grid.

    x3 (B, N, D); y3 (B, M, D) or None for a self-graph (co-nodes = x,
    norms shared); pos_bias (B, N, M) or None. Returns (dist, idx),
    each (B, N, kd), distances ascending, BIG-sentinel invalid lanes.

    ``block_m=None`` streams the whole co-node set in one tile;
    ``block_n=None`` disables query tiling (PR-1 behavior). ``sq_y``
    accepts precomputed co-node squared norms (B, M) — the
    ``DigcCache`` hook for serving a fixed co-node gallery.

    ``m_valid`` is an (M,) or (B, M) bool mask of *live* co-nodes: pad
    co-nodes take the same BIG-norm masking the internal tile padding
    already uses (the ring tier's pad idiom lifted engine-wide), so a
    pad node's distance is >= BIG/2 from every query and can never
    displace a live neighbor — serving pads ragged patch counts to a
    static N-bucket with exact results on the live rows (DESIGN.md §13).
    """
    if merge is None:
        merge = "select"
    if merge not in MERGE_STRATEGIES:
        raise ValueError(
            f"unknown merge strategy {merge!r}; one of {MERGE_STRATEGIES}"
        )
    if group_w is None:
        group_w = _SELECT_GROUP_W
    if not 1 <= group_w <= _SELECT_GROUP_W_MAX:
        raise ValueError(
            f"group_w={group_w} out of range [1, {_SELECT_GROUP_W_MAX}]"
        )
    self_graph = y3 is None
    y3 = x3 if self_graph else y3
    b, n, feat = x3.shape
    m = y3.shape[1]
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")

    x3 = x3.astype(jnp.float32)
    y3 = x3 if self_graph else y3.astype(jnp.float32)
    sq_x = jnp.sum(x3 * x3, axis=-1)  # (B, N)
    if sq_y is None:
        sq_y = sq_x if self_graph else jnp.sum(y3 * y3, axis=-1)
    else:
        sq_y = sq_y.astype(jnp.float32)
    if m_valid is not None:
        # Live-node mask rides the norm term: every merge strategy and
        # the fuse_norms operand packing consume sq_y, so one mask site
        # covers them all. The query-side sq_x stays unmasked — pad
        # *rows* still compute (garbage) neighbors; only pad *columns*
        # are unselectable.
        mask = jnp.asarray(m_valid, bool)
        mask = mask[None, :] if mask.ndim == 1 else mask
        if mask.shape[-1] != m:
            raise ValueError(
                f"m_valid has {mask.shape[-1]} co-node lanes, expected M={m}"
            )
        sq_y = jnp.where(mask, sq_y, BIG)

    block_m = m if block_m is None else max(1, min(block_m, m))
    m_pad = _ceil_to(m, block_m)
    nb_m = m_pad // block_m
    y_p = jnp.pad(y3, ((0, 0), (0, m_pad - m), (0, 0)))
    # Padded co-nodes are masked through their norm term.
    sq_y_p = jnp.pad(sq_y, ((0, 0), (0, m_pad - m)))
    sq_y_p = jnp.where(jnp.arange(m_pad)[None, :] < m, sq_y_p, BIG)

    if mxu_bf16:
        fuse_norms = False  # norm terms must stay fp32
    if fuse_norms:
        ones_x = jnp.ones((b, n, 1), jnp.float32)
        ones_y = jnp.ones((b, m_pad, 1), jnp.float32)
        x_op = jnp.concatenate([-2.0 * x3, ones_x, sq_x[..., None]], axis=-1)
        y_op = jnp.concatenate([y_p, sq_y_p[..., None], ones_y], axis=-1)
    elif mxu_bf16:
        x_op = x3.astype(jnp.bfloat16)
        y_op = y_p.astype(jnp.bfloat16)
    else:
        x_op = x3
        y_op = y_p

    y_blocks = y_op.reshape(b, nb_m, block_m, y_op.shape[-1]).transpose(1, 0, 2, 3)
    sqy_blocks = sq_y_p.reshape(b, nb_m, block_m).transpose(1, 0, 2)
    offsets = jnp.arange(nb_m, dtype=jnp.int32) * block_m

    idx_bits = idx_bits_for(m_pad) if merge == "packed" else 0

    if pos_bias is not None:
        pos_bias = jnp.pad(
            pos_bias.astype(jnp.float32), ((0, 0), (0, 0), (0, m_pad - m))
        )

    def run_queries(xq_op, sqx_q, p_q, row_off):
        """Top-kd for one query block (B, bn, ...) at global row offset."""
        bn = xq_op.shape[1]

        def tile_dists(y_blk, sqy_blk, off, p_blk):
            d_blk = lax.dot_general(
                xq_op, y_blk, (((2,), (2,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )
            if not fuse_norms:
                d_blk = sqx_q[..., None] - 2.0 * d_blk + sqy_blk[:, None, :]
            if p_blk is not None:
                d_blk = d_blk + p_blk
            cols = off + lax.broadcasted_iota(jnp.int32, d_blk.shape, 2)
            if causal:
                rows = row_off + lax.broadcasted_iota(
                    jnp.int32, d_blk.shape, 1
                )
                d_blk = jnp.where(cols <= rows, d_blk, BIG)
            return d_blk, cols

        def p_blk_for(step):
            if p_q is None:
                return None
            return lax.dynamic_slice_in_dim(p_q, step * block_m, block_m, 2)

        if merge == "select":
            def step(carry, sm):
                y_blk, sqy_blk, off, step_i = sm
                d_blk, _ = tile_dists(y_blk, sqy_blk, off, p_blk_for(step_i))
                vals, col = select_topkd(d_blk, kd, group_w=group_w)
                return carry, (vals, off + col)

            _, (vals, idxs) = lax.scan(
                step, None,
                (y_blocks, sqy_blocks, offsets,
                 jnp.arange(nb_m, dtype=jnp.int32)),
            )
            if nb_m == 1:
                return vals[0], idxs[0]
            cd = vals.transpose(1, 2, 0, 3).reshape(b, bn, nb_m * kd)
            ci = idxs.transpose(1, 2, 0, 3).reshape(b, bn, nb_m * kd)
            neg, sel = lax.top_k(-cd, kd)
            return -neg, jnp.take_along_axis(ci, sel, axis=-1)

        if merge == "packed":
            def step(run_k, sm):
                y_blk, sqy_blk, off, step_i = sm
                d_blk, cols = tile_dists(y_blk, sqy_blk, off, p_blk_for(step_i))
                blk_k = pack_keys(d_blk, cols, idx_bits)
                return merge_packed_xla(run_k, blk_k, kd), None

            init = jnp.full((b, bn, kd), INT_BIG, jnp.int32)
            run_k, _ = lax.scan(
                step, init,
                (y_blocks, sqy_blocks, offsets,
                 jnp.arange(nb_m, dtype=jnp.int32)),
            )
            return unpack_keys(run_k, idx_bits)

        def step(carry, sm):  # merge == "topk"
            run_d, run_i = carry
            y_blk, sqy_blk, off, step_i = sm
            d_blk, cols = tile_dists(y_blk, sqy_blk, off, p_blk_for(step_i))
            run_d, run_i = merge_topk_xla(run_d, run_i, d_blk, cols, kd)
            return (run_d, run_i), None

        init = (
            jnp.full((b, bn, kd), BIG, jnp.float32),
            jnp.zeros((b, bn, kd), jnp.int32),
        )
        (run_d, run_i), _ = lax.scan(
            step, init,
            (y_blocks, sqy_blocks, offsets, jnp.arange(nb_m, dtype=jnp.int32)),
        )
        return run_d, run_i

    if block_n is None or block_n >= n:
        return run_queries(x_op, sq_x, pos_bias, jnp.int32(0))

    block_n = max(1, block_n)
    n_pad = _ceil_to(n, block_n)
    nb_n = n_pad // block_n
    x_op_p = jnp.pad(x_op, ((0, 0), (0, n_pad - n), (0, 0)))
    sq_x_p = jnp.pad(sq_x, ((0, 0), (0, n_pad - n)))
    p_p = None
    if pos_bias is not None:
        p_p = jnp.pad(pos_bias, ((0, 0), (0, n_pad - n), (0, 0)))

    def q_step(carry, qi):
        row_off = qi * block_n
        xq = lax.dynamic_slice_in_dim(x_op_p, row_off, block_n, 1)
        sqx_q = lax.dynamic_slice_in_dim(sq_x_p, row_off, block_n, 1)
        p_q = (
            None if p_p is None
            else lax.dynamic_slice_in_dim(p_p, row_off, block_n, 1)
        )
        return carry, run_queries(xq, sqx_q, p_q, row_off)

    _, (dist_q, idx_q) = lax.scan(
        q_step, None, jnp.arange(nb_n, dtype=jnp.int32)
    )
    dist = dist_q.transpose(1, 0, 2, 3).reshape(b, n_pad, kd)[:, :n]
    idx = idx_q.transpose(1, 0, 2, 3).reshape(b, n_pad, kd)[:, :n]
    return dist, idx


# ---------------------------------------------------------------------------
# Cross-layer / cross-request cache


@dataclasses.dataclass
class DigcCache:
    """Host-side cache for reusable graph-construction state — the
    **legacy eager shim**; new code should thread the functional
    ``repro.core.state.DigcState`` pytree instead, which carries the
    same state *through* ``jit`` (DESIGN.md §7).

    Holds co-node squared norms (serving a fixed gallery), cluster
    centroids (layer-to-layer / request-to-request k-means warm
    starts) and any other builder state, keyed by (kind, caller key).
    Strictly eager: entries are only read or written for concrete
    arrays — under ``jit`` tracing the cache is bypassed entirely,
    because a cached value captured by a trace would be baked into the
    compiled program as a stale constant.
    """

    max_entries: int = 256
    _store: dict = dataclasses.field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    @staticmethod
    def usable(*arrays) -> bool:
        """Cache only engages outside tracing (concrete values)."""
        return not any(isinstance(a, jax.core.Tracer) for a in arrays)

    def get(self, kind: str, key: Any):
        entry = self._store.get((kind, key))
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, kind: str, key: Any, value) -> None:
        if not self.usable(*jax.tree_util.tree_leaves(value)):
            return
        if len(self._store) >= self.max_entries:
            self._store.pop(next(iter(self._store)))
        self._store[(kind, key)] = value

    def norms(self, key: Any, y: jax.Array) -> jax.Array:
        """||y||^2 for a co-node set identified by ``key``.

        The key must identify the co-node *contents* (e.g. a gallery
        version tag) — shapes alone are not enough.
        """
        if not self.usable(y):
            return jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)
        cached = self.get("sq_y", key)
        if cached is not None and cached.shape == y.shape[:-1]:
            return cached
        sq = jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)
        self.put("sq_y", key, sq)
        return sq

    def stats(self) -> dict:
        return {
            "entries": len(self._store),
            "hits": self.hits,
            "misses": self.misses,
        }

    def clear(self) -> None:
        self._store.clear()
