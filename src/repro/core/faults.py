"""Deterministic fault injection for the serving stack (DESIGN.md §11).

Six PRs of speed produced a stack with zero fault tolerance — and no
way to even *test* the failure paths. ``FaultPlan`` is that test
surface: a seedable registry of injectors bound to named **sites**
inside ``digc()`` / ``VigServeEngine``. Production code carries one
``if fault_plan is None`` branch per site and nothing else — the
fault-free path is unchanged (the ``serve/guarded_*`` bench rows pin
the guard overhead, not the injection overhead, which is zero).

Sites (the engine fires these; ``digc()`` fires ``digc.x``):

  * ``admit.image``   — a request's image at tick admission. Injectors
    plant non-finite values per (tenant, tick); the admission screen
    must catch them before they reach a compiled program.
  * ``state.rows``    — the canonical per-slot ``DigcState`` at the
    top of a tick. Injectors bit-corrupt one row of one entry buffer
    (centroids / sq_y / row_step) *without* going through the
    sanctioned ``put_rows``/``reset_rows`` lifecycle — exactly what
    the integrity tokens (``core/state.py``) exist to detect.
  * ``program.build`` — bucket program construction. Injectors raise
    (a compile failure on an untested shape); the engine retries with
    backoff and then walks the degradation ladder.
  * ``park.restore``  — a parked tenant's host rows at re-admission.
    Injectors raise transiently (retried) or return ``None``
    (parking-store loss: the tenant must re-admit *cold*).
  * ``tick.serve``    — inside the tick's timed serve section.
    Injectors sleep, forcing a deadline miss.
  * ``digc.x``        — node features entering an eager ``digc()``
    call (kernel-level screening tests; bypassed under tracing).

Every injector is deterministic given the plan's seed and the request
trace: random draws (corruption positions, bit indices) come from one
``numpy`` generator in registration order, so a failing fault-matrix
test replays exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

SITES = (
    "admit.image",
    "state.rows",
    "program.build",
    "park.restore",
    "tick.serve",
    "digc.x",
)

_ANY = object()  # match-anything sentinel (None is a real tenant value)


@dataclasses.dataclass(frozen=True)
class FaultInfo:
    """Typed record of one fault — injected or detected.

    ``kind`` names the taxonomy entry (DESIGN.md §11): e.g.
    ``nonfinite_input``, ``state_corruption``, ``nonfinite_state``,
    ``compile_failure``, ``parking_loss``, ``slow_tick``,
    ``deadline_miss``, ``deadline_degrade``. ``site`` is where it
    fired/was caught; ``tenant``/``tick`` locate it in the trace.
    A quarantined request carries its ``FaultInfo`` in
    ``VigRequest.fault``.
    """

    kind: str
    site: str
    tenant: Any = None
    tick: Optional[int] = None
    detail: str = ""

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tenant"] = None if self.tenant is None else str(self.tenant)
        return d


class FaultError(RuntimeError):
    """An injected (or detected) fault raised as an exception."""

    def __init__(self, info: FaultInfo):
        super().__init__(
            f"injected fault {info.kind!r} at {info.site}"
            + (f" (tick {info.tick})" if info.tick is not None else "")
            + (f": {info.detail}" if info.detail else "")
        )
        self.info = info


@dataclasses.dataclass
class _Injector:
    site: str
    action: Callable  # (value, ctx) -> value; may raise / sleep
    criteria: dict  # ctx-key -> required value (_ANY matches all)
    remaining: float  # inf = unlimited

    def matches(self, ctx: dict) -> bool:
        if self.remaining <= 0:
            return False
        for key, want in self.criteria.items():
            if want is _ANY:
                continue
            if ctx.get(key, _ANY) != want:
                return False
        return True


class FaultPlan:
    """Seedable, deterministic fault-injection plan.

    Register injectors with the ``inject_*`` methods, pass the plan to
    ``VigServeEngine(fault_plan=...)`` (or ``digc(fault_plan=...)``),
    and replay a trace. ``fired`` logs every injection that actually
    triggered, in order — the test oracle for "the fault happened".
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._injectors: list[_Injector] = []
        self.fired: list[FaultInfo] = []

    # -- firing (called from the instrumented sites) --------------------

    def fire(self, site: str, value=None, **ctx):
        """Run every armed injector registered at ``site`` whose
        criteria match ``ctx``; returns the (possibly replaced) value.
        Injectors may raise ``FaultError`` or sleep instead."""
        for inj in self._injectors:
            if inj.site != site or not inj.matches(ctx):
                continue
            inj.remaining -= 1
            value = inj.action(value, ctx)
        return value

    def counts(self) -> dict:
        """Fired-injection counts by kind (test/ops summary)."""
        out: dict[str, int] = {}
        for f in self.fired:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    # -- registration ---------------------------------------------------

    def _add(self, site: str, action, criteria: dict, times) -> "FaultPlan":
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; sites: {SITES}")
        self._injectors.append(_Injector(
            site=site, action=action, criteria=criteria,
            remaining=float("inf") if times is None else float(times),
        ))
        return self

    def _log(self, kind: str, site: str, ctx: dict, detail: str = ""):
        info = FaultInfo(
            kind=kind, site=site, tenant=ctx.get("tenant"),
            tick=ctx.get("tick"), detail=detail,
        )
        self.fired.append(info)
        return info

    def inject_nonfinite_input(self, tenant=_ANY, *, tick=None, count=3,
                               mode="nan", times=1,
                               site="admit.image") -> "FaultPlan":
        """Plant ``count`` non-finite values (``mode``: nan | inf |
        -inf) at seeded positions of the matched image/features."""
        fill = {"nan": np.nan, "inf": np.inf, "-inf": -np.inf}[mode]

        def action(value, ctx):
            img = np.array(value, dtype=np.float32, copy=True)
            flat = img.reshape(-1)
            pos = self._rng.integers(0, flat.size, size=min(count, flat.size))
            flat[pos] = fill
            self._log("nonfinite_input", site, ctx,
                      f"{mode} at {len(pos)} seeded positions")
            return img

        crit = {"tenant": tenant}
        if tick is not None:
            crit["tick"] = tick
        return self._add(site, action, crit, times)

    def inject_state_corruption(self, *, key=None, field="centroids",
                                row=0, tick=None, mode="bitflip",
                                times=1) -> "FaultPlan":
        """Corrupt one row of one ``DigcStateEntry`` buffer *outside*
        the sanctioned row lifecycle. ``mode="bitflip"`` XORs a seeded
        bit of the row's bytes (a finite wrong value — only the
        integrity fingerprint can catch it); ``mode="nan"`` plants a
        NaN (the state finiteness screen's test case; float fields
        only)."""
        if mode not in ("bitflip", "nan"):
            raise ValueError(f"mode must be 'bitflip' or 'nan': {mode!r}")

        def action(state, ctx):
            import jax.numpy as jnp

            from repro.core.state import DigcState

            keys = [key] if key is not None else [
                k for k, e in state.entries.items()
                if getattr(e, field, None) is not None
            ]
            if not keys or state.entries[keys[0]] is None:
                raise ValueError(
                    f"no state entry carries field {field!r} to corrupt"
                )
            k = keys[0]
            entry = state.entries[k]
            buf = np.array(np.asarray(getattr(entry, field)), copy=True)
            rowv = buf.reshape(buf.shape[0], -1)[row]
            if mode == "nan":
                if not np.issubdtype(rowv.dtype, np.floating):
                    raise ValueError(
                        f"mode='nan' needs a float field, {field} is "
                        f"{rowv.dtype}"
                    )
                rowv[int(self._rng.integers(0, rowv.size))] = np.nan
                detail = f"NaN planted in {k}.{field}[{row}]"
            else:
                raw = rowv.view(np.uint8)
                bit = int(self._rng.integers(0, raw.size * 8))
                raw[bit // 8] ^= np.uint8(1 << (bit % 8))
                detail = f"bit {bit} flipped in {k}.{field}[{row}]"
            self._log("state_corruption", "state.rows", ctx, detail)
            new_entry = dataclasses.replace(entry, **{field: jnp.asarray(buf)})
            return DigcState(entries={**state.entries, k: new_entry})

        crit = {} if tick is None else {"tick": tick}
        return self._add("state.rows", action, crit, times)

    def inject_build_failure(self, *, bucket=_ANY, impl=_ANY,
                             times=1) -> "FaultPlan":
        """Raise from the program-build site (a Pallas compile failure
        on an untested shape). ``times`` bounds how many build attempts
        fail — transient (< retry budget) vs persistent (the engine
        walks the degradation ladder). ``impl`` scopes the failure to
        one tier, so the ladder's fallback build can succeed."""

        def action(value, ctx):
            info = self._log(
                "compile_failure", "program.build", ctx,
                f"bucket={ctx.get('bucket')} impl={ctx.get('impl')}",
            )
            raise FaultError(info)

        return self._add(
            "program.build", action, {"bucket": bucket, "impl": impl}, times
        )

    def inject_parking_loss(self, tenant=_ANY, *, times=1) -> "FaultPlan":
        """Parking-store loss: the matched tenant's parked rows are
        gone at restore time (``None``) — it must re-admit cold."""

        def action(value, ctx):
            self._log("parking_loss", "park.restore", ctx,
                      "parked rows dropped")
            return None

        return self._add("park.restore", action, {"tenant": tenant}, times)

    def inject_park_restore_error(self, tenant=_ANY, *,
                                  times=1) -> "FaultPlan":
        """Transient host-side restore failure: raises ``times`` times,
        then the (unchanged) rows restore — the retry loop's test
        case."""

        def action(value, ctx):
            info = self._log("parking_transient", "park.restore", ctx,
                             "transient restore failure")
            raise FaultError(info)

        return self._add("park.restore", action, {"tenant": tenant}, times)

    def inject_slow_tick(self, *, tick=None, seconds=0.05,
                         times=1) -> "FaultPlan":
        """Sleep inside the tick's timed serve section — an artificial
        straggler forcing a deadline miss."""

        def action(value, ctx):
            self._log("slow_tick", "tick.serve", ctx, f"slept {seconds}s")
            time.sleep(seconds)
            return value

        crit = {} if tick is None else {"tick": tick}
        return self._add("tick.serve", action, crit, times)
