"""Graph operations consuming DIGC output: gather + GNN aggregation.

ViG's Grapher block uses max-relative graph convolution (MRConv):
    agg_i = max_{j in N(i)} (x_j - x_i)
    out_i = W [x_i ; agg_i]
The gather/aggregate here is the message-passing consumer of the
neighbor lists produced by DIGC.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def knn_gather(y: Array, idx: Array) -> Array:
    """Gather neighbor features. y: (M, D), idx: (N, k) -> (N, k, D);
    batched (B, M, D) + (B, N, k) -> (B, N, k, D)."""
    if y.ndim == 2:
        return jnp.take(y, idx, axis=0)
    return jax.vmap(lambda yb, ib: jnp.take(yb, ib, axis=0))(y, idx)


def mr_aggregate(x: Array, y: Array, idx: Array) -> Array:
    """Max-relative aggregation: max_j (y_j - x_i). Output matches x's
    rank: (N, D) or (B, N, D)."""
    neigh = knn_gather(y, idx)  # (..., N, k, D)
    rel = neigh - x[..., :, None, :]
    return jnp.max(rel, axis=-2)


def sum_aggregate(x: Array, y: Array, idx: Array) -> Array:
    neigh = knn_gather(y, idx)
    return jnp.sum(neigh - x[..., :, None, :], axis=-2)


def mean_aggregate(x: Array, y: Array, idx: Array) -> Array:
    neigh = knn_gather(y, idx)
    return jnp.mean(neigh - x[..., :, None, :], axis=-2)


AGGREGATORS = {
    "max": mr_aggregate,
    "sum": sum_aggregate,
    "mean": mean_aggregate,
}


def edge_list(idx: Array) -> Array:
    """(N, k) neighbor indices -> COO edge list (2, N*k) of (src=j, dst=i)."""
    n, k = idx.shape
    dst = jnp.repeat(jnp.arange(n, dtype=idx.dtype), k)
    src = idx.reshape(-1)
    return jnp.stack([src, dst])


def degree_histogram(idx: Array, m: int) -> Array:
    """In-degree of each co-node given neighbor lists (diagnostics)."""
    flat = idx.reshape(-1)
    return jnp.zeros((m,), jnp.int32).at[flat].add(1)


def grid_pos_bias(h: int, w: int, hc: Optional[int] = None, wc: Optional[int] = None,
                  scale: float = 0.0) -> Array:
    """Relative positional bias P (N, M) between an h*w node grid and an
    hc*wc co-node grid (co-grid defaults to node grid). ViG adds a
    distance-based spatial prior to D_XY; `scale` 0 disables (returns zeros)."""
    hc = hc or h
    wc = wc or w
    ys, xs = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    pn = jnp.stack([ys.reshape(-1) / max(h - 1, 1), xs.reshape(-1) / max(w - 1, 1)], -1)
    ysc, xsc = jnp.meshgrid(jnp.arange(hc), jnp.arange(wc), indexing="ij")
    pc = jnp.stack(
        [ysc.reshape(-1) / max(hc - 1, 1), xsc.reshape(-1) / max(wc - 1, 1)], -1
    )
    d2 = jnp.sum((pn[:, None, :] - pc[None, :, :]) ** 2, -1)
    return (scale * d2).astype(jnp.float32)
