"""KNN-sparse attention built on DIGC (beyond-paper integration).

The paper's DIGC selects, for each node, the k most similar co-nodes.
Applied to an LM: each query attends only to its k nearest keys under
squared euclidean distance — sub-quadratic attention whose neighbor
list construction IS the paper's kernel. For unit-norm keys the distance
ranking equals the dot-product ranking, so this is a faithful sparse
approximation of softmax attention (Routing-Transformer-family).

Exposed to the arch configs as ``attention="knn"`` (opt-in; baselines
keep the published full attention).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.digc import BIG, digc


def knn_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    num_neighbors: int,
    causal: bool = True,
    impl: str = "blocked",
    scale: Optional[float] = None,
    **digc_kwargs,
) -> jax.Array:
    """Single-head KNN attention. q: (S, Dh), k/v: (T, Dh) -> (S, Dh).

    Neighbor lists come from DIGC (squared-euclidean, causal-masked);
    softmax runs over the gathered k-subset of true dot-product logits.
    """
    s, dh = q.shape
    t = k.shape[0]
    nn = min(num_neighbors, t)
    scale = scale if scale is not None else dh**-0.5
    idx, dist = digc(
        q, k, k=nn, causal=causal, impl=impl, return_dists=True, **digc_kwargs
    )
    kg = jnp.take(k, idx, axis=0)  # (S, nn, Dh)
    vg = jnp.take(v, idx, axis=0)
    logits = jnp.einsum("sd,snd->sn", q, kg) * scale
    # Entries whose DIGC distance is the BIG sentinel are padding /
    # causally-excluded: mask them out of the softmax.
    invalid = dist >= BIG / 2
    logits = jnp.where(invalid, -jnp.inf, logits)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(invalid, 0.0, w)  # all-invalid rows: zero output
    return jnp.einsum("sn,snd->sd", w, vg)


def knn_attention_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    num_neighbors: int,
    causal: bool = True,
    impl: str = "blocked",
    **digc_kwargs,
) -> jax.Array:
    """Multi-head wrapper. q: (S, H, Dh), k/v: (T, H, Dh) -> (S, H, Dh)."""

    def per_head(qh, kh, vh):
        return knn_attention(
            qh,
            kh,
            vh,
            num_neighbors=num_neighbors,
            causal=causal,
            impl=impl,
            **digc_kwargs,
        )

    return jax.vmap(per_head, in_axes=(1, 1, 1), out_axes=1)(q, k, v)


def knn_attention_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    num_neighbors: int,
) -> jax.Array:
    """Single-token decode: top-k over one distance row (the degenerate
    N=1 DIGC), then softmax over the gathered neighbors.

    q: (H, Dh); caches: (T, H, Dh); cache_len: valid prefix length.
    """
    t, h, dh = k_cache.shape
    nn = min(num_neighbors, t)
    valid = jnp.arange(t) < cache_len  # (T,)

    def per_head(qh, kh, vh):
        d = jnp.sum((kh - qh[None, :]) ** 2, -1)
        d = jnp.where(valid, d, BIG)
        neg, idx = jax.lax.top_k(-d, nn)
        kg = kh[idx]
        vg = vh[idx]
        logits = (kg @ qh) * dh**-0.5
        logits = jnp.where(-neg >= BIG / 2, -jnp.inf, logits)
        w = jax.nn.softmax(logits)
        w = jnp.where(-neg >= BIG / 2, 0.0, w)
        return w @ vg

    return jax.vmap(per_head, in_axes=(0, 1, 1))(q, k_cache, v_cache)
