"""Order-preserving packed (distance, index) keys — shared by the XLA
engine and the Pallas kernel.

The paper's GMM stage moves (distance, index) pairs through the merge
network as one word (u16 index + truncated distance). The TPU/XLA
analogue packs both into a single int32 whose *integer* order equals
the lexicographic (distance, index) order:

  * the fp32 distance is made order-monotonic with the standard IEEE
    total-order flip (non-negative floats keep their bit pattern;
    negative floats are inverted), then truncated to the top
    ``32 - idx_bits`` bits;
  * the low ``idx_bits = ceil(log2 M)`` bits hold the co-node index.

One array instead of two halves merge traffic, ``min()`` extracts the
(dist, idx) winner in a single op, and ties created by the truncation
resolve to the *lowest index* — the same tie rule as ``lax.top_k``.
Precision is adaptive: M=196 keeps 16 mantissa bits (near-exact);
M=16384 (ViG @ 2048^2) keeps 9. Packed selection is therefore
tie-tolerant rather than bit-exact: indices may differ from the fp32
path only where two distances agree in their truncated high bits
(within ~2^-(23-idx_bits) relative). Exact consumers use the unpacked
paths; ``kernels/digc_topk.py`` and ``core/engine.py`` expose packing
as an opt-in knob (``DigcSpec.packed`` / ``merge="packed"``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Packed-key sentinel (a very large distance with index bits zeroed).
# A python int so it inlines as a weak-typed literal in kernels instead
# of being captured as a constant.
INT_BIG = 0x7F7F0000

# Beyond 20 index bits fewer than 3 mantissa bits survive — selection
# degenerates to exponent-only comparison. Refuse rather than degrade.
MAX_IDX_BITS = 20


def idx_bits_for(m: int) -> int:
    """Index bits needed to address co-nodes [0, m); at least 1."""
    if m > (1 << MAX_IDX_BITS):
        raise ValueError(
            f"packed keys support at most {1 << MAX_IDX_BITS} co-nodes "
            f"({MAX_IDX_BITS} index bits); got M={m}. Use an unpacked "
            "merge for larger co-node sets."
        )
    return max(int(m - 1).bit_length(), 1)


def pack_keys(d: jax.Array, idx: jax.Array, idx_bits: int) -> jax.Array:
    """Order-preserving (distance, index) -> single int32 key."""
    INT_MIN = jnp.int32(-(2**31))
    bits = jax.lax.bitcast_convert_type(d.astype(jnp.float32), jnp.int32)
    key = jnp.where(bits >= 0, bits, jnp.invert(bits) ^ INT_MIN)
    hi = jnp.right_shift(key, idx_bits)  # arithmetic shift: order-preserving
    mask = jnp.int32((1 << idx_bits) - 1)
    return jnp.left_shift(hi, idx_bits) | (idx & mask)


def unpack_keys(keys: jax.Array, idx_bits: int) -> tuple[jax.Array, jax.Array]:
    """Inverse of ``pack_keys``: int32 keys -> (fp32 distance, int32 idx).

    The recovered distance carries the truncation (low ``idx_bits``
    mantissa bits zeroed) — within 2^-(23-idx_bits) relative of the
    original, and still far above ``BIG/2`` for sentinel lanes.
    """
    INT_MIN = jnp.int32(-(2**31))
    idx = keys & jnp.int32((1 << idx_bits) - 1)
    bits = jnp.left_shift(jnp.right_shift(keys, idx_bits), idx_bits)
    bits = jnp.where(bits >= 0, bits, jnp.invert(bits ^ INT_MIN))
    return jax.lax.bitcast_convert_type(bits, jnp.float32), idx
