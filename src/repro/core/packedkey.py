"""Order-preserving packed (distance, index) keys — shared by the XLA
engine and the Pallas kernel.

The paper's GMM stage moves (distance, index) pairs through the merge
network as one word (u16 index + truncated distance). The TPU/XLA
analogue packs both into a single int32 whose *integer* order equals
the lexicographic (distance, index) order:

  * the fp32 distance is made order-monotonic with the standard IEEE
    total-order flip (non-negative floats keep their bit pattern;
    negative floats are inverted), then truncated to the top
    ``32 - idx_bits`` bits;
  * the low ``idx_bits = ceil(log2 M)`` bits hold the co-node index.

One array instead of two halves merge traffic, ``min()`` extracts the
(dist, idx) winner in a single op, and ties created by the truncation
resolve to the *lowest index* — the same tie rule as ``lax.top_k``.
Precision is adaptive: M=196 keeps 16 mantissa bits (near-exact);
M=16384 (ViG @ 2048^2) keeps 9. Packed selection is therefore
tie-tolerant rather than bit-exact: indices may differ from the fp32
path only where two distances agree in their truncated high bits
(within ~2^-(23-idx_bits) relative). Exact consumers use the unpacked
paths; ``kernels/digc_topk.py`` and ``core/engine.py`` expose packing
as an opt-in knob (``DigcSpec.packed`` / ``merge="packed"``).

This module also hosts the **bitonic sort/merge networks** shared by
the Pallas kernel's LSM+GMM stages and the engine's packed merge
(``sort_keys`` / ``merge_sorted`` / ``topk_keys``, plus the
comparator-generic ``bitonic_*`` forms used by the kernel's exact
two-array path). Every network is built from data-independent
compare-exchange passes realized as reshape + elementwise min/max —
no gathers, no data-dependent control flow, static shapes throughout —
so the same code lowers on the VPU and runs under XLA. Because the
packed-key integer order *is* the lexicographic (dist, idx) order,
the bitonic path preserves the lowest-index tie rule exactly.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

# Packed-key sentinel (a very large distance with index bits zeroed).
# A python int so it inlines as a weak-typed literal in kernels instead
# of being captured as a constant.
INT_BIG = 0x7F7F0000

# Beyond 20 index bits fewer than 3 mantissa bits survive — selection
# degenerates to exponent-only comparison. Refuse rather than degrade.
MAX_IDX_BITS = 20


def idx_bits_for(m: int) -> int:
    """Index bits needed to address co-nodes [0, m); at least 1."""
    if m > (1 << MAX_IDX_BITS):
        raise ValueError(
            f"packed keys support at most {1 << MAX_IDX_BITS} co-nodes "
            f"({MAX_IDX_BITS} index bits); got M={m}. Use an unpacked "
            "merge for larger co-node sets."
        )
    return max(int(m - 1).bit_length(), 1)


def pack_keys(d: jax.Array, idx: jax.Array, idx_bits: int) -> jax.Array:
    """Order-preserving (distance, index) -> single int32 key."""
    INT_MIN = jnp.int32(-(2**31))
    bits = jax.lax.bitcast_convert_type(d.astype(jnp.float32), jnp.int32)
    key = jnp.where(bits >= 0, bits, jnp.invert(bits) ^ INT_MIN)
    hi = jnp.right_shift(key, idx_bits)  # arithmetic shift: order-preserving
    mask = jnp.int32((1 << idx_bits) - 1)
    return jnp.left_shift(hi, idx_bits) | (idx & mask)


def unpack_keys(keys: jax.Array, idx_bits: int) -> tuple[jax.Array, jax.Array]:
    """Inverse of ``pack_keys``: int32 keys -> (fp32 distance, int32 idx).

    The recovered distance carries the truncation (low ``idx_bits``
    mantissa bits zeroed) — within 2^-(23-idx_bits) relative of the
    original, and still far above ``BIG/2`` for sentinel lanes.
    """
    INT_MIN = jnp.int32(-(2**31))
    idx = keys & jnp.int32((1 << idx_bits) - 1)
    bits = jnp.left_shift(jnp.right_shift(keys, idx_bits), idx_bits)
    bits = jnp.where(bits >= 0, bits, jnp.invert(bits ^ INT_MIN))
    return jax.lax.bitcast_convert_type(bits, jnp.float32), idx


# ---------------------------------------------------------------------------
# Bitonic compare-exchange networks (LSM local sort + GMM sorted merge)
#
# The comparator-generic forms move a *tuple* of arrays through the
# network in lockstep so the kernel's exact path can sort (dist, idx)
# pairs under the lexicographic order; the packed wrappers specialize
# to a single int32 key array whose integer order already encodes it.

# Index fill for padded lanes in the exact two-array path: larger than
# any real co-node index, so a padding lane loses every distance tie.
IDX_FILL = 0x7FFFFFFF


def next_pow2(v: int) -> int:
    """Smallest power of two >= v (1 for v <= 1)."""
    return 1 if v <= 1 else 1 << (v - 1).bit_length()


def key_less(a: Sequence[jax.Array], b: Sequence[jax.Array]) -> jax.Array:
    """Packed-key comparator: integer order == (dist, idx) order."""
    return a[0] < b[0]


def dist_idx_less(a: Sequence[jax.Array], b: Sequence[jax.Array]) -> jax.Array:
    """Lexicographic (distance, index) comparator — ``lax.top_k``'s tie
    rule (lowest index wins among equal distances), made explicit."""
    return (a[0] < b[0]) | ((a[0] == b[0]) & (a[1] < b[1]))


def _ce_pass(vals: tuple, dist: int, asc_run: int | None, less: Callable):
    """One compare-exchange pass at partner distance ``dist`` along the
    last axis: reshape to (..., L/2d, 2, d) pairs element p with p^d —
    no gathers, static shapes. ``asc_run`` is the sorted-run length
    whose bit of p picks the direction (the classic ``i & k`` rule);
    None means every pair sorts ascending (a merge/clean pass)."""
    lead = vals[0].shape[:-1]
    n_items = vals[0].shape[-1]
    chunks = n_items // (2 * dist)
    resh = [v.reshape(lead + (chunks, 2, dist)) for v in vals]
    lo = tuple(r[..., 0, :] for r in resh)
    hi = tuple(r[..., 1, :] for r in resh)
    if asc_run is None:
        asc = True
    else:
        # chunk c holds positions [c*2d, (c+1)*2d); all of them share
        # the asc_run bit because dist < asc_run. broadcasted_iota keeps
        # this a traced op (TPU rejects 1D iota / captured constants).
        cid = lax.broadcasted_iota(jnp.int32, (chunks, dist), 0)
        asc = ((cid * (2 * dist)) // asc_run) % 2 == 0
    keep = jnp.equal(less(lo, hi), asc)
    new_lo = tuple(jnp.where(keep, a, b) for a, b in zip(lo, hi))
    new_hi = tuple(jnp.where(keep, b, a) for a, b in zip(lo, hi))
    return tuple(
        jnp.stack((a, b), axis=-2).reshape(lead + (n_items,))
        for a, b in zip(new_lo, new_hi)
    )


def bitonic_sort(vals: tuple, less: Callable) -> tuple:
    """Full ascending bitonic sort along the last axis (length must be a
    power of two): log2(L)*(log2(L)+1)/2 data-independent passes."""
    n_items = vals[0].shape[-1]
    if n_items & (n_items - 1):
        raise ValueError(f"bitonic_sort needs a power-of-two length; got {n_items}")
    run = 2
    while run <= n_items:
        dist = run // 2
        while dist >= 1:
            vals = _ce_pass(vals, dist, run, less)
            dist //= 2
        run *= 2
    return vals


def bitonic_merge_sorted(a: tuple, b: tuple, less: Callable) -> tuple:
    """Merge two ascending sorted length-L sequences into the ascending
    lowest-L of their union in 1 + log2(L) passes.

    The first pass pairs a[i] with b[L-1-i] (a ++ reverse(b) is
    bitonic): the elementwise winners are exactly the L smallest of the
    union and form a bitonic sequence, cleaned by log2(L) ascending
    passes — the paper's GMM heap-insert, as a sorting network."""
    n_items = a[0].shape[-1]
    b_rev = tuple(jnp.flip(v, axis=-1) for v in b)
    take_a = less(a, b_rev)
    vals = tuple(jnp.where(take_a, x, y) for x, y in zip(a, b_rev))
    dist = n_items // 2
    while dist >= 1:
        vals = _ce_pass(vals, dist, None, less)
        dist //= 2
    return vals


def bitonic_topk(vals: tuple, k_pad: int, less: Callable, fill: tuple) -> tuple:
    """Ascending lowest-``k_pad`` of the last axis (any width) — the
    LSM local-sort stage: pad with ``fill`` sentinels to g*k_pad (g a
    power of two), sort each width-k_pad group, then tournament-merge
    group pairs with ``bitonic_merge_sorted`` until one remains.
    Per-element pass count is O(log^2 k_pad), independent of width."""
    if k_pad & (k_pad - 1):
        raise ValueError(f"bitonic_topk needs power-of-two k_pad; got {k_pad}")
    lead = vals[0].shape[:-1]
    width = vals[0].shape[-1]
    groups = next_pow2(-(-width // k_pad))
    w_pad = groups * k_pad
    if w_pad != width:
        vals = tuple(
            jnp.concatenate(
                [v, jnp.full(lead + (w_pad - width,), f, v.dtype)], axis=-1
            )
            for v, f in zip(vals, fill)
        )
    grp = tuple(v.reshape(lead + (groups, k_pad)) for v in vals)
    grp = bitonic_sort(grp, less)
    while groups > 1:
        halves = [v.reshape(lead + (groups // 2, 2, k_pad)) for v in grp]
        a = tuple(h[..., 0, :] for h in halves)
        b = tuple(h[..., 1, :] for h in halves)
        grp = bitonic_merge_sorted(a, b, less)
        groups //= 2
    return tuple(v.reshape(lead + (k_pad,)) for v in grp)


# -- packed-key wrappers (the shared kernel/engine API) ---------------------


def sort_keys(keys: jax.Array) -> jax.Array:
    """Ascending bitonic sort of packed keys along the last axis
    (power-of-two length). Integer order == (dist, idx) order, so the
    result is lexicographically sorted with ties -> lowest index."""
    return bitonic_sort((keys,), key_less)[0]


def merge_sorted(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sorted lowest-L of two ascending sorted packed-key lists (equal
    power-of-two length L) in 1 + log2(L) passes."""
    return bitonic_merge_sorted((a,), (b,), key_less)[0]


def topk_keys(keys: jax.Array, k_pad: int) -> jax.Array:
    """Ascending lowest-``k_pad`` packed keys of the last axis (any
    width; ``INT_BIG``-padded internally)."""
    return bitonic_topk((keys,), k_pad, key_less, (INT_BIG,))[0]
