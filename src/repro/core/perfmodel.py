"""The paper's analytical performance model (Table I) + our TPU analogue.

Paper cycle model (per ViG layer DIGC):
    DCM: ceil(N/P_row) * ceil(M/P_col) * ceil(D/P_vec)
    LSM: ceil(N/P_sort) * (m * ceil(log2 m))
    GMM: N * k * ceil(log2 Q)
    NSM: ceil(N/Q) * k
Reference config (ViG-Tiny): N=M=196, D=192, k=8, d=2, m=28,
P_row=P_col=14, P_vec=8, P_sort=7, Q=7 -> Table I reports
DCM=4704, LSM=3920, GMM=4704, NSM=224.

The TPU model estimates the same quantities for the Pallas kernel:
MXU cycles for the -2XY^T tile matmuls, VPU cycles for the running
top-kd merge, HBM bytes moved (the paper's DDR-traffic claim).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def clog2(v: int) -> int:
    return max(1, math.ceil(math.log2(max(v, 2))))


@dataclass(frozen=True)
class FPGAConfig:
    """Static parallelism of the paper's accelerator."""

    p_row: int = 14
    p_col: int = 14
    p_vec: int = 8
    p_sort: int = 7
    q: int = 7
    m_part: int = 28  # partition size m


def fpga_cycles(n: int, m: int, d: int, k: int, cfg: FPGAConfig = FPGAConfig()):
    """Paper Table I formulas, verbatim."""
    dcm = ceil_div(n, cfg.p_row) * ceil_div(m, cfg.p_col) * ceil_div(d, cfg.p_vec)
    lsm = ceil_div(n, cfg.p_sort) * (cfg.m_part * clog2(cfg.m_part))
    gmm = n * k * clog2(cfg.q)
    nsm = ceil_div(n, cfg.q) * k
    return {"DCM": dcm, "LSM": lsm, "GMM": gmm, "NSM": nsm}


def fpga_latency_ms(n: int, m: int, d: int, k: int, clock_hz: float = 600e6,
                    cfg: FPGAConfig = FPGAConfig()) -> float:
    """Pipeline latency estimate: modules are deeply pipelined, so total
    time ~ max stage (streaming) + fill; we report the sum as the
    conservative serial bound (matches the paper's per-module table)."""
    cyc = fpga_cycles(n, m, d, k, cfg)
    return sum(cyc.values()) / clock_hz * 1e3


@dataclass(frozen=True)
class TPUConfig:
    """TPU v5e single-core constants (target hardware)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s
    vpu_lanes: int = 8 * 128  # f32 lanes per cycle (one VPU op = 1024 elems)
    clock_hz: float = 940e6
    vmem_bytes: int = 128 * 1024 * 1024


def digc_flops(n: int, m: int, d: int) -> int:
    """FLOPs for the distance computation (the MXU term dominates)."""
    return 2 * n * m * d  # -2XY^T matmul; norm terms are O(ND + MD)


def digc_hbm_bytes(n: int, m: int, d: int, kd: int, *, block_n: int,
                   streaming: bool, with_pos_bias: bool = False,
                   dtype_bytes: int = 4) -> int:
    """External-memory traffic. The paper's central claim: streaming keeps
    traffic at O(ND + MD + N*kd) while the naive path writes + re-reads
    the N*M distance matrix."""
    x_bytes = n * d * dtype_bytes
    # Y is re-read once per node-block sweep (same as a blocked matmul).
    y_sweeps = ceil_div(n, block_n) if streaming else 1
    y_bytes = m * d * dtype_bytes * y_sweeps
    out_bytes = n * kd * (4 + 4)
    p_bytes = n * m * dtype_bytes if with_pos_bias else 0
    traffic = x_bytes + y_bytes + out_bytes + p_bytes
    if not streaming:
        traffic += 2 * n * m * dtype_bytes  # write + read back D_XY for sort
        traffic += 2 * n * m * (4 + 4)  # sort (dist, idx) pairs through memory
    return traffic


def tpu_digc_estimate(n: int, m: int, d: int, k: int, dilation: int,
                      block_n: int = 128, block_m: int = 256,
                      cfg: TPUConfig = TPUConfig(), *,
                      mxu_bf16: bool = False, packed: bool = False,
                      input_bytes: int = 4, bucket_rounds: int = 0):
    """Roofline-style estimate for the fused Pallas DIGC kernel.

    Variant knobs (the §Perf hillclimb levers, all implemented in
    kernels/digc_topk.py and validated in interpret mode):
      * mxu_bf16: bf16 x bf16 -> fp32 MXU contraction: full 197 TF/s;
        the fp32 path runs the MXU at ~1/4 rate.
      * packed:   single int32 (dist|idx) merge keys: ~3 VPU ops per
        candidate per pass vs ~6 for the two-array form.
      * input_bytes: 2 when X/Y are stored bf16 in HBM.
      * bucket_rounds r>0: per-tile bucketed pre-reduction — r min-pass
        sweeps fold bm columns into kd buckets, then the running merge
        touches only r*kd survivors. O(r) passes instead of O(kd);
        recall@kd measured >= 0.99 at r=2 on ViG workloads.
    """
    kd = k * dilation
    flops = digc_flops(n, m, d)
    peak = cfg.peak_flops if mxu_bf16 else cfg.peak_flops / 4
    compute_s = flops / peak
    bytes_moved = digc_hbm_bytes(n, m, d, kd, block_n=block_n,
                                 streaming=True, dtype_bytes=input_bytes)
    memory_s = bytes_moved / cfg.hbm_bw
    # Merge cost: kd extraction sweeps over (block_n, kd + block_m) per tile.
    tiles = ceil_div(n, block_n) * ceil_div(m, block_m)
    ops_per_elem = 3 if packed else 6
    if bucket_rounds > 0:
        sweep = tiles * block_n * block_m * (3 * bucket_rounds - 1)
        fine = tiles * kd * block_n * (kd + bucket_rounds * kd) * 3
        vpu_ops = sweep + fine
    else:
        vpu_ops = tiles * kd * block_n * (kd + block_m) * ops_per_elem
    merge_s = vpu_ops / (cfg.vpu_lanes * cfg.clock_hz)
    naive_bytes = digc_hbm_bytes(n, m, d, kd, block_n=block_n,
                                 streaming=False, dtype_bytes=input_bytes)
    return {
        "flops": flops,
        "compute_s": compute_s,
        "hbm_bytes": bytes_moved,
        "memory_s": memory_s,
        "merge_s": merge_s,
        "bound": max(
            [("compute", compute_s), ("memory", memory_s), ("merge", merge_s)],
            key=lambda t: t[1],
        )[0],
        "latency_s": max(compute_s, memory_s, merge_s),
        "naive_hbm_bytes": naive_bytes,
        "traffic_saving": naive_bytes / bytes_moved,
    }


def vig_resolution_to_nodes(resolution: int, patch: int = 16, reduction: int = 1) -> int:
    side = resolution // patch
    n = side * side
    return n // (reduction * reduction)
