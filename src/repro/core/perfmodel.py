"""The paper's analytical performance model (Table I) + our TPU analogue.

Paper cycle model (per ViG layer DIGC):
    DCM: ceil(N/P_row) * ceil(M/P_col) * ceil(D/P_vec)
    LSM: ceil(N/P_sort) * (m * ceil(log2 m))
    GMM: N * k * ceil(log2 Q)
    NSM: ceil(N/Q) * k
Reference config (ViG-Tiny): N=M=196, D=192, k=8, d=2, m=28,
P_row=P_col=14, P_vec=8, P_sort=7, Q=7 -> Table I reports
DCM=4704, LSM=3920, GMM=4704, NSM=224.

The TPU model estimates the same quantities for the Pallas kernel:
MXU cycles for the -2XY^T tile matmuls, VPU cycles for the running
top-kd merge, HBM bytes moved (the paper's DDR-traffic claim).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def clog2(v: int) -> int:
    return max(1, math.ceil(math.log2(max(v, 2))))


@dataclass(frozen=True)
class FPGAConfig:
    """Static parallelism of the paper's accelerator."""

    p_row: int = 14
    p_col: int = 14
    p_vec: int = 8
    p_sort: int = 7
    q: int = 7
    m_part: int = 28  # partition size m


def fpga_cycles(n: int, m: int, d: int, k: int, cfg: FPGAConfig = FPGAConfig()):
    """Paper Table I formulas, verbatim."""
    dcm = ceil_div(n, cfg.p_row) * ceil_div(m, cfg.p_col) * ceil_div(d, cfg.p_vec)
    lsm = ceil_div(n, cfg.p_sort) * (cfg.m_part * clog2(cfg.m_part))
    gmm = n * k * clog2(cfg.q)
    nsm = ceil_div(n, cfg.q) * k
    return {"DCM": dcm, "LSM": lsm, "GMM": gmm, "NSM": nsm}


def fpga_latency_ms(n: int, m: int, d: int, k: int, clock_hz: float = 600e6,
                    cfg: FPGAConfig = FPGAConfig()) -> float:
    """Pipeline latency estimate: modules are deeply pipelined, so total
    time ~ max stage (streaming) + fill; we report the sum as the
    conservative serial bound (matches the paper's per-module table)."""
    cyc = fpga_cycles(n, m, d, k, cfg)
    return sum(cyc.values()) / clock_hz * 1e3


@dataclass(frozen=True)
class TPUConfig:
    """TPU v5e single-core constants (target hardware)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s
    vpu_lanes: int = 8 * 128  # f32 lanes per cycle (one VPU op = 1024 elems)
    clock_hz: float = 940e6
    vmem_bytes: int = 128 * 1024 * 1024


def digc_flops(n: int, m: int, d: int) -> int:
    """FLOPs for the distance computation (the MXU term dominates)."""
    return 2 * n * m * d  # -2XY^T matmul; norm terms are O(ND + MD)


def digc_hbm_bytes(n: int, m: int, d: int, kd: int, *, block_n: int,
                   streaming: bool, with_pos_bias: bool = False,
                   dtype_bytes: int = 4) -> int:
    """External-memory traffic. The paper's central claim: streaming keeps
    traffic at O(ND + MD + N*kd) while the naive path writes + re-reads
    the N*M distance matrix."""
    x_bytes = n * d * dtype_bytes
    # Y is re-read once per node-block sweep (same as a blocked matmul).
    y_sweeps = ceil_div(n, block_n) if streaming else 1
    y_bytes = m * d * dtype_bytes * y_sweeps
    out_bytes = n * kd * (4 + 4)
    p_bytes = n * m * dtype_bytes if with_pos_bias else 0
    traffic = x_bytes + y_bytes + out_bytes + p_bytes
    if not streaming:
        traffic += 2 * n * m * dtype_bytes  # write + read back D_XY for sort
        traffic += 2 * n * m * (4 + 4)  # sort (dist, idx) pairs through memory
    return traffic


def tpu_digc_estimate(n: int, m: int, d: int, k: int, dilation: int,
                      block_n: int = 128, block_m: int = 256,
                      cfg: TPUConfig = TPUConfig(), *,
                      mxu_bf16: bool = False, packed: bool = False,
                      input_bytes: int = 4, bucket_rounds: int = 0,
                      kernel_merge: str = "legacy"):
    """Roofline-style estimate for the fused Pallas DIGC kernel.

    Variant knobs (the §Perf hillclimb levers, all implemented in
    kernels/digc_topk.py and validated in interpret mode):
      * mxu_bf16: bf16 x bf16 -> fp32 MXU contraction: full 197 TF/s;
        the fp32 path runs the MXU at ~1/4 rate.
      * packed:   single int32 (dist|idx) merge keys: compare-exchange
        is a min/max pair (~1.5 ops/elem/pass) vs the two-array
        predicate+4-select form (~3.5); the legacy extraction passes
        cost ~3 vs ~6 ops/elem/pass for the same reason.
      * input_bytes: 2 when X/Y are stored bf16 in HBM.
      * bucket_rounds r>0 (legacy only): per-tile bucketed pre-reduction
        — r min-pass sweeps fold bm columns into kd buckets, then the
        running merge touches only r*kd survivors. O(r) passes instead
        of O(kd); recall@kd measured >= 0.99 at r=2 on ViG workloads.
      * kernel_merge: "legacy" = kd sequential extraction sweeps over
        (kd + block_m) candidates per tile; "bitonic" = the sorted
        two-level merge — per tile, a local group sort costs
        log2(kd_pad)*(log2(kd_pad)+1)/2 passes over bm elements, the
        tournament reduce a further (log2(kd_pad)+1) amortized passes
        (geometric over rounds), and the GMM fold one (log2(kd_pad)+1)-
        pass merge over kd_pad — so per-element passes drop from
        O(kd) to O(log^2 kd_pad), independent of bm, and stay exact.
    """
    kd = k * dilation
    flops = digc_flops(n, m, d)
    peak = cfg.peak_flops if mxu_bf16 else cfg.peak_flops / 4
    compute_s = flops / peak
    bytes_moved = digc_hbm_bytes(n, m, d, kd, block_n=block_n,
                                 streaming=True, dtype_bytes=input_bytes)
    memory_s = bytes_moved / cfg.hbm_bw
    tiles = ceil_div(n, block_n) * ceil_div(m, block_m)
    if kernel_merge == "bitonic":
        kd_pad = 1 if kd <= 1 else 1 << (kd - 1).bit_length()
        lg = clog2(kd_pad)
        ce_ops = 1.5 if packed else 3.5  # ops per element per CE pass
        local_sort = block_m * (lg * (lg + 1) // 2)  # LSM group sort
        tournament = block_m * (lg + 1)  # geometric sum over rounds
        gmm = kd_pad * (lg + 1)  # one sorted merge per tile
        vpu_ops = tiles * block_n * (local_sort + tournament + gmm) * ce_ops
    elif bucket_rounds > 0:
        sweep = tiles * block_n * block_m * (3 * bucket_rounds - 1)
        fine = tiles * kd * block_n * (kd + bucket_rounds * kd) * 3
        vpu_ops = sweep + fine
    else:
        ops_per_elem = 3 if packed else 6
        vpu_ops = tiles * kd * block_n * (kd + block_m) * ops_per_elem
    merge_s = vpu_ops / (cfg.vpu_lanes * cfg.clock_hz)
    naive_bytes = digc_hbm_bytes(n, m, d, kd, block_n=block_n,
                                 streaming=False, dtype_bytes=input_bytes)
    return {
        "flops": flops,
        "compute_s": compute_s,
        "hbm_bytes": bytes_moved,
        "memory_s": memory_s,
        "merge_s": merge_s,
        "bound": max(
            [("compute", compute_s), ("memory", memory_s), ("merge", merge_s)],
            key=lambda t: t[1],
        )[0],
        "latency_s": max(compute_s, memory_s, merge_s),
        "naive_hbm_bytes": naive_bytes,
        "traffic_saving": naive_bytes / bytes_moved,
    }


def vig_resolution_to_nodes(resolution: int, patch: int = 16, reduction: int = 1) -> int:
    side = resolution // patch
    n = side * side
    return n // (reduction * reduction)


def kernel_tile_defaults(
    n: int, m: int, d: int, kd: int,
    vmem_bytes: int = TPUConfig().vmem_bytes,
) -> tuple[int, int]:
    """Workload-adaptive default (block_n, block_m) for the Pallas kernel.

    Replaces the old hard-coded 128x256: pick the largest MXU-aligned
    tile whose per-instance working set (block_n*D + block_m*D +
    block_n*block_m + 2*block_n*kd floats) fits a double-buffered VMEM
    budget, preferring wider co-node tiles (fewer streaming steps, the
    merge runs once per tile) then taller query tiles.
    """
    budget = vmem_bytes // 8  # double-buffered pipeline, headroom
    best = (128, 256)
    best_score = -1.0
    for bn in (128, 256, 512):
        if bn > max(ceil_div(n, 8) * 8, 8):
            continue
        for bm in (256, 512, 1024, 2048):
            if bm > ceil_div(m, 128) * 128:
                continue
            work = (bn * d + bm * d + bn * bm + 2 * bn * kd) * 4
            if work > budget:
                continue
            score = bm * 2 + bn  # wider co-node tiles first
            if score > best_score:
                best, best_score = (bn, bm), score
    return best


# ---------------------------------------------------------------------------
# XLA streaming-engine cost model (tuner priors)

# Per-backend throughput constants (seconds per unit). These are only
# used to *rank* tile configurations before measurement refines them
# (core/tuner.py), so rough magnitudes suffice; they were fitted to the
# measured CPU decomposition (gemm ~40 GFLOP/s, lax.top_k ~9 ns per
# candidate row-element, fused elementwise lane ~1 ns, tile
# materialization ~0.15 ns/byte).
_ENGINE_CONSTANTS = {
    "cpu": dict(gemm=1 / 40e9, topk=9e-9, lane=1e-9, byte=1.5e-10),
    # TPU: MXU gemm, VPU lanes; top_k lowers to sort — heavily penalized.
    "tpu": dict(gemm=1 / 49e12, topk=2e-9, lane=1e-12, byte=1.2e-12),
}


def engine_cost_estimate(
    n: int,
    m: int,
    d: int,
    kd: int,
    *,
    b: int = 1,
    block_n: int | None = None,
    block_m: int | None = None,
    merge: str = "select",
    fuse_norms: bool = False,
    mxu_bf16: bool = False,
    backend: str = "cpu",
    select_group_w: int = 32,
) -> dict:
    """Analytical cost of one ``stream_topk`` call (seconds, by term).

    Mirrors the engine's actual dataflow: a (block_n x block_m) tile
    grid, a DCM contraction + tile assembly per tile, and the selected
    LSM/GMM merge. ``select`` costs one build pass over each tile plus
    kd O(G + w) rounds; ``topk`` costs a kd-deep selection sweep over
    every candidate (the term that made PR-1's block_m sweep flat);
    ``packed`` costs a pack pass plus kd min/mask passes.
    """
    c = _ENGINE_CONSTANTS.get(backend, _ENGINE_CONSTANTS["cpu"])
    bn = n if block_n is None else min(block_n, n)
    bm = m if block_m is None else min(block_m, m)
    nb_n = ceil_div(n, bn)
    nb_m = ceil_div(m, bm)
    rows = b * nb_n * bn  # padded query rows
    tile_elems = rows * nb_m * bm

    d_eff = d + 2 if fuse_norms else d
    gemm_rate = c["gemm"] / 2 if (mxu_bf16 and backend == "tpu") else c["gemm"]
    gemm_s = 2.0 * tile_elems * d_eff * gemm_rate
    # Tile assembly (norm adds + masks) reads/writes the tile unless the
    # norms were folded into the contraction.
    assembly_s = tile_elems * 4 * c["byte"] * (1 if fuse_norms else 3)

    if merge == "select":
        w = min(select_group_w, bm)
        groups = ceil_div(bm, w)
        build = tile_elems * c["lane"]
        rounds = rows * nb_m * kd * (groups + 2 * w) * c["lane"]
        final = 0.0 if nb_m == 1 else rows * nb_m * kd * c["topk"]
        merge_s = build + rounds + final
    elif merge == "packed":
        # Bitonic two-level merge (core/packedkey networks): group sort
        # + tournament + sorted fold, O(log^2 kd_pad) passes per elem.
        kd_pad = 1 if kd <= 1 else 1 << (kd - 1).bit_length()
        lg = clog2(kd_pad)
        pack = tile_elems * 2 * c["lane"]
        passes = rows * nb_m * (
            bm * (lg * (lg + 1) // 2 + lg + 1) + kd_pad * (lg + 1)
        ) * 1.5 * c["lane"]
        merge_s = pack + passes
    else:  # "topk"
        merge_s = rows * nb_m * (kd + bm) * c["topk"]

    # Per-tile dispatch overhead (scan step launch, slices, transposes).
    overhead_s = nb_n * nb_m * 50e-6 if backend == "cpu" else 0.0
    # Live-tile footprint: tiles that overflow the cache budget (CPU
    # LLC / TPU VMEM headroom) pay re-read traffic on every merge pass.
    live_tile_bytes = b * bn * bm * 4
    budget = 24e6 if backend == "cpu" else 64e6
    spill_s = max(0.0, live_tile_bytes - budget) * nb_n * nb_m * 4 * c["byte"]
    total = gemm_s + assembly_s + merge_s + overhead_s + spill_s
    return {
        "gemm_s": gemm_s,
        "assembly_s": assembly_s,
        "merge_s": merge_s,
        "overhead_s": overhead_s,
        "spill_s": spill_s,
        "total_s": total,
        "live_tile_bytes": live_tile_bytes,
    }


# Interpret-mode emulation constants (fitted to CPU wall-clock): each
# grid program pays a python/XLA dispatch, plus per-element emulated
# vector work. Huge relative to the engine on purpose — the prior must
# keep interpret-mode kernel configs out of the measured top-N on CPU
# while letting compiled TPU configs compete on roofline terms.
_INTERPRET_PROGRAM_S = 2e-3
_INTERPRET_ELEM_S = 2e-8


def kernel_cost_estimate(
    n: int,
    m: int,
    d: int,
    kd: int,
    *,
    b: int = 1,
    block_n: int = 128,
    block_m: int = 256,
    kernel_merge: str = "bitonic",
    packed: bool = False,
    mxu_bf16: bool = False,
    backend: str = "cpu",
    interpret: bool | None = None,
) -> dict:
    """Analytical cost of one fused-kernel DIGC call (tuner priors).

    The engine/kernel choice is a *measured* decision (core/tuner.py);
    this prior only has to rank sensibly: on a TPU backend the cost is
    the roofline ``tpu_digc_estimate`` scaled by batch, everywhere else
    the interpret-mode emulation penalty dominates by construction.
    """
    if interpret is None:
        interpret = backend != "tpu"
    n_pad = ceil_div(n, block_n) * block_n
    m_pad = ceil_div(m, block_m) * block_m
    if interpret:
        programs = b * ceil_div(n, block_n) * ceil_div(m, block_m)
        total = (programs * _INTERPRET_PROGRAM_S
                 + b * n_pad * m_pad * _INTERPRET_ELEM_S)
        return {"total_s": total, "interpret": True, "bound": "interpret"}
    est = tpu_digc_estimate(
        n_pad, m_pad, d, kd, 1, block_n=block_n, block_m=block_m,
        mxu_bf16=mxu_bf16, packed=packed, kernel_merge=kernel_merge,
    )
    return {
        "total_s": est["latency_s"] * b,
        "interpret": False,
        "bound": est["bound"],
    }
