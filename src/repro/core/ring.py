"""Ring-DIGC: the paper's GMM lifted to the pod level (beyond-paper).

Co-node features are sharded across devices along a mesh axis. Each hop,
every device (a) kicks off the ``collective_permute`` that rotates the
co-node shard to its ring neighbor and (b) merges the shard it currently
holds into its running top-(k*d) list. XLA's latency-hiding scheduler
overlaps (a) with (b) — the ICI link plays the role of the FPGA heap's
input streams, the running list plays the heap.

After ``num_devices`` hops every device has seen every co-node shard and
holds the exact global top-(k*d) for its local nodes: no device ever
materializes the full co-node set, so graphs whose co-node features
exceed per-device HBM still construct exactly.

The tier is **batched-first and mesh-native** (DESIGN.md §10): the whole
(B, N, D) batch rides one ``shard_map`` program — the node and co-node
axes shard along ``axis_name`` and an optional ``batch_axis`` shards the
batch rows data-parallel (serving slot rows × ring-sharded co-nodes).

It is also a **stateful builder** (``GraphBuilder.supports_state``): a
``DigcStateEntry`` carrying the co-node squared norms (``sq_y``) rides
the same contract as the blocked tier's frozen-gallery hook, but the
norms live *sharded* — each device selects, inside the shard_map body,
between its carried norm shard (warm) and a fresh shard-local norm pass
(cold), gated per batch row by the entry's ``row_step`` counters. A warm
hop therefore never touches the co-node features for norms at all: only
the (m_loc,) norm shard rotates the ring alongside its feature shard.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.builder import (
    REUSE_KNOBS, DigcSpec, GraphBuilder, promote_batch, register,
)
from repro.core.compat import shard_map as _shard_map
from repro.core.digc import BIG, dilate, merge_topk


def _ring_hops(x_loc, y_loc, sq_loc, *, kd, axis_name, n_dev):
    """The hop loop run on each device inside shard_map.

    x_loc (b, n_loc, D) local node shard; y_loc (b, m_loc, D) local
    co-node shard; sq_loc (b, m_loc) the shard's co-node squared norms
    (already selected warm/cold and BIG-masked on padding — the hop
    loop never recomputes them: norms rotate the ring with their
    feature shard). Returns (dist, idx) of the *global* top-kd, idx in
    global co-node coordinates.
    """
    my = lax.axis_index(axis_name)
    m_loc = y_loc.shape[-2]
    n_loc = x_loc.shape[-2]
    b = x_loc.shape[0]

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    # Hoisted out of the hop loop: the query norms never rotate.
    sq_x = jnp.sum(x_loc * x_loc, -1, keepdims=True)  # (b, n_loc, 1)

    def hop(h, state):
        y_cur, sq_cur, run_d, run_i = state
        # Kick off the rotation first so the permute DMA overlaps the
        # local distance+merge compute below (double buffering). The
        # norm shard rides the same rotation as its feature shard.
        y_next = lax.ppermute(y_cur, axis_name, perm)
        sq_next = lax.ppermute(sq_cur, axis_name, perm)
        # Shard currently held originated at device (my - h) mod n_dev.
        owner = (my.astype(jnp.int32) - h) % n_dev
        off = owner.astype(jnp.int32) * m_loc
        inner = jnp.einsum("bnd,bmd->bnm", x_loc, y_cur)
        d_blk = sq_x - 2.0 * inner + sq_cur[:, None, :]
        blk_i = off + lax.broadcasted_iota(jnp.int32, (b, n_loc, m_loc), 2)
        new_d, new_i = merge_topk(run_d, run_i, d_blk, blk_i, kd)
        return (y_next, sq_next, new_d, new_i)

    init = (
        y_loc,
        sq_loc,
        jnp.full((b, n_loc, kd), BIG, jnp.float32),
        jnp.zeros((b, n_loc, kd), jnp.int32),
    )
    _, _, run_d, run_i = lax.fori_loop(0, n_dev, hop, init)
    return run_d, run_i


def _local_norms(y_loc, sq_loc, valid_loc, *, m, axis_name, live_loc=None):
    """Select this device's co-node norm shard: carried (warm rows) or
    a fresh shard-local pass (cold rows), then BIG-mask padded co-nodes
    so they can never be selected. Runs inside shard_map — the global
    (B, M) norm array is only ever touched one shard at a time, which
    is what lets a ``DigcStateEntry.sq_y`` placed with a
    ``PartitionSpec`` stay resident on its device across requests.
    ``live_loc`` (b, m_loc) extends the same BIG-norm treatment to
    caller-declared pad co-nodes (``m_valid``) — serving's N-bucket pad
    nodes ride the exact masking the ring's own device padding uses."""
    m_loc = y_loc.shape[-2]
    my = lax.axis_index(axis_name)
    gid = my.astype(jnp.int32) * m_loc + jnp.arange(m_loc, dtype=jnp.int32)
    pad = gid >= m  # (m_loc,)
    fresh = jnp.sum(y_loc * y_loc, -1)  # (b, m_loc)
    if sq_loc is None:
        sq = fresh
    else:
        sq = jnp.where(valid_loc[:, None], sq_loc, fresh)
    if live_loc is not None:
        sq = jnp.where(live_loc, sq, jnp.float32(BIG))
    return jnp.where(pad[None, :], jnp.float32(BIG), sq)


def ring_digc(
    x: jax.Array,
    y: Optional[jax.Array] = None,
    *,
    k: int,
    dilation: int = 1,
    mesh: Optional[Mesh] = None,
    axis_name: str = "data",
    batch_axis: Optional[str] = None,
    sq_y: Optional[jax.Array] = None,
    sq_valid: Optional[jax.Array] = None,
    return_dists: bool = False,
    return_norms: bool = False,
    m_valid: Optional[jax.Array] = None,
):
    """Distributed DIGC over a device ring.

    Nodes AND co-nodes are sharded along ``axis_name``; the result
    (B, N, k) arrives sharded over nodes. Exact — bit-identical
    neighbor sets to the single-device reference. Accepts (N, D) or
    (B, N, D): the whole batch rides **one** shard_map program (the
    old per-image unroll is gone), and ``batch_axis`` optionally
    shards the batch rows along a second mesh axis (data-parallel
    rows × ring-sharded co-nodes; B must divide by that axis).

    ``sq_y`` (B, M) carries precomputed co-node squared norms — the
    frozen-gallery hook, same contract as ``digc_blocked(sq_y=)`` but
    sharded: each device reads only its norm shard. ``sq_valid`` is a
    traced () or (B,) bool selecting carried vs freshly-computed norms
    (per batch row with a vector — multi-tenant serving mixes warm and
    cold rows). ``return_norms`` appends the selected (B, M) norms so
    a stateful caller can carry them into its ``DigcStateEntry``.
    ``m_valid`` ((M,) or (B, M) bool) marks live co-nodes: pad lanes
    take the same BIG-norm masking as the ring's internal device
    padding, so serving's N-bucket pad nodes can never enter a top-k
    (carried norms at masked lanes come back BIG — self-consistent for
    a frozen gallery, whose pad set never changes).
    """
    if mesh is None:
        raise ValueError("ring_digc requires an explicit mesh")
    if y is not None and y.ndim == 2 and x.ndim == 3:
        # Shared co-node gallery next to batched nodes (the frozen-
        # gallery spelling): broadcast across the batch, as before the
        # batched-shard_map rewrite.
        y = jnp.broadcast_to(y[None], (x.shape[0],) + y.shape)
    x3, y3, _, squeeze = promote_batch(x, y)
    n_dev = mesh.shape[axis_name]
    b, n, feat = x3.shape
    m = y3.shape[1]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    if batch_axis is not None and b % mesh.shape[batch_axis] != 0:
        raise ValueError(
            f"batch {b} does not divide the {batch_axis!r} mesh axis "
            f"({mesh.shape[batch_axis]} devices)"
        )

    n_pad = _ceil_to(n, n_dev)
    m_pad = _ceil_to(m, n_dev)
    x_p = jnp.pad(x3.astype(jnp.float32), ((0, 0), (0, n_pad - n), (0, 0)))
    # Padded co-nodes are zero rows masked through their *norm* (BIG):
    # distance = |x|^2 - 0 + BIG >= BIG/2, so a pad lane can never
    # displace a real neighbor and the feature rows stay cheap zeros.
    y_p = jnp.pad(y3.astype(jnp.float32), ((0, 0), (0, m_pad - m), (0, 0)))

    stateful = sq_y is not None
    if stateful:
        sq_p = jnp.pad(
            sq_y.astype(jnp.float32), ((0, 0), (0, m_pad - m))
        )
        valid = sq_valid if sq_valid is not None else jnp.bool_(True)
        valid = jnp.broadcast_to(jnp.asarray(valid, bool), (b,))

    live_p = None
    if m_valid is not None:
        live = jnp.asarray(m_valid, bool)
        live = live[None, :] if live.ndim == 1 else live
        live = jnp.broadcast_to(live, (b, m))
        # Pad lanes beyond M are already gid-masked inside the body;
        # padding the caller mask with False keeps the two consistent.
        live_p = jnp.pad(live, ((0, 0), (0, m_pad - m)))

    bspec = batch_axis  # None = batch rows replicated along the ring

    def body_stateless(x_loc, y_loc, live_loc=None):
        sq = _local_norms(
            y_loc, None, None, m=m, axis_name=axis_name, live_loc=live_loc
        )
        return _ring_hops(
            x_loc, y_loc, sq, kd=kd, axis_name=axis_name, n_dev=n_dev
        )

    def body_stateful(x_loc, y_loc, sq_loc, valid_loc, live_loc=None):
        sq = _local_norms(
            y_loc, sq_loc, valid_loc, m=m, axis_name=axis_name,
            live_loc=live_loc,
        )
        run_d, run_i = _ring_hops(
            x_loc, y_loc, sq, kd=kd, axis_name=axis_name, n_dev=n_dev
        )
        return run_d, run_i, sq

    mask_specs = () if live_p is None else (P(bspec, axis_name),)
    mask_args = () if live_p is None else (live_p,)
    if stateful:
        mapped = _shard_map(
            body_stateful,
            mesh,
            in_specs=(
                P(bspec, axis_name, None),
                P(bspec, axis_name, None),
                P(bspec, axis_name),
                P(bspec),
            ) + mask_specs,
            out_specs=(
                P(bspec, axis_name, None),
                P(bspec, axis_name, None),
                P(bspec, axis_name),
            ),
        )
        run_d, run_i, sq_out = mapped(x_p, y_p, sq_p, valid, *mask_args)
    else:
        mapped = _shard_map(
            body_stateless,
            mesh,
            in_specs=(
                P(bspec, axis_name, None),
                P(bspec, axis_name, None),
            ) + mask_specs,
            out_specs=(P(bspec, axis_name, None), P(bspec, axis_name, None)),
        )
        run_d, run_i = mapped(x_p, y_p, *mask_args)
        sq_out = None

    run_d = run_d[:, :n]
    run_i = run_i[:, :n]
    idx = dilate(run_i, dilation)
    dist = dilate(run_d, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    out = (idx, dist) if return_dists else (idx,)
    if return_norms:
        # The selected norms, pad lanes sliced off: exactly what the
        # next warm call's entry should carry. BIG pad masking lives
        # only beyond [:m], so the carried values are the true norms.
        norms = None if sq_out is None else sq_out[:, :m]
        out = out + (norms,)
    return out if len(out) > 1 else out[0]


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


# --------------------------------------------------------------------------
# Registry entry (DESIGN.md §4, §10).


def _build_ring(x, y, pos_bias, spec: DigcSpec, state_entry=None,
                m_valid=None):
    del pos_bias  # validated unsupported upstream
    common = dict(
        k=spec.k, dilation=spec.dilation, mesh=spec.mesh,
        axis_name=spec.axis_name if spec.axis_name is not None else "data",
        batch_axis=spec.batch_axis,
        return_dists=True,
        m_valid=m_valid,
    )
    if state_entry is None:
        return ring_digc(x, y, **common)
    # Functional form: same frozen-gallery contract as the blocked tier
    # (state.py invalidation rules) — the entry's sq_y asserts the
    # co-node set identified by its key is frozen, so it only engages
    # for explicit co-nodes of the matching shape. Self-graph calls
    # (y=None: co-nodes are this call's features, drifting every call)
    # advance the counters but never carry norms. Warm/cold is a
    # runtime value, per batch row when the entry carries row_step.
    if (
        y is not None
        and state_entry.sq_y is not None
        and state_entry.sq_y.shape == y.shape[:-1]
    ):
        valid = (
            state_entry.row_warm
            if state_entry.row_step is not None
            else state_entry.warm
        )
        idx, dist, norms = ring_digc(
            x, y, sq_y=state_entry.sq_y, sq_valid=valid,
            return_norms=True, **common,
        )
        return idx, dist, state_entry.bump(sq_y=norms)
    idx, dist = ring_digc(x, y, **common)
    return idx, dist, state_entry.bump()


register(GraphBuilder(
    name="ring",
    build=_build_ring,
    knobs=frozenset({"mesh", "axis_name", "batch_axis"}) | REUSE_KNOBS,
    exact=True,
    distributed=True,
    supports_state=True,  # sharded co-node norms via DigcState entries
    supports_pad=True,  # m_valid rides the same BIG-norm mask as device pads
    doc="pod-level GMM: co-node shards rotate a device ring "
        "(requires mesh= knob; batch_axis= shards rows data-parallel; "
        "stateful — carries sharded frozen-gallery norms)",
))
