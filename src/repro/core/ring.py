"""Ring-DIGC: the paper's GMM lifted to the pod level (beyond-paper).

Co-node features are sharded across devices along a mesh axis. Each hop,
every device (a) kicks off the ``collective_permute`` that rotates the
co-node shard to its ring neighbor and (b) merges the shard it currently
holds into its running top-(k*d) list. XLA's latency-hiding scheduler
overlaps (a) with (b) — the ICI link plays the role of the FPGA heap's
input streams, the running list plays the heap.

After ``num_devices`` hops every device has seen every co-node shard and
holds the exact global top-(k*d) for its local nodes: no device ever
materializes the full co-node set, so graphs whose co-node features
exceed per-device HBM still construct exactly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.builder import DigcSpec, GraphBuilder, register
from repro.core.compat import shard_map as _shard_map
from repro.core.digc import BIG, dilate, merge_topk


def ring_digc_local(
    x_loc: jax.Array,
    y_loc: jax.Array,
    *,
    kd: int,
    axis_name: str,
    n_dev: int,
) -> tuple[jax.Array, jax.Array]:
    """Body run on each device inside shard_map.

    x_loc: (n_loc, D) local node shard; y_loc: (m_loc, D) local co-node
    shard. Returns (dist, idx) of the *global* top-kd, idx in global
    co-node coordinates. Must be called with equal shard sizes (the
    public wrapper pads).
    """
    my = lax.axis_index(axis_name)
    m_loc = y_loc.shape[0]
    n_loc = x_loc.shape[0]

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def hop(h, state):
        y_cur, run_d, run_i = state
        # Kick off the rotation first so the permute DMA overlaps the
        # local distance+merge compute below (double buffering).
        y_next = lax.ppermute(y_cur, axis_name, perm)
        # Shard currently held originated at device (my - h) mod n_dev.
        owner = (my.astype(jnp.int32) - h) % n_dev
        off = owner.astype(jnp.int32) * m_loc
        d_blk = (
            jnp.sum(x_loc * x_loc, -1, keepdims=True)
            - 2.0 * (x_loc @ y_cur.T)
            + jnp.sum(y_cur * y_cur, -1)[None, :]
        )
        blk_i = off + lax.broadcasted_iota(jnp.int32, (n_loc, m_loc), 1)
        new_d, new_i = merge_topk(run_d, run_i, d_blk, blk_i, kd)
        return (y_next, new_d, new_i)

    init = (
        y_loc.astype(jnp.float32),
        jnp.full((n_loc, kd), BIG, jnp.float32),
        jnp.zeros((n_loc, kd), jnp.int32),
    )
    _, run_d, run_i = lax.fori_loop(0, n_dev, hop, init)
    return run_d, run_i


def ring_digc(
    x: jax.Array,
    y: Optional[jax.Array] = None,
    *,
    k: int,
    dilation: int = 1,
    mesh: Optional[Mesh] = None,
    axis_name: str = "data",
    return_dists: bool = False,
):
    """Distributed DIGC over a device ring.

    Nodes AND co-nodes are sharded along ``axis_name``; the result
    (N, k) arrives sharded over nodes. Exact — bit-identical neighbor
    sets to the single-device reference.
    """
    if y is None:
        y = x
    if mesh is None:
        raise ValueError("ring_digc requires an explicit mesh")
    if x.ndim == 3:
        # Batched: each image's ring pass is an independent shard_map
        # program; B is static, so unroll (the node axis, not the batch
        # axis, is what the ring shards).
        y3 = y if y.ndim == 3 else jnp.broadcast_to(y[None], (x.shape[0],) + y.shape)
        outs = [
            ring_digc(x[b], y3[b], k=k, dilation=dilation, mesh=mesh,
                      axis_name=axis_name, return_dists=True)
            for b in range(x.shape[0])
        ]
        idx = jnp.stack([o[0] for o in outs])
        dist = jnp.stack([o[1] for o in outs])
        return (idx, dist) if return_dists else idx
    n_dev = mesh.shape[axis_name]
    n, feat = x.shape
    m = y.shape[0]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")

    n_pad = _ceil_to(n, n_dev)
    m_pad = _ceil_to(m, n_dev)
    x_p = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    y_p = jnp.pad(y.astype(jnp.float32), ((0, m_pad - m), (0, 0)))
    # Mask padded co-nodes by pushing them far away: a +BIG feature-norm
    # cannot be expressed post-hoc, so instead overwrite padded rows with
    # a large constant vector (distance to anything real ~ D * BIG^2...
    # use sqrt(BIG) to stay finite in fp32).
    if m_pad != m:
        pad_rows = jnp.arange(m_pad) >= m
        y_p = jnp.where(pad_rows[:, None], jnp.float32(1e15), y_p)

    body = functools.partial(
        ring_digc_local, kd=kd, axis_name=axis_name, n_dev=n_dev
    )
    mapped = _shard_map(
        body,
        mesh,
        in_specs=(P(axis_name, None), P(axis_name, None)),
        out_specs=(P(axis_name, None), P(axis_name, None)),
    )
    run_d, run_i = mapped(x_p, y_p)
    run_d = run_d[:n]
    run_i = run_i[:n]
    idx = dilate(run_i, dilation)
    if return_dists:
        return idx, dilate(run_d, dilation)
    return idx


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


# --------------------------------------------------------------------------
# Registry entry (DESIGN.md §4).


def _build_ring(x, y, pos_bias, spec: DigcSpec):
    del pos_bias  # validated unsupported upstream
    return ring_digc(
        x, y, k=spec.k, dilation=spec.dilation, mesh=spec.mesh,
        axis_name=spec.axis_name if spec.axis_name is not None else "data",
        return_dists=True,
    )


register(GraphBuilder(
    name="ring",
    build=_build_ring,
    knobs=frozenset({"mesh", "axis_name"}),
    exact=True,
    distributed=True,
    doc="pod-level GMM: co-node shards rotate a device ring "
        "(requires mesh= knob)",
))
