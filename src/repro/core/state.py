"""Functional DIGC state (DESIGN.md §7): the jit-native successor to
the host-side ``DigcCache``.

The paper's FPGA accelerator keeps its construction state (stream
buffers, heap contents) resident on-chip across layers. Our analogue —
cluster centroids for k-means warm starts, co-node norms for a frozen
gallery — used to live in a mutable host-side ``DigcCache``, which by
design never engages under tracing; serving the cache-aware tiers
therefore meant running them *eager*. ``DigcState`` makes that state an
explicit pytree value instead: it is threaded in-and-out of ``digc()``
(``digc(..., state=, state_key=) -> (idx, new_state)``), through
``vig_forward``, and through a single donated ``jax.jit`` in
``serve.VigServeEngine`` — warm starts now work *inside* compiled
serving, and the buffers are donated so the state updates in place.

Layout: ``DigcState.entries`` maps a caller-chosen key (e.g. the model
stage name) to a ``DigcStateEntry``:

  * ``step``      — () int32 call counter. 0 means cold: builders gate
    their warm-start paths on ``step > 0`` via ``lax.cond``, so the
    pytree structure is identical on every call (a jit requirement) and
    validity is a *runtime* value, not a trace-time one.
  * ``centroids`` — (B, C, D) k-means centroids (the cluster tier's
    warm start), or None for builders without them.
  * ``sq_y``      — (B, M) co-node squared norms (the blocked tier's
    frozen-gallery hook), or None.

Invalidation rules (who may reuse what):

  * The pytree *structure* is fixed at init time (``DigcState.init`` /
    ``models.vig.init_vig_state``); entries are never created on the
    fly — a builder given no entry for its key computes statelessly and
    the state passes through unchanged.
  * Entry shapes are part of the compiled program: a workload change
    (batch, cluster count, co-node count) requires re-init. Builders
    check shapes *statically* and fall back to a cold build on
    mismatch rather than reading stale-shaped state.
  * ``centroids`` are drift-tolerant (an approximate tier's init):
    reuse across layers of a stage and across requests is safe.
    ``sq_y`` must match the co-node *contents* exactly: an entry with
    ``sq_y`` asserts the gallery identified by its key is frozen — the
    caller must re-init the state when the gallery version changes.

Why donation matters: serving threads the same state pytree through
every request (`state -> forward -> new state -> forward -> ...`).
Donating the argument lets XLA write the new centroids into the old
buffers, so steady-state serving allocates nothing for DIGC state and
the update is a true in-place carry — the compiled analogue of the
paper's on-chip residency.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DigcStateEntry:
    """Per-key functional construction state (see module docstring)."""

    step: jax.Array  # () int32; 0 = cold
    centroids: Optional[jax.Array] = None  # (B, C, D) | None
    sq_y: Optional[jax.Array] = None  # (B, M) | None

    @property
    def warm(self) -> jax.Array:
        """Traced bool: has this entry been written at least once?"""
        return self.step > 0

    def bump(self, **updates) -> "DigcStateEntry":
        """Functional update: advance the call counter, replace fields."""
        return dataclasses.replace(self, step=self.step + 1, **updates)


def state_entry(
    *,
    centroids_shape: Optional[tuple[int, ...]] = None,
    sq_y_shape: Optional[tuple[int, ...]] = None,
    dtype=jnp.float32,
) -> DigcStateEntry:
    """A cold entry with zero-initialized buffers of the given shapes.

    The zeros are never *read* as values — ``step == 0`` routes every
    builder to its cold path — they only fix the pytree leaves so the
    first and the thousandth call share one compiled program.
    """
    return DigcStateEntry(
        step=jnp.zeros((), jnp.int32),
        centroids=(
            None if centroids_shape is None
            else jnp.zeros(centroids_shape, dtype)
        ),
        sq_y=None if sq_y_shape is None else jnp.zeros(sq_y_shape, jnp.float32),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DigcState:
    """Keyed collection of ``DigcStateEntry`` — the value threaded
    through ``digc()`` / ``vig_forward`` / ``VigServeEngine``."""

    entries: dict[str, DigcStateEntry]

    @classmethod
    def init(cls, entries: Optional[dict[str, DigcStateEntry]] = None):
        return cls(entries=dict(entries or {}))

    def get(self, key: Optional[str]) -> Optional[DigcStateEntry]:
        if key is None:
            return None
        return self.entries.get(key)

    def set(self, key: str, entry: DigcStateEntry) -> "DigcState":
        return DigcState(entries={**self.entries, key: entry})

    def steps(self) -> dict[str, int]:
        """Host-side view of the per-key call counters (concrete only)."""
        return {k: int(e.step) for k, e in self.entries.items()}

    def __len__(self) -> int:
        return len(self.entries)
