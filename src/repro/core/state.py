"""Functional DIGC state (DESIGN.md §7): the jit-native successor to
the host-side ``DigcCache``.

The paper's FPGA accelerator keeps its construction state (stream
buffers, heap contents) resident on-chip across layers. Our analogue —
cluster centroids for k-means warm starts, co-node norms for a frozen
gallery — used to live in a mutable host-side ``DigcCache``, which by
design never engages under tracing; serving the cache-aware tiers
therefore meant running them *eager*. ``DigcState`` makes that state an
explicit pytree value instead: it is threaded in-and-out of ``digc()``
(``digc(..., state=, state_key=) -> (idx, new_state)``), through
``vig_forward``, and through a single donated ``jax.jit`` in
``serve.VigServeEngine`` — warm starts now work *inside* compiled
serving, and the buffers are donated so the state updates in place.

Layout: ``DigcState.entries`` maps a caller-chosen key (e.g. the model
stage name) to a ``DigcStateEntry``:

  * ``step``      — () int32 call counter. 0 means cold: builders gate
    their warm-start paths on ``step > 0`` via ``lax.cond``, so the
    pytree structure is identical on every call (a jit requirement) and
    validity is a *runtime* value, not a trace-time one.
  * ``centroids`` — (B, C, D) k-means centroids (the cluster tier's
    warm start), or None for builders without them.
  * ``sq_y``      — (B, M) co-node squared norms (the blocked tier's
    frozen-gallery hook), or None.
  * ``row_step``  — optional (B,) int32 **per-row** call counters for
    multi-tenant serving (DESIGN.md §9): when present, builders gate
    warm/cold *per batch row* instead of per entry, so a batch may mix
    a warm tenant (row carried from its previous request) with a cold
    one (row just reset on slot admission) without either leaking into
    the other. Absent (None) on single-tenant state: the scalar
    ``step`` gate applies to the whole batch, the PR-3 behavior.
  * ``graph_idx`` / ``graph_dist`` / ``graph_snap`` / ``graph_age`` —
    the stale-graph serving buffers (DESIGN.md §12): the cached
    (B, N, k) graph last built for this entry, the (B,) per-row feature
    statistic it was built from, and the (B,) staleness age in gated
    calls. Allocated together via ``state_entry(graph_shape=)``; the
    drift-gated reuse policies (``DigcSpec.reuse``) serve the cached
    graph when drift stays under ``drift_tau`` and the age under
    ``max_stale``, rebuilding otherwise.

Invalidation rules (who may reuse what):

  * The pytree *structure* is fixed at init time (``DigcState.init`` /
    ``models.vig.init_vig_state``); entries are never created on the
    fly — a builder given no entry for its key computes statelessly and
    the state passes through unchanged.
  * Entry shapes are part of the compiled program: a workload change
    (batch, cluster count, co-node count) requires re-init. Builders
    check shapes *statically* and fall back to a cold build on
    mismatch rather than reading stale-shaped state.
  * ``centroids`` are drift-tolerant (an approximate tier's init):
    reuse across layers of a stage and across requests is safe.
    ``sq_y`` must match the co-node *contents* exactly: an entry with
    ``sq_y`` asserts the gallery identified by its key is frozen — the
    caller must re-init the state when the gallery version changes.
  * Cached graphs invalidate through three independent guards: a
    *static* shape check (a workload change means the buffers never
    engage), the *runtime* drift gate (``graph_snap`` vs the current
    feature statistic), and the staleness bound (``graph_age`` vs
    ``max_stale``). Only ``digc()``'s reuse path writes them.
  * Row reuse is **per tenant** (multi-tenant serving): a state row may
    only warm-start requests of the tenant that wrote it. The serving
    engine enforces this with ``take_rows`` / ``put_rows`` /
    ``reset_rows`` — a slot reassigned to a new tenant has its rows
    reset (``row_step`` 0 ⇒ cold), and padding lanes of a bucketed
    batch are never scattered back, so they cannot clobber live rows.

Why donation matters: serving threads the same state pytree through
every request (`state -> forward -> new state -> forward -> ...`).
Donating the argument lets XLA write the new centroids into the old
buffers, so steady-state serving allocates nothing for DIGC state and
the update is a true in-place carry — the compiled analogue of the
paper's on-chip residency.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _like_sharding(src, new):
    """Re-place ``new`` with ``src``'s NamedSharding (eager row ops on
    sharded entries must not silently collapse a device-resident buffer
    onto the default device — DESIGN.md §10 state placement).

    A no-op under tracing (jit propagates shardings itself), for
    unsharded arrays, and when the row op changed the partitioned
    dimension itself (a take/put only ever changes the *row* axis,
    which serving keeps unpartitioned)."""
    if isinstance(new, jax.core.Tracer) or isinstance(src, jax.core.Tracer):
        return new
    sharding = getattr(src, "sharding", None)
    if not isinstance(sharding, jax.sharding.NamedSharding):
        return new
    try:
        return jax.device_put(new, sharding)
    except (ValueError, TypeError):  # shape no longer placeable: keep
        return new


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DigcStateEntry:
    """Per-key functional construction state (see module docstring)."""

    step: jax.Array  # () int32; 0 = cold
    centroids: Optional[jax.Array] = None  # (B, C, D) | None
    sq_y: Optional[jax.Array] = None  # (B, M) | None
    row_step: Optional[jax.Array] = None  # (B,) int32 | None; 0 = cold row
    # -- stale-graph serving buffers (DESIGN.md §12) --------------------
    # The cached, versioned graph artifact the drift-gated reuse
    # policies serve (``DigcSpec.reuse``): the last built (idx, dist)
    # pair, the per-row feature statistic it was built from, and the
    # per-row staleness age (gated calls since the last rebuild).
    # Validity rides ``row_step``/``step`` like every other buffer: a
    # cold row's cached graph is never read.
    graph_idx: Optional[jax.Array] = None  # (B, N, k) int32 | None
    graph_dist: Optional[jax.Array] = None  # (B, N, k) f32 | None
    graph_snap: Optional[jax.Array] = None  # (B,) f32 drift snapshot | None
    graph_age: Optional[jax.Array] = None  # (B,) int32; 0 = just built

    @property
    def warm(self) -> jax.Array:
        """Traced bool: has this entry been written at least once?"""
        return self.step > 0

    @property
    def row_warm(self) -> Optional[jax.Array]:
        """Traced (B,) bool: which rows have been written at least once.
        None when the entry carries no per-row counters."""
        if self.row_step is None:
            return None
        return self.row_step > 0

    def bump(self, **updates) -> "DigcStateEntry":
        """Functional update: advance the call counter(s), replace
        fields. ``row_step`` (when present) advances for every row —
        the serving engine discards padding lanes on scatter, so only
        live rows' counters persist."""
        if self.row_step is not None and "row_step" not in updates:
            updates["row_step"] = self.row_step + 1
        return dataclasses.replace(self, step=self.step + 1, **updates)

    # -- per-slot row lifecycle (multi-tenant serving, DESIGN.md §9) ----

    def _row_fields(self):
        # Every per-row buffer: the take/put/reset lifecycle, the crc32
        # integrity fingerprints and the finiteness screen all iterate
        # this tuple, so the cached-graph buffers get the same coverage
        # as the warm-start buffers by construction (DESIGN.md §11/§12).
        return (
            "centroids", "sq_y", "row_step",
            "graph_idx", "graph_dist", "graph_snap", "graph_age",
        )

    def take_rows(self, rows) -> "DigcStateEntry":
        """Gather batch rows: entry over rows ``rows`` (any index array/
        sequence; repeats allowed — padding lanes replicate a live
        row). The scalar ``step`` is copied, not aliased: the taken
        entry is typically donated into a jit, and an aliased buffer
        would invalidate the source entry's counter on real backends."""
        rows = jnp.asarray(rows, jnp.int32)
        updates = {
            f: _like_sharding(getattr(self, f), getattr(self, f)[rows])
            for f in self._row_fields() if getattr(self, f) is not None
        }
        updates["step"] = self.step + 0
        return dataclasses.replace(self, **updates)

    def put_rows(self, src: "DigcStateEntry", rows) -> "DigcStateEntry":
        """Scatter ``src``'s leading rows back: row ``i`` of ``src``
        lands at ``rows[i]`` of self. ``src`` rows beyond ``len(rows)``
        (padding lanes) are dropped — they can never clobber live rows.
        The scalar ``step`` is taken from ``src`` (the served entry)."""
        rows = jnp.asarray(rows, jnp.int32)
        n = rows.shape[0]
        updates = {"step": jnp.asarray(src.step)}
        for f in self._row_fields():
            dst_v, src_v = getattr(self, f), getattr(src, f)
            if dst_v is None or src_v is None:
                continue
            src_v = jnp.asarray(src_v)  # parked host rows re-materialize
            updates[f] = _like_sharding(dst_v, dst_v.at[rows].set(src_v[:n]))
        return dataclasses.replace(self, **updates)

    def reset_rows(self, rows) -> "DigcStateEntry":
        """Zero the given rows (cold: ``row_step`` 0 routes builders to
        their cold path; the zeroed buffers are never read as values).
        Called when a slot is reassigned to a new tenant, so warm state
        never leaks across tenants."""
        rows = jnp.asarray(rows, jnp.int32)
        updates = {}
        for f in self._row_fields():
            v = getattr(self, f)
            if v is None:
                continue
            updates[f] = _like_sharding(
                v, v.at[rows].set(jnp.zeros((), v.dtype))
            )
        return dataclasses.replace(self, **updates)


# -- state-integrity guards (fault-tolerant serving, DESIGN.md §11) --------
#
# The serving engine trusts its slot rows because every write goes
# through the sanctioned lifecycle above. A bit flip (host memory, a
# buggy injector, a bad device) bypasses that lifecycle — so the engine
# keeps a cheap per-row fingerprint of every slot row, recomputed after
# each sanctioned write and checked before each read. These helpers are
# host-side by construction (they hash concrete bytes); calling them on
# tracers is an error the engine never commits.


def entry_row_fingerprint(entry: DigcStateEntry, row: int) -> int:
    """crc32 over one row's bytes across every per-row buffer.

    Cheap (a few KB per row), deterministic, and sensitive to any bit
    of ``centroids`` / ``sq_y`` / ``row_step`` — a mismatch against the
    token recorded at the last sanctioned write means the row was
    mutated outside the lifecycle and must be cold-reset.
    """
    h = 0
    for f in entry._row_fields():
        v = getattr(entry, f)
        if v is None:
            continue
        h = zlib.crc32(np.ascontiguousarray(np.asarray(v[row])).tobytes(), h)
    return h


def entry_row_finite(entry: DigcStateEntry, row: int) -> bool:
    """True when every float buffer of ``row`` is finite. A NaN/Inf in
    a warm row poisons every later request of its tenant (warm starts
    feed it back) — the engine screens served rows each tick."""
    for f in entry._row_fields():
        v = getattr(entry, f)
        if v is None:
            continue
        host = np.asarray(v[row])
        if np.issubdtype(host.dtype, np.floating) and not np.isfinite(host).all():
            return False
    return True


def prefetch_park_rows(host_rows):
    """Start the host->device upload of parked rows ahead of the tick
    that binds them (prefetched parking restore, DESIGN.md §14).

    ``host_rows`` is what ``VigServeEngine._park`` stored: a
    ``DigcState`` of single-row entries with numpy leaves (or a
    ``{size: DigcState}`` dict on the multi-resolution lattice). The
    structure is preserved exactly — only the numpy leaves move to
    device via ``jax.device_put`` (asynchronous on real accelerator
    backends), so ``put_rows``'s ``jnp.asarray`` at bind time finds the
    transfer already done (or in flight) instead of paying it on the
    tick's critical path. Purely a placement change: the device values
    are bit-identical to a bind-time upload, and the engine's §11
    integrity screens still run against whatever rows end up bound."""
    return jax.tree_util.tree_map(
        lambda v: jax.device_put(v) if isinstance(v, np.ndarray) else v,
        host_rows,
    )


def state_entry(
    *,
    centroids_shape: Optional[tuple[int, ...]] = None,
    sq_y_shape: Optional[tuple[int, ...]] = None,
    graph_shape: Optional[tuple[int, int, int]] = None,
    dtype=jnp.float32,
    rows: Optional[int] = None,
    mesh=None,
    axis_name: str = "data",
) -> DigcStateEntry:
    """A cold entry with zero-initialized buffers of the given shapes.

    The zeros are never *read* as values — ``step == 0`` routes every
    builder to its cold path — they only fix the pytree leaves so the
    first and the thousandth call share one compiled program.

    ``rows`` allocates (rows,) per-row counters (``row_step``) for
    multi-tenant serving: warm/cold becomes a per-batch-row value and
    the ``take_rows``/``put_rows``/``reset_rows`` lifecycle applies.

    ``mesh`` places the entry for sharded construction (DESIGN.md §10):
    ``sq_y`` — the ring tier's per-shard co-node norms — is partitioned
    along ``axis_name`` on its co-node dimension (each device owns the
    norm shard its ``shard_map`` body reads/writes), while the
    counters and centroids are replicated across the mesh (they are
    per-row values every device needs). Entries placed this way stay
    device-resident through the row lifecycle: ``take_rows`` /
    ``put_rows`` / ``reset_rows`` re-place their results with the
    source buffer's sharding.
    """
    graph_b = None if graph_shape is None else graph_shape[0]
    entry = DigcStateEntry(
        step=jnp.zeros((), jnp.int32),
        centroids=(
            None if centroids_shape is None
            else jnp.zeros(centroids_shape, dtype)
        ),
        sq_y=None if sq_y_shape is None else jnp.zeros(sq_y_shape, jnp.float32),
        row_step=None if rows is None else jnp.zeros((rows,), jnp.int32),
        # ``graph_shape`` (B, N, k) allocates the stale-graph buffers
        # (DESIGN.md §12): cached (idx, dist), the per-row drift
        # snapshot and the staleness age. Like every other buffer the
        # zeros are structure, not values — a cold row rebuilds.
        graph_idx=(
            None if graph_shape is None else jnp.zeros(graph_shape, jnp.int32)
        ),
        graph_dist=(
            None if graph_shape is None
            else jnp.zeros(graph_shape, jnp.float32)
        ),
        graph_snap=(
            None if graph_shape is None else jnp.zeros((graph_b,), jnp.float32)
        ),
        graph_age=(
            None if graph_shape is None else jnp.zeros((graph_b,), jnp.int32)
        ),
    )
    if mesh is None:
        return entry
    if axis_name not in mesh.shape:
        raise ValueError(
            f"state_entry placement axis {axis_name!r} is not an axis "
            f"of the mesh (axes: {tuple(mesh.shape)}); pass the mesh's "
            "co-node ring axis as axis_name="
        )
    from jax.sharding import NamedSharding, PartitionSpec

    def place(v, spec):
        return None if v is None else jax.device_put(
            v, NamedSharding(mesh, spec)
        )

    sq_spec = PartitionSpec(None, axis_name)
    if (
        entry.sq_y is not None
        and entry.sq_y.shape[-1] % mesh.shape[axis_name] != 0
    ):
        # A ragged co-node count still *works* sharded (the ring pads
        # internally) but cannot be device_put along the axis;
        # replicate — placement is a performance choice, never a
        # semantic one.
        sq_spec = PartitionSpec()
    return dataclasses.replace(
        entry,
        step=place(entry.step, PartitionSpec()),
        centroids=place(entry.centroids, PartitionSpec()),
        sq_y=place(entry.sq_y, sq_spec),
        row_step=place(entry.row_step, PartitionSpec()),
        # Cached graphs are per-row values every device reads whole
        # (the reuse gate selects per batch row, not per shard):
        # replicate, like the centroids.
        graph_idx=place(entry.graph_idx, PartitionSpec()),
        graph_dist=place(entry.graph_dist, PartitionSpec()),
        graph_snap=place(entry.graph_snap, PartitionSpec()),
        graph_age=place(entry.graph_age, PartitionSpec()),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DigcState:
    """Keyed collection of ``DigcStateEntry`` — the value threaded
    through ``digc()`` / ``vig_forward`` / ``VigServeEngine``.

    Entry row buffers have one static N (node count), so the
    multi-resolution engine (DESIGN.md §13) keeps one ``DigcState``
    per N-bucket and keys the §9-§12 row lifecycle — take/put/reset
    rows, parking, quarantine, cached graphs — by (slot, N-bucket):
    a slot's 224-cell rows and 448-cell rows are independent carries
    of the same tenant."""

    entries: dict[str, DigcStateEntry]

    @classmethod
    def init(cls, entries: Optional[dict[str, DigcStateEntry]] = None):
        return cls(entries=dict(entries or {}))

    def get(self, key: Optional[str]) -> Optional[DigcStateEntry]:
        if key is None:
            return None
        return self.entries.get(key)

    def set(self, key: str, entry: DigcStateEntry) -> "DigcState":
        return DigcState(entries={**self.entries, key: entry})

    def steps(self) -> dict[str, int]:
        """Host-side view of the per-key call counters (concrete only)."""
        return {k: int(e.step) for k, e in self.entries.items()}

    def row_steps(self) -> dict[str, list[int]]:
        """Host-side view of per-row counters (keys carrying them)."""
        return {
            k: [int(v) for v in e.row_step]
            for k, e in self.entries.items() if e.row_step is not None
        }

    # -- per-slot row lifecycle (multi-tenant serving, DESIGN.md §9) ----

    def take_rows(self, rows) -> "DigcState":
        """Gather batch rows from every entry (slot rows -> bucket
        lanes; repeats allowed for padding lanes)."""
        return DigcState(entries={
            k: e.take_rows(rows) for k, e in self.entries.items()
        })

    def put_rows(self, src: "DigcState", rows) -> "DigcState":
        """Scatter ``src``'s leading rows into every entry at ``rows``
        (bucket lanes -> slot rows; src rows beyond ``len(rows)`` —
        padding lanes — are dropped)."""
        return DigcState(entries={
            k: e.put_rows(src.entries[k], rows)
            for k, e in self.entries.items()
        })

    def reset_rows(self, rows) -> "DigcState":
        """Cold-reset the given rows in every entry (slot reassigned to
        a new tenant)."""
        return DigcState(entries={
            k: e.reset_rows(rows) for k, e in self.entries.items()
        })

    # -- integrity guards (fault-tolerant serving, DESIGN.md §11) -------

    def row_fingerprints(self, rows) -> dict[str, dict[int, int]]:
        """Per-entry integrity tokens for the given slot rows.

        Batched variant of ``entry_row_fingerprint``: each per-row
        buffer crosses to host ONCE per call, not once per row — the
        engine checks/refreshes several lanes per tick, and the
        device->host sync (not the crc) is the guard's real cost."""
        out: dict[str, dict[int, int]] = {}
        for k, e in self.entries.items():
            tokens = {int(r): 0 for r in rows}
            for f in e._row_fields():
                v = getattr(e, f)
                if v is None:
                    continue
                host = np.ascontiguousarray(np.asarray(v))
                for r in tokens:
                    tokens[r] = zlib.crc32(host[r].tobytes(), tokens[r])
            out[k] = tokens
        return out

    def rows_finite(self, rows) -> dict[int, bool]:
        """Which of the given slot rows are finite across every entry
        (host-side, one transfer per buffer; per-row semantics of
        ``entry_row_finite``)."""
        finite = {int(r): True for r in rows}
        for e in self.entries.values():
            for f in e._row_fields():
                v = getattr(e, f)
                if v is None:
                    continue
                host = np.asarray(v)
                if not np.issubdtype(host.dtype, np.floating):
                    continue
                for r in finite:
                    if finite[r] and not np.isfinite(host[r]).all():
                        finite[r] = False
        return finite

    def __len__(self) -> int:
        return len(self.entries)
