"""Alternative graph-construction strategies (paper §VI conclusion:
"the graph construction approach can be generalized by adjusting the
mechanism used to compute similarity ... clustering-based approaches
exemplified by ClusterViG and greedy edge-selection techniques used in
GreedyViG").

Both reuse the DIGC substrate (blocked distance + top-k merge), keep
static shapes (TPU-compilable), and are batched-first — (B, N, D) in,
(B, N, k) out, with (N, D) promoted to B=1:

  * ``cluster_digc`` — IVF-style two-stage search (ClusterViG family):
    k-means centroids over co-nodes, queries probe only the n_probe
    nearest clusters. O(N·(C + probe·cap)·D) vs O(N·M·D).
  * ``axial_digc``   — GreedyViG-family axial construction: candidates
    restricted to the query's grid row + column. O(N·(H+W)·D).

Approximate by design; recall measured in tests/benchmarks. Both are
registered GraphBuilders (DESIGN.md §4), peers of the exact tiers.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.builder import (
    REUSE_KNOBS, DigcSpec, GraphBuilder, promote_batch, register,
)
from repro.core.digc import BIG, digc_blocked, dilate, pairwise_sq_dists
from repro.core.engine import select_topkd


def kmeans(y: jax.Array, n_clusters: int, iters: int = 5,
           seed: int = 0, init: Optional[jax.Array] = None) -> jax.Array:
    """Lightweight Lloyd's iterations. y (M, D) -> centroids (C, D).

    ``init`` warm-starts from previous centroids (a DigcCache carry:
    consecutive ViG layers / serving requests drift slowly, so a warm
    start converges in 1-2 iterations instead of 5 from random init).
    """
    m = y.shape[0]
    if init is None:
        idx = jax.random.permutation(jax.random.PRNGKey(seed), m)[:n_clusters]
        cents = y[idx]
    else:
        cents = init.astype(y.dtype)

    def step(cents, _):
        d = pairwise_sq_dists(y, cents)  # (M, C)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, n_clusters, dtype=y.dtype)  # (M, C)
        sums = onehot.T @ y  # (C, D)
        counts = jnp.sum(onehot, axis=0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents)
        return new, None

    cents, _ = lax.scan(step, cents, None, length=iters)
    return cents


def default_cluster_params(m: int, n_clusters: Optional[int],
                           n_probe: Optional[int]) -> tuple[int, int]:
    """Workload-adaptive defaults (previously hard-coded in the model):
    ~28 co-nodes per cluster, probe up to 8 clusters."""
    if n_clusters is None:
        n_clusters = max(m // 28, 4)
    n_clusters = min(n_clusters, m)
    if n_probe is None:
        n_probe = 8
    return n_clusters, min(n_probe, n_clusters)


def _segment_ranks(labels: jax.Array) -> jax.Array:
    """Rank of each element within its label group, in original order.

    Sort-based: a stable argsort groups equal labels, the rank within a
    group is the position minus the group start (a running max over
    change points), scattered back through the sort order. O(L log L)
    on L elements — replaces the (L, C) one-hot + column cumsum whose
    materialized L*C intermediate dominated the dispatch cost.
    """
    L = labels.shape[0]
    order = jnp.argsort(labels)  # lax.sort: stable
    sorted_l = labels[order]
    pos = jnp.arange(L, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_l[1:] != sorted_l[:-1]]
    )
    seg_start = lax.cummax(jnp.where(change, pos, 0))
    rank_sorted = pos - seg_start
    return jnp.zeros((L,), jnp.int32).at[order].set(rank_sorted)


def _cluster_index(y, *, n_clusters, cap, seed, iters=5, init_centroids=None):
    """Build the IVF index for one co-node set: y (M, D) ->
    (centroids (C, D), members (C, cap) with pad id M).

    Hoisted out of the per-image search so it runs once when co-nodes
    are shared across the batch, and so DigcCache can warm-start the
    k-means from a previous layer's / request's centroids.
    """
    m = y.shape[0]
    cents = kmeans(y, n_clusters, iters=iters, seed=seed, init=init_centroids)
    d_yc = pairwise_sq_dists(y, cents)  # (M, C)
    assign = jnp.argmin(d_yc, axis=1)  # (M,)
    # fixed-capacity member lists via rank-in-cluster scatter
    pos = _segment_ranks(assign)  # (M,)
    keep = pos < cap
    slot = jnp.where(keep, assign * cap + pos, n_clusters * cap)
    members = jnp.full((n_clusters * cap + 1,), m, jnp.int32)  # m = pad id
    members = members.at[slot].set(jnp.arange(m, dtype=jnp.int32))
    members = members[:-1].reshape(n_clusters, cap)
    return cents, members


def _cluster_search(x, y, cents, members, *, kd, n_probe, block_t=128):
    """Dispatch-form two-stage search for one image.

    Stage 2 is organized cluster-major (the MoE group-GEMM pattern, as
    in ClusterViG's balanced partitions): each (query, probe-slot) pair
    is assigned a dispatch slot in its target cluster's *block-aligned*
    segment — every cluster's pair list is padded only up to the next
    ``block_t`` boundary, so the static dispatch size is
    N*n_probe + C*block_t and **no query is ever dropped**. Each
    block_t-row block belongs to exactly one cluster and runs one dense
    (block_t x D) @ (D x cap) contraction against that cluster's member
    features; per-query candidate rows are combined back by slot.

    This replaces the per-query candidate-feature gather of the old
    path — (N, P, D) rows pulled through XLA's scalar row-gather, ~60x
    the traffic of the cluster-major form — with matmul-form distances
    (``pairwise_sq_dists`` algebra: ||y||^2 - 2xy; the query norm is
    added back at the end, rank-invariant since it is constant per
    row).
    """
    n, d = x.shape
    m = y.shape[0]
    n_clusters, cap = members.shape
    y_pad = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    sq_y = jnp.concatenate(
        [jnp.sum(y.astype(jnp.float32) ** 2, axis=-1), jnp.full((1,), BIG)], 0
    )
    cluster_feats = y_pad[members]  # (C, cap, D) — cluster-major gather
    sq_members = sq_y[members]  # (C, cap); BIG on member pads

    # stage 1: nearest centroids per query
    d_xc = pairwise_sq_dists(x, cents)  # (N, C)
    _, probe = lax.top_k(-d_xc, n_probe)  # (N, n_probe)

    # dispatch: each (query, probe-slot) pair gets a slot in its target
    # cluster's block-aligned segment
    flat_c = probe.reshape(-1)  # (N * n_probe,)
    q_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), n_probe)
    rank = _segment_ranks(flat_c)  # (N * n_probe,)
    counts = jnp.zeros((n_clusters,), jnp.int32).at[flat_c].add(1)
    seg_len = ((counts + block_t - 1) // block_t) * block_t
    ends = jnp.cumsum(seg_len)
    starts = ends - seg_len
    slot = starts[flat_c] + rank  # (N * n_probe,) — never dropped
    # static bound on sum(seg_len), rounded to whole blocks
    nblocks = -(-(n * n_probe) // block_t) + n_clusters
    total = nblocks * block_t
    qmap = jnp.full((total,), n, jnp.int32).at[slot].set(q_of)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    x_disp = x_pad[qmap].reshape(nblocks, block_t, d)
    # block -> owning cluster (blocks past the used prefix hit the BIG
    # pad cluster)
    block_c = jnp.searchsorted(
        ends, jnp.arange(nblocks, dtype=jnp.int32) * block_t, side="right"
    )
    feats_pad = jnp.concatenate(
        [cluster_feats, jnp.zeros((1, cap, d), y.dtype)], axis=0)
    sqm_pad = jnp.concatenate(
        [sq_members, jnp.full((1, cap), BIG, jnp.float32)], axis=0)
    feats_blk = feats_pad[jnp.minimum(block_c, n_clusters)]  # (nb, cap, D)
    sqm_blk = sqm_pad[jnp.minimum(block_c, n_clusters)]  # (nb, cap)

    # per-block dense contraction: -2 X_blk Y_c^T + ||y||^2
    xy = lax.dot_general(
        x_disp, feats_blk, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (nb, block_t, cap)
    d_c = sqm_blk[:, None, :] - 2.0 * xy

    # combine: each (query, slot) reads its cap-row back
    d_flat = d_c.reshape(total, cap)
    cand_d = d_flat[slot].reshape(n, n_probe * cap)  # (N, P)
    cand_i = members[probe].reshape(n, n_probe * cap)

    kd_eff = min(kd, cand_d.shape[1])
    vals, cols = select_topkd(cand_d, kd_eff)
    idx = jnp.take_along_axis(cand_i, cols, axis=-1)
    # add the per-query norm back (rank-invariant; BIG lanes stay BIG)
    dist = vals + jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None]
    dist = jnp.where(vals >= BIG / 2, vals, dist)
    if kd_eff < kd:  # pad to kd for API uniformity
        idx = jnp.pad(idx, ((0, 0), (0, kd - kd_eff)))
        dist = jnp.pad(dist, ((0, 0), (0, kd - kd_eff)), constant_values=BIG)
    return idx, dist


def cluster_digc(
    x: jax.Array,
    y: Optional[jax.Array] = None,
    *,
    k: int,
    dilation: int = 1,
    n_clusters: Optional[int] = None,
    n_probe: Optional[int] = None,
    capacity_factor: float = 2.0,
    seed: int = 0,
    kmeans_iters: int = 5,
    init_centroids: Optional[jax.Array] = None,
    init_valid: Optional[jax.Array] = None,
    warm_iters: int = 2,
    return_dists: bool = False,
    return_state: bool = False,
):
    """Two-stage ANN graph construction (ClusterViG family).

    1. cluster co-nodes (k-means, static iters; ``init_centroids``
       warm-starts from a previous layer/request via ``DigcCache`` or a
       functional ``DigcState`` entry);
    2. bucket members into fixed-capacity cluster lists (overflow
       drops, like the MoE dispatch);
    3. per query: top-n_probe centroids, then top-k·d over the probed
       clusters' members in dispatch form (one dense contraction per
       cluster; see ``_cluster_search``).

    Accepts (N, D) or (B, N, D); the whole batch shares static cluster
    shapes. Index construction is hoisted out of the per-image search:
    a shared co-node set — explicit (M, D) co-nodes next to batched
    (B, N, D) queries — is indexed **once** and broadcast, instead of
    being re-clustered per image. ``n_clusters`` / ``n_probe`` default
    to a workload-adaptive heuristic (``default_cluster_params``).

    ``init_valid`` selects the **functional warm start**: a traced ()
    bool (a ``DigcStateEntry`` step counter test). Both branches are
    staged — ``lax.cond`` runs the warm index build (``warm_iters``
    Lloyd iterations from ``init_centroids``) when true and the cold
    build (``kmeans_iters`` from random init) when false — so the same
    compiled program serves the first and every later request. A (B,)
    bool vector makes validity **per batch row** (multi-tenant
    serving, DESIGN.md §9): each row gets the build its own validity
    selects — all-warm batches pay one build, mixed batches stage both
    and select per row. With ``init_valid=None`` (the legacy eager
    path), warm/cold is a trace-time choice: ``init_centroids``
    present means warm.

    ``return_state=True`` additionally returns {"centroids": (B, C, D)}
    for warm-starting the next call.
    """
    # Shared external co-nodes: index once, before batch promotion.
    shared_y = y is not None and y.ndim == 2 and x.ndim == 3
    if shared_y:
        b = x.shape[0]
        y = jnp.broadcast_to(y[None], (b,) + y.shape)
    x3, y3, _, squeeze = promote_batch(x, y)
    b = x3.shape[0]
    m = y3.shape[1]
    kd = k * dilation
    n_clusters, n_probe = default_cluster_params(m, n_clusters, n_probe)
    cap = max(int(m / n_clusters * capacity_factor), kd)

    init3 = init_centroids
    if init3 is not None and init3.ndim == 2:
        init3 = jnp.broadcast_to(init3[None], (b,) + init3.shape)
    if init3 is not None and init3.shape[1] != n_clusters:
        init3 = None  # stale cache shape (workload changed): cold start

    def build_index(iters: int, init_b3, shared: bool = shared_y):
        def index_one(yb, init_b=None):
            return _cluster_index(
                yb, n_clusters=n_clusters, cap=cap, seed=seed,
                iters=iters, init_centroids=init_b,
            )

        if shared:
            cents1, members1 = index_one(
                y3[0], None if init_b3 is None else init_b3[0]
            )
            return (
                jnp.broadcast_to(cents1[None], (b,) + cents1.shape),
                jnp.broadcast_to(members1[None], (b,) + members1.shape),
            )
        if init_b3 is None:
            return jax.vmap(lambda yb: index_one(yb))(y3)
        return jax.vmap(index_one)(y3, init_b3)

    if init3 is None:
        cents, members = build_index(kmeans_iters, None)
    elif init_valid is None:
        cents, members = build_index(kmeans_iters, init3)
    elif jnp.ndim(init_valid) == 0:
        cents, members = lax.cond(
            init_valid,
            lambda: build_index(warm_iters, init3),
            lambda: build_index(kmeans_iters, None),
        )
    else:
        # (B,) per-row validity (multi-tenant serving): a batch mixing
        # warm tenants with cold ones must give each *row* exactly the
        # build a B=1 call with that row's validity would — warm rows a
        # warm_iters Lloyd refinement of their carried centroids, cold
        # rows the full cold build. Steady state (every row warm) pays
        # one build; a mixed batch stages both and selects per row.
        # Shared-co-node indexing is per-row here by construction: rows
        # carry independent init centroids.
        valid = init_valid

        def mixed_index():
            cw, mw = build_index(warm_iters, init3, shared=False)
            cc, mc = build_index(kmeans_iters, None, shared=False)
            sel = valid[:, None, None]
            return jnp.where(sel, cw, cc), jnp.where(sel, mw, mc)

        cents, members = lax.cond(
            jnp.all(valid),
            lambda: build_index(warm_iters, init3, shared=False),
            mixed_index,
        )

    idx, dist = jax.vmap(
        lambda xb, yb, cb, mb: _cluster_search(
            xb, yb, cb, mb, kd=kd, n_probe=n_probe,
        )
    )(x3, y3, cents, members)
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    out = (idx, dist) if return_dists else idx
    if return_state:
        state = {"centroids": cents}
        return (*out, state) if return_dists else (out, state)
    return out


def axial_digc(
    x: jax.Array,
    *,
    grid_h: int,
    grid_w: int,
    k: int,
    dilation: int = 1,
    return_dists: bool = False,
):
    """Axial construction (GreedyViG family): each patch considers only
    its grid row and column — O(N·(H+W)·D), no full distance matrix.

    x (N, D) or (B, N, D) with N == grid_h * grid_w, row-major patch
    order. The candidate structure is shared across the batch, so the
    whole batch runs as one gather + one top-k.
    """
    x3, _, _, squeeze = promote_batch(x)
    b, n, d = x3.shape
    assert n == grid_h * grid_w, (n, grid_h, grid_w)
    kd = k * dilation

    rows = jnp.arange(grid_h)
    cols = jnp.arange(grid_w)
    # row candidates for patch (r, c): ids r*W + c' for all c'
    row_ids = rows[:, None, None] * grid_w + cols[None, None, :]  # (H,1,W)
    row_ids = jnp.broadcast_to(row_ids, (grid_h, grid_w, grid_w))
    # column candidates for patch (r, c): ids r'*W + c for all r'
    col_ids = rows[None, None, :] * grid_w + cols[None, :, None]  # (1,W,H)
    col_ids = jnp.broadcast_to(col_ids, (grid_h, grid_w, grid_h))
    cand = jnp.concatenate([row_ids, col_ids], axis=-1).reshape(n, grid_w + grid_h)

    feats = x3[:, cand]  # (B, N, H+W, D)
    dists = jnp.sum((feats - x3[:, :, None, :]) ** 2, axis=-1)  # (B, N, H+W)
    # the row and column lists intersect exactly at the query itself:
    # mask the column-side duplicate so it can't displace a neighbor
    qid = jnp.arange(n, dtype=cand.dtype)
    dup = cand[:, grid_w:] == qid[:, None]  # (N, H)
    dists = dists.at[:, :, grid_w:].set(
        jnp.where(dup[None], BIG, dists[:, :, grid_w:])
    )
    kd_eff = min(kd, cand.shape[1])
    neg, sel = lax.top_k(-dists, kd_eff)
    cand_b = jnp.broadcast_to(cand[None], (b,) + cand.shape)
    idx = jnp.take_along_axis(cand_b, sel, axis=-1)
    dist = -neg
    if kd_eff < kd:
        idx = jnp.pad(idx, ((0, 0), (0, 0), (0, kd - kd_eff)))
        dist = jnp.pad(dist, ((0, 0), (0, 0), (0, kd - kd_eff)),
                       constant_values=BIG)
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def recall_vs_exact(x, y, idx_approx, k: int) -> float:
    """Neighbor-set recall of an approximate construction vs Algorithm 1."""
    import numpy as np

    from repro.core.digc import digc_reference

    exact = np.asarray(digc_reference(x, y, k=k))
    approx = np.asarray(idx_approx)[..., :k]
    exact = exact.reshape(-1, k)
    approx = approx.reshape(-1, k)
    hits = 0
    for i in range(exact.shape[0]):
        hits += len(set(exact[i]) & set(approx[i]))
    return hits / exact.size


# --------------------------------------------------------------------------
# Registry entries (DESIGN.md §4).


def _build_cluster(x, y, pos_bias, spec: DigcSpec, cache=None, cache_key=None,
                   state_entry=None):
    del pos_bias  # validated unsupported upstream
    if state_entry is not None:
        return _build_cluster_stateful(x, y, spec, state_entry)
    init = None
    ckey = None
    if cache is not None and cache_key is not None:
        # An explicit key is required: two unrelated callers sharing a
        # cache with matching shapes must not warm-start from each
        # other's centroids.
        from repro.core.engine import DigcCache

        concrete = DigcCache.usable(x) and (y is None or DigcCache.usable(y))
        if concrete:
            m = y.shape[1] if y is not None else x.shape[1]
            ckey = (cache_key, x.shape[0], m, x.shape[-1])
            init = cache.get("cluster_centroids", ckey)
    warm = init is not None
    out = cluster_digc(
        x, y, k=spec.k, dilation=spec.dilation,
        n_clusters=spec.n_clusters, n_probe=spec.n_probe,
        capacity_factor=(
            spec.capacity_factor if spec.capacity_factor is not None else 2.0
        ),
        seed=spec.seed if spec.seed is not None else 0,
        # warm starts converge in 2 Lloyd iterations (features drift
        # slowly layer-to-layer / request-to-request)
        kmeans_iters=2 if warm else 5,
        init_centroids=init,
        return_dists=True,
        return_state=ckey is not None,
    )
    if ckey is not None:
        idx, dist, state = out
        cache.put("cluster_centroids", ckey, state["centroids"])
        return idx, dist
    return out


def _build_cluster_stateful(x, y, spec: DigcSpec, entry):
    """Functional form: (x, y, spec, DigcStateEntry) ->
    (idx, dist, new entry). Jit-native — warm/cold is a runtime
    ``lax.cond`` on the entry's step counter (per batch row when the
    entry carries ``row_step``: multi-tenant batches mix warm and cold
    tenants), and the new centroids are returned in the entry
    (donation-stable shapes/dtypes)."""
    m = y.shape[1] if y is not None else x.shape[1]
    n_clusters, _ = default_cluster_params(m, spec.n_clusters, spec.n_probe)
    expected = (x.shape[0], n_clusters, x.shape[-1])
    init = entry.centroids
    common = dict(
        k=spec.k, dilation=spec.dilation,
        n_clusters=spec.n_clusters, n_probe=spec.n_probe,
        capacity_factor=(
            spec.capacity_factor if spec.capacity_factor is not None else 2.0
        ),
        seed=spec.seed if spec.seed is not None else 0,
        return_dists=True, return_state=True,
    )
    if init is None or init.shape != expected:
        # No centroid buffer for this workload (shape is static): cold
        # build, advance the counter only — never write mismatched
        # shapes into the state (the pytree structure is the compiled
        # program's contract).
        idx, dist, st = cluster_digc(x, y, **common)
        return idx, dist, entry.bump()
    valid = entry.row_warm if entry.row_step is not None else entry.warm
    idx, dist, st = cluster_digc(
        x, y, init_centroids=init, init_valid=valid, **common
    )
    return idx, dist, entry.bump(
        centroids=st["centroids"].astype(init.dtype)
    )


def _build_axial(x, y, pos_bias, spec: DigcSpec):
    del pos_bias
    n = x.shape[1]
    if y is not None:
        # Axial candidates are x's own grid row/column — it is a
        # self-graph construction (the y=None spelling) and cannot
        # target explicit co-nodes: pooled model stages and any
        # caller-supplied y fall back to the exact streaming tier, as
        # the model used to special-case by hand.
        return digc_blocked(
            x, y, k=spec.k, dilation=spec.dilation, return_dists=True
        )
    gh, gw = spec.grid_h, spec.grid_w
    if gh is None and gw is None:
        side = int(round(n ** 0.5))
        if side * side != n:
            raise ValueError(
                f"axial DIGC needs grid_h/grid_w for non-square N={n}"
            )
        gh = gw = side
    elif gh is None:
        gh = n // gw
    elif gw is None:
        gw = n // gh
    if gh * gw != n:
        raise ValueError(
            f"axial grid {gh}x{gw} does not match N={n} nodes"
        )
    return axial_digc(
        x, grid_h=gh, grid_w=gw, k=spec.k, dilation=spec.dilation,
        return_dists=True,
    )


register(GraphBuilder(
    name="cluster",
    build=_build_cluster,
    knobs=frozenset({"n_clusters", "n_probe", "capacity_factor", "seed"})
    | REUSE_KNOBS,
    exact=False,
    supports_cache=True,
    supports_state=True,  # jit-native centroid warm starts via DigcState
    doc="ClusterViG-family IVF search: k-means index (shared co-nodes "
        "indexed once, DigcState/DigcCache warm starts) + dispatch-form "
        "probe",
))

register(GraphBuilder(
    name="axial",
    build=_build_axial,
    knobs=frozenset({"grid_h", "grid_w"}),
    exact=False,
    doc="GreedyViG-family axial (row+column) construction; falls back "
        "to blocked when co-nodes are pooled (M != N)",
))
