"""Alternative graph-construction strategies (paper §VI conclusion:
"the graph construction approach can be generalized by adjusting the
mechanism used to compute similarity ... clustering-based approaches
exemplified by ClusterViG and greedy edge-selection techniques used in
GreedyViG").

Both reuse the DIGC substrate (blocked distance + top-k merge), keep
static shapes (TPU-compilable), and are batched-first — (B, N, D) in,
(B, N, k) out, with (N, D) promoted to B=1:

  * ``cluster_digc`` — IVF-style two-stage search (ClusterViG family):
    k-means centroids over co-nodes, queries probe only the n_probe
    nearest clusters. O(N·(C + probe·cap)·D) vs O(N·M·D).
  * ``axial_digc``   — GreedyViG-family axial construction: candidates
    restricted to the query's grid row + column. O(N·(H+W)·D).

Approximate by design; recall measured in tests/benchmarks. Both are
registered GraphBuilders (DESIGN.md §4), peers of the exact tiers.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.builder import DigcSpec, GraphBuilder, promote_batch, register
from repro.core.digc import BIG, digc_blocked, dilate, merge_topk, pairwise_sq_dists


def kmeans(y: jax.Array, n_clusters: int, iters: int = 5,
           seed: int = 0) -> jax.Array:
    """Lightweight Lloyd's iterations. y (M, D) -> centroids (C, D)."""
    m = y.shape[0]
    idx = jax.random.permutation(jax.random.PRNGKey(seed), m)[:n_clusters]
    cents = y[idx]

    def step(cents, _):
        d = pairwise_sq_dists(y, cents)  # (M, C)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, n_clusters, dtype=y.dtype)  # (M, C)
        sums = onehot.T @ y  # (C, D)
        counts = jnp.sum(onehot, axis=0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents)
        return new, None

    cents, _ = lax.scan(step, cents, None, length=iters)
    return cents


def default_cluster_params(m: int, n_clusters: Optional[int],
                           n_probe: Optional[int]) -> tuple[int, int]:
    """Workload-adaptive defaults (previously hard-coded in the model):
    ~28 co-nodes per cluster, probe up to 8 clusters."""
    if n_clusters is None:
        n_clusters = max(m // 28, 4)
    n_clusters = min(n_clusters, m)
    if n_probe is None:
        n_probe = 8
    return n_clusters, min(n_probe, n_clusters)


def _cluster_single(x, y, *, k, dilation, n_clusters, n_probe, cap, seed):
    """Single-image IVF search core; vmapped over the batch axis."""
    n, d = x.shape
    m = y.shape[0]
    kd = k * dilation

    cents = kmeans(y, n_clusters, seed=seed)
    d_yc = pairwise_sq_dists(y, cents)  # (M, C)
    assign = jnp.argmin(d_yc, axis=1)  # (M,)
    # fixed-capacity member lists via rank-in-cluster scatter
    onehot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot  # (M, C)
    pos = jnp.sum(rank * onehot, axis=1)  # (M,)
    keep = pos < cap
    slot = jnp.where(keep, assign * cap + pos, n_clusters * cap)
    members = jnp.full((n_clusters * cap + 1,), m, jnp.int32)  # m = pad id
    members = members.at[slot].set(jnp.arange(m, dtype=jnp.int32))
    members = members[:-1].reshape(n_clusters, cap)

    # stage 1: nearest centroids per query
    d_xc = pairwise_sq_dists(x, cents)  # (N, C)
    _, probe = lax.top_k(-d_xc, n_probe)  # (N, n_probe)

    # stage 2: exact top-kd over probed members (padded with id m)
    cand = members[probe].reshape(n, n_probe * cap)  # (N, P)
    y_pad = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    cand_feats = y_pad[cand]  # (N, P, D)
    dists = jnp.sum((cand_feats - x[:, None, :]) ** 2, axis=-1)
    dists = jnp.where(cand < m, dists, BIG)
    kd_eff = min(kd, cand.shape[1])
    neg, sel = lax.top_k(-dists, kd_eff)
    idx = jnp.take_along_axis(cand, sel, axis=1)
    dist = -neg
    if kd_eff < kd:  # pad to kd for API uniformity
        idx = jnp.pad(idx, ((0, 0), (0, kd - kd_eff)))
        dist = jnp.pad(dist, ((0, 0), (0, kd - kd_eff)), constant_values=BIG)
    return idx, dist


def cluster_digc(
    x: jax.Array,
    y: Optional[jax.Array] = None,
    *,
    k: int,
    dilation: int = 1,
    n_clusters: Optional[int] = None,
    n_probe: Optional[int] = None,
    capacity_factor: float = 2.0,
    seed: int = 0,
    return_dists: bool = False,
):
    """Two-stage ANN graph construction (ClusterViG family).

    1. cluster co-nodes (k-means, static iters);
    2. bucket members into fixed-capacity cluster lists (overflow drops,
       like the MoE dispatch);
    3. per query: top-n_probe centroids, then exact top-k·d over the
       probed clusters' members only.

    Accepts (N, D) or (B, N, D); the whole batch shares static cluster
    shapes, each image clusters its own co-nodes. ``n_clusters`` /
    ``n_probe`` default to a workload-adaptive heuristic
    (``default_cluster_params``).
    """
    x3, y3, _, squeeze = promote_batch(x, y)
    m = y3.shape[1]
    kd = k * dilation
    n_clusters, n_probe = default_cluster_params(m, n_clusters, n_probe)
    cap = max(int(m / n_clusters * capacity_factor), kd)

    idx, dist = jax.vmap(
        lambda xb, yb: _cluster_single(
            xb, yb, k=k, dilation=dilation, n_clusters=n_clusters,
            n_probe=n_probe, cap=cap, seed=seed,
        )
    )(x3, y3)
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def axial_digc(
    x: jax.Array,
    *,
    grid_h: int,
    grid_w: int,
    k: int,
    dilation: int = 1,
    return_dists: bool = False,
):
    """Axial construction (GreedyViG family): each patch considers only
    its grid row and column — O(N·(H+W)·D), no full distance matrix.

    x (N, D) or (B, N, D) with N == grid_h * grid_w, row-major patch
    order. The candidate structure is shared across the batch, so the
    whole batch runs as one gather + one top-k.
    """
    x3, _, _, squeeze = promote_batch(x)
    b, n, d = x3.shape
    assert n == grid_h * grid_w, (n, grid_h, grid_w)
    kd = k * dilation

    rows = jnp.arange(grid_h)
    cols = jnp.arange(grid_w)
    # row candidates for patch (r, c): ids r*W + c' for all c'
    row_ids = rows[:, None, None] * grid_w + cols[None, None, :]  # (H,1,W)
    row_ids = jnp.broadcast_to(row_ids, (grid_h, grid_w, grid_w))
    # column candidates for patch (r, c): ids r'*W + c for all r'
    col_ids = rows[None, None, :] * grid_w + cols[None, :, None]  # (1,W,H)
    col_ids = jnp.broadcast_to(col_ids, (grid_h, grid_w, grid_h))
    cand = jnp.concatenate([row_ids, col_ids], axis=-1).reshape(n, grid_w + grid_h)

    feats = x3[:, cand]  # (B, N, H+W, D)
    dists = jnp.sum((feats - x3[:, :, None, :]) ** 2, axis=-1)  # (B, N, H+W)
    # the row and column lists intersect exactly at the query itself:
    # mask the column-side duplicate so it can't displace a neighbor
    qid = jnp.arange(n, dtype=cand.dtype)
    dup = cand[:, grid_w:] == qid[:, None]  # (N, H)
    dists = dists.at[:, :, grid_w:].set(
        jnp.where(dup[None], BIG, dists[:, :, grid_w:])
    )
    kd_eff = min(kd, cand.shape[1])
    neg, sel = lax.top_k(-dists, kd_eff)
    cand_b = jnp.broadcast_to(cand[None], (b,) + cand.shape)
    idx = jnp.take_along_axis(cand_b, sel, axis=-1)
    dist = -neg
    if kd_eff < kd:
        idx = jnp.pad(idx, ((0, 0), (0, 0), (0, kd - kd_eff)))
        dist = jnp.pad(dist, ((0, 0), (0, 0), (0, kd - kd_eff)),
                       constant_values=BIG)
    idx = dilate(idx, dilation)
    dist = dilate(dist, dilation)
    if squeeze:
        idx, dist = idx[0], dist[0]
    if return_dists:
        return idx, dist
    return idx


def recall_vs_exact(x, y, idx_approx, k: int) -> float:
    """Neighbor-set recall of an approximate construction vs Algorithm 1."""
    import numpy as np

    from repro.core.digc import digc_reference

    exact = np.asarray(digc_reference(x, y, k=k))
    approx = np.asarray(idx_approx)[..., :k]
    exact = exact.reshape(-1, k)
    approx = approx.reshape(-1, k)
    hits = 0
    for i in range(exact.shape[0]):
        hits += len(set(exact[i]) & set(approx[i]))
    return hits / exact.size


# --------------------------------------------------------------------------
# Registry entries (DESIGN.md §4).


def _build_cluster(x, y, pos_bias, spec: DigcSpec):
    del pos_bias  # validated unsupported upstream
    return cluster_digc(
        x, y, k=spec.k, dilation=spec.dilation,
        n_clusters=spec.n_clusters, n_probe=spec.n_probe,
        capacity_factor=(
            spec.capacity_factor if spec.capacity_factor is not None else 2.0
        ),
        seed=spec.seed if spec.seed is not None else 0,
        return_dists=True,
    )


def _build_axial(x, y, pos_bias, spec: DigcSpec):
    del pos_bias
    n = x.shape[1]
    if y is not None:
        # Axial candidates are x's own grid row/column — it is a
        # self-graph construction (the y=None spelling) and cannot
        # target explicit co-nodes: pooled model stages and any
        # caller-supplied y fall back to the exact streaming tier, as
        # the model used to special-case by hand.
        return digc_blocked(
            x, y, k=spec.k, dilation=spec.dilation, return_dists=True
        )
    gh, gw = spec.grid_h, spec.grid_w
    if gh is None and gw is None:
        side = int(round(n ** 0.5))
        if side * side != n:
            raise ValueError(
                f"axial DIGC needs grid_h/grid_w for non-square N={n}"
            )
        gh = gw = side
    elif gh is None:
        gh = n // gw
    elif gw is None:
        gw = n // gh
    if gh * gw != n:
        raise ValueError(
            f"axial grid {gh}x{gw} does not match N={n} nodes"
        )
    return axial_digc(
        x, grid_h=gh, grid_w=gw, k=spec.k, dilation=spec.dilation,
        return_dists=True,
    )


register(GraphBuilder(
    name="cluster",
    build=_build_cluster,
    knobs=frozenset({"n_clusters", "n_probe", "capacity_factor", "seed"}),
    exact=False,
    doc="ClusterViG-family IVF two-stage search (approximate)",
))

register(GraphBuilder(
    name="axial",
    build=_build_axial,
    knobs=frozenset({"grid_h", "grid_w"}),
    exact=False,
    doc="GreedyViG-family axial (row+column) construction; falls back "
        "to blocked when co-nodes are pooled (M != N)",
))
