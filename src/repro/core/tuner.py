"""Workload autotuner for the streaming DIGC engine.

GraphLeap's lesson (PAPERS.md, arXiv 2604.21290) is that a decoupled
construction dataflow leaves most of its headroom on the table until
the tile/merge configuration is *tuned per workload*. This module
picks ``(block_n, block_m, merge, fuse_norms)`` — or a fused-kernel
config ``(impl="pallas", block_n, block_m, kernel_merge)`` — per
``(backend, B, N, M, D, kd, causal, pos_bias)`` workload, so
kernel-vs-engine is a measured per-workload choice, not a code path:

  1. rank the candidate grid with the analytical cost models
     (``perfmodel.engine_cost_estimate`` for engine schedules,
     ``perfmodel.kernel_cost_estimate`` for kernel configs — the
     latter's interpret-mode penalty keeps emulated kernels out of the
     measured top-N off-TPU while compiled TPU configs compete on
     roofline terms) — priors;
  2. measure the top-ranked candidates on the live workload arrays
     (median wall time over a few jitted calls) — refinement;
  3. verify each measured candidate's indices against an
     exact-by-construction oracle config on the same probe input, so a
     tie-tolerant variant (``fuse_norms``) is only ever chosen when it
     matched exactly on the workload it will serve;
  4. persist the winner to a JSON cache keyed by the workload so later
     runs (and serving engines) skip the measurement entirely.

The tuner never changes *what* is computed — only the engine schedule.
Approximate merges (``packed``) are excluded unless ``allow_approx``.

The JSON cache is **host-keyed** (schema 3): entries nest under
``host_key()`` = backend + platform + jax version, so a schedule tuned
on one machine is never silently reused on another — a laptop's
block_n=512 is not a v5e's. Each host slot holds two stores:
``"schedules"`` (the tile measurements above, keyed by
``workload_key``) and ``"bucket_sets"`` (the serving engine's
arrival-histogram bucket-set choices, keyed by ``bucket_set_key`` —
see ``optimal_bucket_set``/``tune_bucket_set``, DESIGN.md §14).
Schema-2 files (hosts mapping straight to schedule entries) migrate
losslessly on load — the measurements stay valid, only the nesting
moved. Schema-1 files (flat, backend-only keys) are not migrated:
their entries cannot be attributed to a host, so they are dropped on
load and re-measured.

``VigSchedule`` maps pyramid stages to tuned specs:
``DigcTuner.tune_schedule`` tunes each stage's (N, M, D, kd) workload
separately — the PR-2 engine applied the stage-0 schedule everywhere,
but a pooled stage (M = N/r²) or a downsampled one (N/4) wants
different tiles.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import platform
import time
from pathlib import Path
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.builder import DigcSpec
from repro.core.perfmodel import (
    engine_cost_estimate,
    kernel_cost_estimate,
    kernel_tile_defaults,
)

# Knobs the tuner owns on a DigcSpec.
TUNED_KNOBS = ("block_n", "block_m", "merge", "fuse_norms", "kernel_merge")

_BLOCK_N_CANDIDATES = (None, 256, 512, 1024)
_BLOCK_M_CANDIDATES = (256, 512, 1024, 2048, 4096)
_EXACT_MERGES = ("select", "topk")
# Fused-kernel candidates compete as first-class configs: the LSM/GMM
# realization is a measured per-workload choice (ISSUE 6 tentpole).
_KERNEL_MERGES = ("bitonic", "legacy")
_KERNEL_TILE_FALLBACKS = ((128, 256), (256, 512))


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One schedule — engine tiles *or* a fused-kernel config: the
    tuner's unit of search. ``impl`` picks the tier ("blocked" engine
    schedules keep their historical field meanings; "pallas" configs
    carry kernel tile dims + the ``kernel_merge`` variant and use
    ``merge="kernel"`` as a display placeholder)."""

    block_n: Optional[int]
    block_m: int
    merge: str
    fuse_norms: bool = False
    impl: str = "blocked"
    kernel_merge: Optional[str] = None

    def apply(self, spec: DigcSpec) -> DigcSpec:
        if self.impl == "pallas":
            return spec.replace(
                impl="pallas",
                block_n=self.block_n,
                block_m=self.block_m,
                kernel_merge=self.kernel_merge,
                # engine-only knobs must be unset for the kernel builder
                merge=None,
                fuse_norms=None,
                group_w=None,
            )
        return spec.replace(
            block_n=self.block_n,
            block_m=self.block_m,
            merge=self.merge,
            fuse_norms=self.fuse_norms or None,
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TuneResult:
    config: TileConfig
    us_per_call: float
    exact_match: bool
    source: str  # "measured" | "cached" | "prior"

    def as_dict(self) -> dict:
        return {
            **self.config.as_dict(),
            "us_per_call": self.us_per_call,
            "exact_match": self.exact_match,
            "source": self.source,
        }


def host_key(backend: Optional[str] = None) -> str:
    """Identity of the measuring host: backend + platform + jax version.

    A tuned schedule is a *measurement* of this machine; entries under a
    different host key are never read (and a jax upgrade re-measures —
    compiler changes move the optimum).
    """
    import jax

    backend = backend if backend is not None else jax.default_backend()
    return (
        f"{backend}|{platform.system().lower()}-{platform.machine()}"
        f"|jax-{jax.__version__}"
    )


def workload_key(
    b: int, n: int, m: int, d: int, kd: int,
    causal: bool = False, has_pos: bool = False,
    mesh_shape: Optional[tuple[int, ...]] = None,
) -> str:
    """Workload identity within one host (see ``host_key``).

    ``mesh_shape`` (device counts per mesh axis, ``DigcSpec.
    mesh_shape()``) keys sharded workloads separately: a schedule
    measured with co-nodes rotating a 4-device ring is a different
    measurement from the single-device tile sweep, even at identical
    (B, N, M) — the per-hop tile is M/n_dev wide and the ICI transfer
    is part of the measured step. Unsharded workloads (the common
    case) keep their historical keys. Today this is a *forward guard*:
    ``tune()`` only measures the blocked tier, which carries no mesh
    knob — the suffix exists so the committed single-device entries
    can never be clobbered (or mis-served) the day a sharded tier
    becomes measurable (ROADMAP: ring on real ICI).
    """
    key = f"b{b}:n{n}:m{m}:d{d}:kd{kd}"
    if causal:
        key += ":causal"
    if has_pos:
        key += ":pos"
    if mesh_shape:
        key += ":mesh" + "x".join(str(s) for s in mesh_shape)
    return key


def bucket_set_key(slots: int, sizes, max_programs: int) -> str:
    """Identity of one serving shape for bucket-set persistence: the
    slot count, the configured N-bucket image sizes, and the
    compile-count cap. Unlike schedules (measurements of a machine), a
    bucket set is a property of the *arrival trace* — but it is stored
    under the host key anyway, because the trace that produced it was
    served on this host and another machine's replica should re-profile
    its own traffic."""
    return (
        f"slots{int(slots)}:cap{int(max_programs)}:sizes"
        + "-".join(str(int(s)) for s in sorted(sizes))
    )


def optimal_bucket_set(
    hist, *, slots: int, max_programs: int = 4, costs=None,
) -> tuple[int, ...]:
    """The (B, N) bucket set minimizing expected padded-lane work under
    a compile-count cap (DESIGN.md §14).

    ``hist`` is a serving engine's live-lane histogram — ``{size:
    {live: ticks}}``, or a flat ``{live: ticks}`` for single-size
    traffic: how many ticks served exactly ``live`` lanes at each
    N-bucket. Under bucket set S, a tick at ``live`` lanes pays
    ``min(b in S : b >= live)`` lanes of compute (padding lanes run the
    full forward), weighted by ``costs[size]`` (per-lane work, e.g. the
    patch count N; default 1). The optimizer minimizes

        sum_{size, live} hist[size][live] * bucket_S(live) * cost[size]

    by brute force over subsets of the *observed* live counts — an
    optimal bucket boundary always sits on an observed count, so the
    candidate pool is tiny (at most ``slots`` values) — of at most
    ``max_programs`` buckets, always including ``slots`` so every
    admissible tick fits. Ties break deterministically: least work,
    then fewest buckets, then lexicographically smallest — a fixed
    trace always selects the same set. An empty histogram returns
    ``(slots,)``."""
    slots = int(slots)
    if slots < 1:
        raise ValueError(f"slots must be >= 1, got {slots}")
    if int(max_programs) < 1:
        raise ValueError(f"max_programs must be >= 1, got {max_programs}")
    if hist and not isinstance(next(iter(hist.values())), dict):
        hist = {None: hist}
    weights: dict[tuple, float] = {}
    for size, per in (hist or {}).items():
        cost = 1.0 if costs is None else float(costs.get(size, 1.0))
        for live, ticks in per.items():
            live = int(live)
            if not 1 <= live <= slots:
                raise ValueError(
                    f"histogram live-lane count {live} outside "
                    f"1..slots={slots}"
                )
            weights[(size, live)] = (
                weights.get((size, live), 0.0) + float(ticks) * cost
            )
    if not weights:
        return (slots,)
    pool = sorted({live for _, live in weights if live < slots})
    best = None
    for r in range(min(int(max_programs) - 1, len(pool)) + 1):
        for extra in itertools.combinations(pool, r):
            cand = tuple(sorted(set(extra) | {slots}))
            work = sum(
                w * min(b for b in cand if b >= live)
                for (_, live), w in weights.items()
            )
            key = (work, len(cand), cand)
            if best is None or key < best:
                best = key
    return best[2]


class DigcTuner:
    """Prior-ranked, measurement-refined, JSON-persisted tile tuner."""

    def __init__(
        self,
        path: Optional[str | Path] = None,
        *,
        backend: Optional[str] = None,
        measure_iters: int = 2,
        max_measure: int = 6,
    ):
        import jax

        self.path = Path(path) if path is not None else None
        self.backend = backend if backend is not None else jax.default_backend()
        self.host = host_key(self.backend)
        self.measure_iters = measure_iters
        self.max_measure = max_measure
        # Full file contents (all hosts) are preserved on save; only
        # this host's entries are ever *read*. Schema 3 nests each
        # host's stores by kind: {"schedules": {workload key: tile},
        # "bucket_sets": {serving-shape key: bucket set}}.
        self._hosts: dict[str, dict] = {}
        if self.path is not None and self.path.exists():
            data = json.loads(self.path.read_text())
            if data.get("schema") == 3:
                self._hosts = {
                    h: {"schedules": dict(v.get("schedules", {})),
                        "bucket_sets": dict(v.get("bucket_sets", {}))}
                    for h, v in data.get("hosts", {}).items()
                }
            elif data.get("schema") == 2:
                # schema-2 migration: hosts mapped straight to their
                # schedule entries. The measurements stay valid — only
                # the nesting moved — so lift them under "schedules"
                # and start each host with an empty bucket-set store.
                self._hosts = {
                    h: {"schedules": dict(e), "bucket_sets": {}}
                    for h, e in data.get("hosts", {}).items()
                }
            # schema 1: flat backend-keyed entries with no platform/jax
            # identity — unattributable, so dropped (re-measured here).
        _slot = self._hosts.setdefault(
            self.host, {"schedules": {}, "bucket_sets": {}}
        )
        self.entries: dict[str, dict] = _slot["schedules"]
        self.bucket_sets: dict[str, dict] = _slot["bucket_sets"]

    # -- candidate generation -------------------------------------------

    def candidates(
        self, n: int, m: int, *, d: Optional[int] = None,
        kd: Optional[int] = None, allow_approx: bool = False
    ) -> list[TileConfig]:
        block_ns = {bn if (bn is None or bn < n) else None
                    for bn in _BLOCK_N_CANDIDATES}
        block_ms = {min(bm, m) for bm in _BLOCK_M_CANDIDATES}
        block_ms.add(m)
        merges = list(_EXACT_MERGES) + (["packed"] if allow_approx else [])
        out = []
        for bn in sorted(block_ns, key=lambda v: -1 if v is None else v):
            for bm in sorted(block_ms):
                for merge in merges:
                    for fuse in (False, True):
                        out.append(TileConfig(bn, bm, merge, fuse))
        # Fused-kernel configs: the VMEM-budgeted workload default tile
        # plus fixed fallbacks, each with both LSM/GMM realizations.
        # All exact (unpacked), so they verify against the same oracle.
        kernel_tiles = set(_KERNEL_TILE_FALLBACKS)
        if d is not None and kd is not None:
            kernel_tiles.add(kernel_tile_defaults(n, m, d, kd))
        for bn, bm in sorted(kernel_tiles):
            for km in _KERNEL_MERGES:
                out.append(TileConfig(bn, bm, "kernel", False,
                                      impl="pallas", kernel_merge=km))
        return out

    def rank(
        self, cands: list[TileConfig], *, b, n, m, d, kd
    ) -> list[TileConfig]:
        def prior(cfg: TileConfig) -> float:
            if cfg.impl == "pallas":
                return kernel_cost_estimate(
                    n, m, d, kd, b=b, block_n=cfg.block_n or 128,
                    block_m=cfg.block_m,
                    kernel_merge=cfg.kernel_merge or "bitonic",
                    backend=self.backend,
                )["total_s"]
            return engine_cost_estimate(
                n, m, d, kd, b=b, block_n=cfg.block_n, block_m=cfg.block_m,
                merge=cfg.merge, fuse_norms=cfg.fuse_norms,
                backend=self.backend,
            )["total_s"]

        return sorted(cands, key=prior)

    # -- persistence ----------------------------------------------------

    def lookup(self, key: str) -> Optional[TuneResult]:
        e = self.entries.get(key)
        if e is None:
            return None
        return TuneResult(
            TileConfig(e["block_n"], e["block_m"], e["merge"],
                       e.get("fuse_norms", False),
                       # pre-PR-6 entries are engine schedules
                       e.get("impl", "blocked"),
                       e.get("kernel_merge")),
            e.get("us_per_call", float("nan")),
            e.get("exact_match", True),
            "cached",
        )

    def save(self) -> None:
        if self.path is None:
            return
        self.path.write_text(json.dumps(
            {"schema": 3, "hosts": self._hosts},
            indent=2, sort_keys=True,
        ) + "\n")

    def lookup_bucket_set(
        self, *, slots: int, sizes, max_programs: int = 4,
    ) -> Optional[tuple[int, ...]]:
        """The persisted bucket set for one serving shape, or None."""
        e = self.bucket_sets.get(bucket_set_key(slots, sizes, max_programs))
        if e is None:
            return None
        return tuple(int(b) for b in e["buckets"])

    def tune_bucket_set(
        self, hist, *, slots: int, max_programs: int = 4, costs=None,
        sizes=None, force: bool = False,
    ) -> tuple[int, ...]:
        """Persisted ``optimal_bucket_set``: derive the bucket set from
        an arrival histogram and cache it per host + serving shape,
        exactly like tuned schedules — a later engine constructed with
        ``buckets="auto"`` and the same tuner path starts on it without
        re-profiling. ``sizes`` pins the shape key (default: the
        histogram's own size keys); the histogram itself is recorded in
        the entry so a cached choice stays auditable."""
        if hist and not isinstance(next(iter(hist.values())), dict):
            hist = {None: hist}
        if sizes is None:
            sizes = sorted(s for s in (hist or {}) if s is not None)
        key = bucket_set_key(slots, sizes, max_programs)
        if not force:
            e = self.bucket_sets.get(key)
            if e is not None:
                return tuple(int(b) for b in e["buckets"])
        buckets = optimal_bucket_set(
            hist, slots=slots, max_programs=max_programs, costs=costs
        )
        self.bucket_sets[key] = {
            "buckets": list(buckets),
            "hist": {
                f"{'any' if s is None else s}:{live}": int(t)
                for s, per in (hist or {}).items()
                for live, t in sorted(per.items())
            },
        }
        self.save()
        return buckets

    # -- tuning ---------------------------------------------------------

    def tune(
        self,
        x,
        y=None,
        *,
        spec: DigcSpec,
        pos_bias=None,
        force: bool = False,
        allow_approx: bool = False,
    ) -> tuple[DigcSpec, TuneResult]:
        """Fill the engine-schedule knobs of ``spec`` for this workload.

        Measures on the live arrays (so the cache records what this
        host actually does), verifies candidates against an exact
        oracle config on the same probe input, persists the winner.
        Returns (tuned spec, result). Only the ``blocked`` engine tier
        is tunable; other impls pass through unchanged.
        """
        import jax

        from repro.core.digc import digc

        if spec.impl != "blocked":
            return spec, TuneResult(
                TileConfig(spec.block_n, spec.block_m or 0,
                           spec.merge or "n/a"),
                float("nan"), True, "prior",
            )
        x3 = x if x.ndim == 3 else x[None]
        b, n, d = x3.shape
        m = n if y is None else (y.shape[-2])
        kd = spec.k * spec.dilation
        key = workload_key(b, n, m, d, kd, spec.causal,
                           pos_bias is not None,
                           mesh_shape=spec.mesh_shape())
        if not force:
            cached = self.lookup(key)
            if cached is not None:
                return cached.config.apply(spec), cached

        cands = self.rank(
            self.candidates(n, m, d=d, kd=kd, allow_approx=allow_approx),
            b=b, n=n, m=m, d=d, kd=kd,
        )[: self.max_measure]

        def run(cfg: TileConfig):
            s = cfg.apply(spec)
            fn = jax.jit(lambda a, by: digc(
                a, by, spec=s, pos_bias=pos_bias, return_dists=True,
            ))
            out = jax.block_until_ready(fn(x, y))
            times = []
            for _ in range(self.measure_iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x, y))
                times.append(time.perf_counter() - t0)
            return out, float(np.median(times))

        oracle_cfg = TileConfig(None, m, "select", False)
        oracle_out, oracle_t = run(oracle_cfg)
        oracle_idx = np.asarray(oracle_out[0])
        results = [TuneResult(oracle_cfg, oracle_t * 1e6, True, "measured")]
        for cfg in cands:
            if cfg == oracle_cfg:
                continue
            out, t = run(cfg)
            match = bool(np.array_equal(np.asarray(out[0]), oracle_idx))
            results.append(TuneResult(cfg, t * 1e6, match, "measured"))

        eligible = [
            r for r in results
            if r.exact_match or (allow_approx and r.config.merge == "packed")
        ]
        best = min(eligible, key=lambda r: r.us_per_call)
        self.entries[key] = best.as_dict()
        self.save()
        return best.config.apply(spec), best

    # -- per-stage schedules --------------------------------------------

    def tune_schedule(
        self,
        workloads: Sequence[dict],
        *,
        spec: DigcSpec,
        batch: int = 1,
        rng_seed: int = 0,
        force: bool = False,
    ) -> tuple["VigSchedule", list[TuneResult]]:
        """Tune one engine schedule per model stage.

        ``workloads`` is one dict per stage — ``{"N", "M", "D", "k",
        "dilation"}``, e.g. the first row of each stage from
        ``models.vig.count_digc_work`` — measured on synthetic probe
        arrays of the stage's true shape (pooled stages tune the real
        (N, M) workload, not a self-graph stand-in). Returns the
        ``VigSchedule`` plus the per-stage results; cached entries are
        served without re-measurement.
        """
        import jax.numpy as jnp

        rng = np.random.default_rng(rng_seed)
        stages: list[DigcSpec] = []
        results: list[TuneResult] = []
        for work in workloads:
            probe = jnp.asarray(
                rng.standard_normal((batch, work["N"], work["D"])),
                jnp.float32,
            )
            y_probe = None
            if work["M"] != work["N"]:
                y_probe = jnp.asarray(
                    rng.standard_normal((batch, work["M"], work["D"])),
                    jnp.float32,
                )
            stage_spec = spec.replace(
                k=work["k"], dilation=work["dilation"],
                block_n=None, block_m=None, merge=None, fuse_norms=None,
                kernel_merge=None,
            )
            tuned, result = self.tune(probe, y_probe, spec=stage_spec,
                                      force=force)
            stages.append(tuned)
            results.append(result)
        return VigSchedule(stages=tuple(stages)), results

    def tune_bucket_schedules(
        self,
        workloads: Sequence[dict],
        *,
        spec: DigcSpec,
        buckets: Sequence[int],
        rng_seed: int = 0,
        force: bool = False,
    ) -> tuple[dict[int, "VigSchedule"], dict[int, list[TuneResult]]]:
        """One ``VigSchedule`` per serving bucket (bucketed multi-tenant
        serving, DESIGN.md §9).

        The workload key includes the batch size, and a bucketed engine
        serves each request batch padded to a bucket — so the schedule
        must be resolved **per bucket**, not per request batch: a
        B=8-tuned tile is not a B=1-tuned tile. Returns ``{bucket:
        schedule}`` plus the per-bucket results; previously-measured
        (host-keyed) entries are served from the JSON cache.
        """
        schedules: dict[int, VigSchedule] = {}
        results: dict[int, list[TuneResult]] = {}
        for b in sorted(set(int(v) for v in buckets)):
            schedules[b], results[b] = self.tune_schedule(
                workloads, spec=spec, batch=b, rng_seed=rng_seed,
                force=force,
            )
        return schedules, results


@dataclasses.dataclass
class ReuseTuneResult:
    """One measured point of the reuse-policy search (DESIGN.md §12)."""

    policy: str
    drift_tau: float
    max_stale: int
    reuse_frac: float  # fraction of calls served from the cached graph
    recall: float      # neighbor recall of served vs per-call exact
    admitted: bool     # recall >= floor
    n: Optional[int] = None  # node count, when the trace is single-N

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def scale_tau(tau: float, n_ref: int, n: int) -> float:
    """Normalize a drift gate across N-buckets (DESIGN.md §13).

    ``drift_stat`` is a per-row mean of |x|^2 over the N nodes, so its
    tick-to-tick relative fluctuation shrinks ~1/sqrt(N): a tau
    admitted at the reference bucket ``n_ref`` under-gates (spurious
    rebuilds) at a smaller N and over-gates at a larger one. Widening
    by sqrt(n_ref / n) keeps the false-rebuild rate comparable across
    buckets; tau=0 stays exactly 0 (the bit-identity contract), and
    the statistic itself is untouched — the serving gate's formula is
    pinned by the stale-graph tests."""
    if tau == 0.0:
        return 0.0
    return float(tau) * float(np.sqrt(n_ref / max(n, 1)))


def _served_recall(served: np.ndarray, exact: np.ndarray) -> float:
    k = exact.shape[-1]
    s = served.reshape(-1, k)
    e = exact.reshape(-1, k)
    hits = 0
    for i in range(e.shape[0]):
        hits += len(set(e[i]) & set(s[i]))
    return hits / e.size


def tune_reuse(
    ticks: Sequence[Sequence[tuple]],
    *,
    spec: DigcSpec,
    policy: str = "tick",
    taus: Sequence[float] = (0.02, 0.05, 0.1, 0.2),
    max_stale: int = 4,
    recall_floor: float = 0.95,
) -> tuple[DigcSpec, list[ReuseTuneResult]]:
    """Pick the widest drift gate that keeps served-graph recall above
    ``recall_floor``, by replaying a captured feature trace through the
    stale-graph gate (DESIGN.md §12).

    ``ticks`` is a sequence of ``digc_capture`` lists — one per
    consecutive ``models.vig.vig_forward`` call on the live request
    stream, each holding ``(layer_key, h, cond)`` per DIGC call. The
    replay mirrors ``core.digc._reuse_build`` exactly (same drift
    statistic, same strict ``<`` gate, same staleness bound) but runs
    host-side against per-call exact graphs, so every candidate tau's
    *served* recall — cached rows scored against what a rebuild would
    have returned — is measured, not estimated. Among candidates whose
    mean recall clears the floor, the one skipping the most builds
    wins; if none clears it, reuse stays off (the returned spec is
    unchanged). A wider tau never lowers reuse, so this is the
    recall-constrained maximum of the swept grid.

    **Mixed resolutions** (DESIGN.md §13): ``drift_stat`` is a mean
    |x|^2 over the N nodes, so a trace that interleaves N-buckets
    under one layer key would (a) compare snapshots across unrelated
    resolutions and (b) mis-gate a tau admitted at one N when applied
    at another. The replay therefore groups per (layer_key, N) — its
    own cache stream per N-bucket, exactly how the lattice engine
    keys per-size state — and evaluates each group at the per-N
    effective gate ``scale_tau(tau, n_ref, n)`` (n_ref = the largest
    N in the trace, whose gate is the nominal tau). tau=0 scales to
    exactly 0 in every bucket — the bit-identity contract holds
    per-bucket.
    """
    from repro.core.digc import digc, drift_stat

    if policy not in ("layer", "tick", "overlap"):
        raise ValueError(f"tune_reuse: unknown policy {policy!r}")
    base = spec.replace(reuse=None, drift_tau=None, max_stale=None)

    # Group the trace per (graph-cache entry, N-bucket), preserving
    # tick structure, and compute each call's exact graph + drift
    # statistic once.
    per_key: dict[tuple, list[list[dict]]] = {}
    for tick in ticks:
        seen_this_tick: dict[tuple, int] = {}
        for layer_key, h, cond in tick:
            x3 = h if h.ndim == 3 else h[None]
            m = cond.shape[-2] if cond is not None else x3.shape[-2]
            dil = max(base.dilation, 1)
            k_eff = min(base.k, m // dil) or 1
            if k_eff * dil > m:
                dil = 1
            call_spec = base.replace(k=k_eff, dilation=dil)
            gkey = (layer_key, int(x3.shape[-2]))
            first = gkey not in seen_this_tick
            seen_this_tick[gkey] = 1
            rows = per_key.setdefault(gkey, [])
            if first:
                rows.append([])
            rows[-1].append({
                "exact": np.asarray(digc(x3, cond, spec=call_spec)),
                "stat": np.asarray(drift_stat(x3)),
            })

    ns = sorted({n for _, n in per_key})
    n_ref = ns[-1] if ns else 1
    single_n = ns[0] if len(ns) == 1 else None
    results: list[ReuseTuneResult] = []
    for tau in sorted(set(float(t) for t in taus)):
        recalls: list[float] = []
        reused = 0
        total = 0
        for (_, n), calls_by_tick in per_key.items():
            tau_n = scale_tau(tau, n_ref, n)
            cached = snap = age = None
            for calls in calls_by_tick:
                for ci, call in enumerate(calls):
                    stat, exact = call["stat"], call["exact"]
                    total += stat.shape[0]
                    if cached is None:
                        reuse_row = np.zeros(stat.shape, bool)
                    elif policy == "overlap":
                        reuse_row = np.ones(stat.shape, bool)
                    elif policy == "tick" and ci > 0:
                        reuse_row = np.ones(stat.shape, bool)
                    else:
                        drift = (np.abs(stat - snap)
                                 / np.maximum(np.abs(snap), 1e-9))
                        reuse_row = (age < max_stale) & (drift < tau_n)
                    reused += int(reuse_row.sum())
                    if reuse_row.all() and policy != "overlap":
                        served = cached
                        age = age + (0 if policy == "tick" and ci > 0
                                     else 1)
                    else:
                        sel = reuse_row.reshape(
                            reuse_row.shape + (1,) * (exact.ndim - 1))
                        served = (np.where(sel, cached, exact)
                                  if cached is not None else exact)
                        cached, snap = exact, stat
                        age = np.where(reuse_row,
                                       (age if age is not None else 0) + 1,
                                       0)
                    recalls.append(_served_recall(served, exact))
        recall = float(np.mean(recalls)) if recalls else 1.0
        frac = reused / total if total else 0.0
        results.append(ReuseTuneResult(
            policy, tau, max_stale, frac, recall,
            bool(recall >= recall_floor), n=single_n,
        ))
        if policy == "overlap":
            break  # tau does not enter the overlap gate

    admitted = [r for r in results if r.admitted]
    if not admitted:
        return spec, results
    best = max(admitted, key=lambda r: (r.reuse_frac, r.drift_tau))
    return spec.replace(reuse=policy, drift_tau=best.drift_tau,
                        max_stale=max_stale), results


@dataclasses.dataclass(frozen=True)
class VigSchedule:
    """Stage -> tuned ``DigcSpec`` map for a pyramid/isotropic model.

    The PR-2 engine tuned the stage-0 workload and applied those knobs
    to every stage; a schedule gives each stage its own measured entry
    (later pyramid stages run at N/4, N/16, ... and pooled co-nodes —
    different optimal tiles). Stages beyond the tuple reuse the last
    entry, so an isotropic model's schedule is one spec.
    """

    stages: tuple[DigcSpec, ...]

    def spec_for(self, si: int) -> DigcSpec:
        if not self.stages:
            raise ValueError("empty VigSchedule")
        return self.stages[min(si, len(self.stages) - 1)]

    def with_reuse(
        self,
        policy: Optional[str],
        drift_tau: Optional[float] = None,
        max_stale: Optional[int] = None,
    ) -> "VigSchedule":
        """Overlay a stale-graph reuse policy (DESIGN.md §12) on every
        stage whose tier carries construction state. Stateless tiers
        (e.g. the fused Pallas kernel) keep their tuned spec unchanged
        — their builders have no cache to serve from, and the knobs
        would be rejected by ``validate``. ``policy=None`` strips the
        reuse knobs everywhere."""
        from repro.core.builder import get_builder

        stages = []
        for s in self.stages:
            if policy is None:
                stages.append(s.replace(reuse=None, drift_tau=None,
                                        max_stale=None))
            elif get_builder(s.impl).supports_state:
                stages.append(s.replace(reuse=policy, drift_tau=drift_tau,
                                        max_stale=max_stale))
            else:
                stages.append(s)
        return VigSchedule(stages=tuple(stages))

    def describe(self) -> list[dict]:
        return [
            {
                "stage": si,
                "impl": s.impl,
                "block_n": s.block_n,
                "block_m": s.block_m,
                "merge": s.merge,
                "fuse_norms": bool(s.fuse_norms),
                "kernel_merge": s.kernel_merge,
                "reuse": s.reuse,
            }
            for si, s in enumerate(self.stages)
        ]


def autotune_spec(
    x,
    y=None,
    *,
    spec: DigcSpec,
    pos_bias=None,
    path: Optional[str | Path] = None,
    tuner: Optional[DigcTuner] = None,
    **kw,
) -> tuple[DigcSpec, TuneResult]:
    """One-shot convenience: tune ``spec``'s engine schedule for x/y."""
    tuner = tuner if tuner is not None else DigcTuner(path)
    return tuner.tune(x, y, spec=spec, pos_bias=pos_bias, **kw)
