# Synthetic deterministic data pipelines (host-sharded, prefetch).
