"""Deterministic synthetic data pipelines, host-sharded, with
double-buffered prefetch.

Every batch is a pure function of (seed, step, host_id) — the property
fault-tolerant training needs: after restart from step N the pipeline
replays batch N+1 exactly, on any number of hosts (elastic restore
re-partitions the host shard)."""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _rng_for(dc: DataConfig, step: int) -> np.random.Generator:
    # independent stream per (seed, step, host)
    return np.random.Generator(
        np.random.Philox(key=dc.seed, counter=[step, dc.host_id, 0, 0])
    )


def synth_lm_batch(dc: DataConfig, step: int) -> dict:
    """Markov synthetic token stream over a small active alphabet:
    next = (tok + noise) % A with A = min(vocab, 32). Structured enough
    to be learnable within tens of steps at smoke scale (the unigram
    restriction alone drops loss from ln(V) to ~ln(A)), while exercising
    the full vocab-sized embedding/unembedding path."""
    rng = _rng_for(dc, step)
    b, s = dc.host_batch, dc.seq_len
    active = min(dc.vocab_size, 32)
    first = rng.integers(0, active, size=(b, 1))
    noise = rng.integers(0, 4, size=(b, s))
    toks = np.zeros((b, s + 1), np.int64)
    toks[:, :1] = first
    for t in range(1, s + 1):
        toks[:, t] = (toks[:, t - 1] + noise[:, t - 1]) % active
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
        "mask": np.ones((b, s), np.float32),
    }


def synth_image_batch(dc: DataConfig, step: int, *, image_size: int,
                      num_classes: int) -> dict:
    """Class-conditional gaussian blobs: images carry label signal."""
    rng = _rng_for(dc, step)
    b = dc.host_batch
    labels = rng.integers(0, num_classes, size=(b,))
    base = rng.standard_normal((b, image_size, image_size, 3)).astype(np.float32)
    # plant a label-dependent low-frequency pattern
    yy, xx = np.meshgrid(np.arange(image_size), np.arange(image_size), indexing="ij")
    for i, lab in enumerate(labels):
        base[i] += 0.5 * np.sin(
            2 * np.pi * (lab + 1) * (yy + xx) / (2 * image_size)
        )[..., None].astype(np.float32)
    return {"images": base, "labels": labels.astype(np.int32)}


class PrefetchIterator:
    """Background-thread double buffering (host-side pipeline overlap)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def lm_pipeline(dc: DataConfig, start_step: int = 0) -> PrefetchIterator:
    return PrefetchIterator(lambda s: synth_lm_batch(dc, s), start_step)


def image_pipeline(dc: DataConfig, image_size: int, num_classes: int,
                   start_step: int = 0) -> PrefetchIterator:
    return PrefetchIterator(
        lambda s: synth_image_batch(dc, s, image_size=image_size,
                                    num_classes=num_classes),
        start_step,
    )
