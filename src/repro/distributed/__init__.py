# Distributed utilities: compression, stragglers, pipeline parallelism.
