"""Gradient compression: int8 ring all-reduce with error feedback.

For DCN-bound multi-pod training the cross-pod gradient all-reduce is
the dominant collective. This module quantizes chunks to int8 with a
per-chunk fp32 scale (~4x traffic cut), runs a ring reduce-scatter +
all-gather over `collective_permute` (bandwidth-optimal), and keeps the
quantization residual in an error-feedback buffer so compression noise
does not bias the optimizer (1-bit-Adam-family argument).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12  # scalar per chunk
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x: jax.Array, axis_name: str, n_dev: int) -> jax.Array:
    """Bandwidth-optimal ring all-reduce; each hop's payload is int8 +
    one fp32 scale per chunk. x: (n_dev * chunk,) fp32 -> summed."""
    chunk = x.shape[0] // n_dev
    xs = x.reshape(n_dev, chunk)
    me = lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    # reduce-scatter: after n-1 hops, device d owns the full sum of
    # chunk (d+1) mod n
    def rs_hop(h, acc):
        send_idx = (me - h) % n_dev
        payload = jnp.take(acc, send_idx, axis=0)
        q, s = quantize_int8(payload)
        q_r = lax.ppermute(q, axis_name, fwd)
        s_r = lax.ppermute(s, axis_name, fwd)
        recv = dequantize_int8(q_r, s_r)
        recv_idx = (me - h - 1) % n_dev
        return acc.at[recv_idx].add(recv)

    acc = lax.fori_loop(0, n_dev - 1, rs_hop, xs)

    # all-gather the owned chunks (int8 again)
    def ag_hop(h, acc):
        send_idx = (me + 1 - h) % n_dev
        payload = jnp.take(acc, send_idx, axis=0)
        q, s = quantize_int8(payload)
        q_r = lax.ppermute(q, axis_name, fwd)
        s_r = lax.ppermute(s, axis_name, fwd)
        recv = dequantize_int8(q_r, s_r)
        recv_idx = (me - h) % n_dev
        return acc.at[recv_idx].set(recv)

    acc = lax.fori_loop(0, n_dev - 1, ag_hop, acc)
    return acc.reshape(-1)


def compressed_psum(x: jax.Array, axis_name: str, n_dev: int) -> jax.Array:
    """Drop-in psum replacement (int8 ring). x flat fp32, padded to
    n_dev multiple by the caller."""
    return _ring_allreduce_int8(x, axis_name, n_dev)


def compressed_allreduce_tree(grads, mesh: Mesh, axis_name: str = "pod"):
    """All-reduce a gradient pytree across `axis_name` with int8 ring
    compression. Grads must be identical-shaped on every member (DP).
    Returns the SUM (caller divides)."""
    n_dev = mesh.shape[axis_name]
    if n_dev == 1:
        return grads
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    pad = (-flat.size) % n_dev
    if pad:
        flat = jnp.pad(flat, (0, pad))

    body = functools.partial(compressed_psum, axis_name=axis_name, n_dev=n_dev)
    other = tuple(a for a in mesh.axis_names if a != axis_name)
    mapped = compat.shard_map(
        body,
        mesh,
        in_specs=P(),
        out_specs=P(),
    )
    summed = mapped(flat)
    if pad:
        summed = summed[: flat.size - pad]
    out = []
    off = 0
    for l, n in zip(leaves, sizes):
        out.append(summed[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


class ErrorFeedback:
    """Residual accumulator: g_compressed = Q(g + e); e' = (g + e) -
    dequant(Q(...)). Keeps long-run compression error unbiased."""

    @staticmethod
    def init(grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )

    @staticmethod
    def apply(grads, residual):
        corrected = jax.tree_util.tree_map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual
        )
        q = jax.tree_util.tree_map(lambda c: dequantize_int8(*quantize_int8(c)), corrected)
        new_residual = jax.tree_util.tree_map(lambda c, d: c - d, corrected, q)
        return q, new_residual
