"""GPipe-style pipeline parallelism over a `stage` mesh axis.

Layers (stacked along the leading dim) are split into S contiguous
stages; microbatches stream through the stage ring via
`collective_permute`. After M + S - 1 ticks every microbatch has
crossed every stage. Opt-in for deep dense models where FSDP+TP alone
leaves the HBM budget tight; the bubble fraction is (S-1)/(M+S-1).

The implementation is deliberately schedule-explicit (the tick loop is
`lax.fori_loop`, the handoff a single ppermute) so the collective
pattern in the lowered HLO is inspectable — this is what the dry-run
roofline reads.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat


def pipeline_apply(
    layer_fn: Callable,
    stacked_params,
    x: jax.Array,
    *,
    mesh: Mesh,
    num_microbatches: int,
    axis_name: str = "stage",
):
    """Run x (B, ...) through L stacked layers split over the `stage`
    axis. layer_fn(params_one_layer, activations) -> activations.

    Returns the final activations (B, ...), bit-equal to the sequential
    scan over all L layers (fp32; modulo dtype rounding otherwise).
    """
    n_stages = mesh.shape[axis_name]
    b = x.shape[0]
    assert b % num_microbatches == 0, (b, num_microbatches)
    mb = b // num_microbatches
    xs = x.reshape((num_microbatches, mb) + x.shape[1:])

    def stage_body(params_local, xs_local):
        # params arrive as the local stage shard (1, L/S, ...): drop the
        # sharded leading axis.
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage = lax.axis_index(axis_name)
        ticks = num_microbatches + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        state = jnp.zeros_like(xs_local[0])  # current activation
        outs = jnp.zeros_like(xs_local)

        def apply_stage(h):
            def scan_fn(carry, lp):
                return layer_fn(lp, carry), None

            out, _ = lax.scan(scan_fn, h, params_local)
            return out

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (when in range)
            mb_idx = jnp.clip(t, 0, num_microbatches - 1)
            fresh = lax.dynamic_index_in_dim(xs_local, mb_idx, keepdims=False)
            h = jnp.where(stage == 0, fresh, state)
            y = apply_stage(h)
            # last stage commits microbatch (t - (S-1)) when valid
            out_idx = jnp.clip(t - (n_stages - 1), 0, num_microbatches - 1)
            commit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = lax.cond(
                commit,
                lambda o: lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outs,
            )
            state = lax.ppermute(y, axis_name, fwd)
            return state, outs

        _, outs = lax.fori_loop(0, ticks, tick, (state, outs))
        return outs[None]  # leading stage axis for out_specs

    mapped = compat.shard_map(
        stage_body,
        mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(axis_name),
    )
    # params stacked (L, ...) -> sharded (S, L/S, ...) over stage axis
    def to_stages(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape((n_stages, l // n_stages) + p.shape[1:])

    staged = jax.tree_util.tree_map(to_stages, stacked_params)
    outs = mapped(staged, xs)  # (S, M, mb, ...): only last stage's rows valid
    final = outs[-1]
    return final.reshape((b,) + x.shape[1:])
