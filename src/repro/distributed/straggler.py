"""Straggler detection & step-time monitoring.

At thousand-node scale, a single slow host gates every synchronous
collective. The monitor keeps a rolling window of per-step wall times,
flags outliers (median + k*MAD), and exposes hooks the launcher uses to
(a) log offending hosts, (b) trigger elastic reconfiguration when a
host is persistently slow (drop it, reshard from checkpoint — see
ckpt.restore's elastic path)."""

from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StragglerConfig:
    window: int = 50
    mad_k: float = 5.0
    min_samples: int = 10
    persistent_threshold: int = 3  # consecutive flags before escalation


@dataclass
class StepTimer:
    """Context manager measuring one step."""

    monitor: "StragglerMonitor"
    _t0: float = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.monitor.record(time.perf_counter() - self._t0)
        return False


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 host_id: int = 0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.host_id = host_id
        self.times: collections.deque = collections.deque(maxlen=cfg.window)
        self.flags = 0
        self.total_flags = 0
        self.on_straggler = on_straggler

    def step_timer(self) -> StepTimer:
        return StepTimer(self)

    def record(self, dt: float):
        self.times.append(dt)
        if self.is_straggler(dt):
            self.flags += 1
            self.total_flags += 1
            if self.on_straggler and self.flags >= self.cfg.persistent_threshold:
                self.on_straggler(self.host_id, dt)
        else:
            self.flags = 0

    def is_straggler(self, dt: float) -> bool:
        if len(self.times) < self.cfg.min_samples:
            return False
        med = statistics.median(self.times)
        mad = statistics.median(abs(t - med) for t in self.times) + 1e-9
        # relative floor: near-zero MAD (very stable steps) must not flag
        # sub-percent jitter
        return dt > med + max(self.cfg.mad_k * mad, 0.2 * med)

    def stats(self) -> dict:
        if not self.times:
            return {"median_s": 0.0, "p95_s": 0.0, "flags": self.total_flags}
        ts = sorted(self.times)
        return {
            "median_s": statistics.median(ts),
            "p95_s": ts[int(0.95 * (len(ts) - 1))],
            "flags": self.total_flags,
        }


def aggregate_host_times(step_times: dict[int, float],
                         cfg: StragglerConfig = StragglerConfig()) -> list[int]:
    """Cluster-level view: given {host_id: step_time} (collected via the
    coordination service), return host ids gating the step."""
    if len(step_times) < 2:
        return []
    vals = list(step_times.values())
    med = statistics.median(vals)
    mad = statistics.median(abs(v - med) for v in vals) + 1e-9
    thresh = med + max(cfg.mad_k * mad, 0.2 * med)
    return [h for h, v in step_times.items() if v > thresh]
