"""Fused streaming DIGC kernel: pairwise distance + running top-(k*d).

TPU-native port of the paper's DCM + LSM + GMM pipeline (DESIGN.md §2):

  * grid = (B, N/block_n, M/block_m); batch is the leading grid
    dimension (no model-level vmap over interpret-mode calls), node
    blocks are independent ("parallel"), the co-node dimension streams
    ("arbitrary"). The Pallas grid pipeline overlaps the HBM->VMEM DMA
    of tile j+1 with the compute of tile j — the TPU analogue of the
    FPGA's deep pipelining.
  * DCM: one MXU contraction per tile, `x_blk @ y_blk^T`, plus the
    rank-1 norm terms. fp32 accumulation.
  * LSM (default ``kernel_merge="bitonic"``): each (bn, bm) tile is
    reduced to its sorted top-kd_pad by a partial bitonic sort — sort
    width-kd_pad groups in O(log^2 kd_pad) data-independent VPU
    passes, then tournament-merge group pairs (core/packedkey.py, the
    networks shared with the engine's packed merge).
  * GMM: the tile's sorted list folds into a running sorted buffer
    with ONE O(log kd_pad) bitonic merge of two sorted sequences — the
    paper's heap insertion as a sorting network. The buffer lives in a
    VMEM **scratch accumulator** (``scratch_shapes``), not in
    revisited output blocks: outputs are written once per (b, i)
    row-block, on the last streaming step.
  * ``kernel_merge="legacy"`` keeps the previous kd-sequential
    (min, argmin, mask) extraction merge (and its ``bucket_rounds``
    approximate pre-reduction) as a measured alternative — the tuner
    treats old-vs-new as a per-workload choice.
  * NSM (stride-d selection) happens in the wrapper (`ops.digc_topk`);
    the kernel returns the full sorted top-(k*d) list, matching the
    paper's modular split.

The full N x M distance matrix never exists in HBM (or VMEM): per-tile
working set = block_n*D + block_m*D + block_n*block_m + 2*block_n*kd
floats (+ 2*block_n*kd_pad scratch), chosen to fit VMEM with
MXU-aligned tile shapes.

Validated in interpret mode on CPU against ``ref.digc_reference``; the
lowering target is TPU v5e. ``interpret=None`` resolves to compiled on
a TPU backend and interpret everywhere else.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

# Packed (dist|idx) int32 keys and the bitonic sort/merge networks are
# shared with the XLA engine's packed merge (core/engine.py) — one
# format and one network family across tiers (DESIGN.md §5).
from repro.core.packedkey import (
    IDX_FILL,
    INT_BIG,
    bitonic_merge_sorted,
    bitonic_topk,
    dist_idx_less,
    idx_bits_for,
    merge_sorted,
    next_pow2,
    topk_keys,
)
from repro.core.packedkey import pack_keys as _pack_keys
from repro.core.packedkey import unpack_keys as _unpack_keys

BIG = float(1e30)  # plain float: jnp scalars would be captured as consts

KERNEL_MERGES = ("bitonic", "legacy")


def _bucket_reduce(blk_k, kd: int, rounds: int):
    """Pre-reduce a packed tile (bn, bm) to its per-bucket top-`rounds`
    candidates: bm columns fold into kd buckets, one min-pass per round.
    O(rounds) passes instead of O(kd) — the LSM local-sort stage taken
    to its cheapest useful form. Per-tile approximate, but the global
    top-kd is spread across tiles, so end-to-end recall stays high
    (measured in tests/benchmarks; rounds trades recall vs speed)."""
    bn, bm = blk_k.shape
    g = kd
    w = bm // g
    resh = blk_k.reshape(bn, g, w)
    outs = []
    for r in range(rounds):
        m = jnp.min(resh, axis=2)  # (bn, g)
        outs.append(m)
        if r + 1 < rounds:
            resh = jnp.where(resh == m[:, :, None], INT_BIG, resh)
    return jnp.concatenate(outs, axis=1)  # (bn, g*rounds)


def _merge_body_packed(kd: int, run_k, blk_k):
    """Legacy packed-key merge: kd passes of (min, compare-mask) over
    one int32 candidate array. ~2 VPU ops/element/pass vs ~4 for the
    two-array form, half the VMEM operand traffic. Keys are unique
    (index bits), so the masked update hits exactly one lane per pass."""
    cand = jnp.concatenate([run_k, blk_k], axis=1)  # (bn, kd+bm) int32
    bn = cand.shape[0]
    out_col = lax.broadcasted_iota(jnp.int32, (bn, kd), 1)

    def body(t, state):
        cand, out = state
        m = jnp.min(cand, axis=1)  # (bn,) packed min == (dist, idx) min
        out = jnp.where(out_col == t, m[:, None], out)
        cand = jnp.where(cand == m[:, None], INT_BIG, cand)
        return cand, out

    _, out = lax.fori_loop(
        0, kd, body, (cand, jnp.full((bn, kd), INT_BIG, jnp.int32))
    )
    return out


def _merge_body(kd: int, run_d, run_i, blk_d, blk_i):
    """Legacy merge: k*d rounds of (min, argmin, mask) over
    [running | tile] candidates.

    Returns the new sorted running (dist, idx) pair. All ops are
    elementwise/reduction VPU ops — no sort networks, no data-dependent
    control flow, but kd *sequential* extraction passes per tile (the
    cost the bitonic path removes).
    """
    cand_d = jnp.concatenate([run_d, blk_d], axis=1)  # (bn, kd+bm)
    cand_i = jnp.concatenate([run_i, blk_i], axis=1)
    bn = cand_d.shape[0]
    width = cand_d.shape[1]
    col = lax.broadcasted_iota(jnp.int32, (bn, width), 1)
    out_col = lax.broadcasted_iota(jnp.int32, (bn, kd), 1)

    def body(t, state):
        cd, od, oi = state
        amin = jnp.argmin(cd, axis=1)  # (bn,)
        vmin = jnp.min(cd, axis=1)
        hit = col == amin[:, None]
        gidx = jnp.max(jnp.where(hit, cand_i, jnp.int32(-1)), axis=1)
        od = jnp.where(out_col == t, vmin[:, None], od)
        oi = jnp.where(out_col == t, gidx[:, None], oi)
        cd = jnp.where(hit, BIG, cd)
        return cd, od, oi

    init = (
        cand_d,
        jnp.full((bn, kd), BIG, jnp.float32),
        jnp.zeros((bn, kd), jnp.int32),
    )
    _, out_d, out_i = lax.fori_loop(0, kd, body, init)
    return out_d, out_i


def _digc_kernel(x_ref, y_ref, *rest, kd: int, kd_pad: int, m_total: int,
                 block_m: int, block_n: int, has_pos: bool, causal: bool,
                 packed: bool, mxu_bf16: bool, kernel_merge: str,
                 idx_bits: int = 16, bucket_rounds: int = 0):
    refs = list(rest)
    p_ref = refs.pop(0) if has_pos else None
    if packed:
        ok_ref = refs.pop(0)  # int32 packed (dist|idx) output
    else:
        od_ref = refs.pop(0)
        oi_ref = refs.pop(0)
    bitonic = kernel_merge == "bitonic"
    if bitonic:
        # VMEM scratch accumulator (bn, kd_pad): the running sorted
        # buffer. Outputs are written once, on the last streaming step.
        if packed:
            (ak_ref,) = refs
        else:
            ad_ref, ai_ref = refs
    # grid = (B, N/bn, M/bm): program_id(0) is the batch index (its
    # blocks are squeezed out of the refs by the None BlockSpec dims).
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        if bitonic:
            if packed:
                ak_ref[...] = jnp.full(ak_ref.shape, INT_BIG, jnp.int32)
            else:
                ad_ref[...] = jnp.full(ad_ref.shape, BIG, jnp.float32)
                ai_ref[...] = jnp.full(ai_ref.shape, IDX_FILL, jnp.int32)
        elif packed:
            ok_ref[...] = jnp.full(ok_ref.shape, INT_BIG, jnp.int32)
        else:
            od_ref[...] = jnp.full(od_ref.shape, BIG, jnp.float32)
            oi_ref[...] = jnp.zeros(oi_ref.shape, jnp.int32)

    def _do_tile():
        if mxu_bf16:
            # MXU-native: bf16 x bf16 -> fp32 accumulation (4x the fp32
            # matmul rate on v5e). Norm terms stay fp32.
            x = x_ref[...].astype(jnp.bfloat16)
            y = y_ref[...].astype(jnp.bfloat16)
        else:
            x = x_ref[...].astype(jnp.float32)
            y = y_ref[...].astype(jnp.float32)
        x32 = x.astype(jnp.float32)
        y32 = y.astype(jnp.float32)
        sq_x = jnp.sum(x32 * x32, axis=1, keepdims=True)  # (bn, 1)
        sq_y = jnp.sum(y32 * y32, axis=1)  # (bm,)
        # DCM: MXU contraction, fp32 accumulate.
        xy = lax.dot_general(
            x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bn, bm)
        d_blk = sq_x - 2.0 * xy + sq_y[None, :]
        if p_ref is not None:
            d_blk = d_blk + p_ref[...].astype(jnp.float32)
        bn, bm = d_blk.shape
        cols = j * block_m + lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
        d_blk = jnp.where(cols < m_total, d_blk, BIG)
        if causal:
            rows = i * block_n + lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
            d_blk = jnp.where(cols <= rows, d_blk, BIG)

        if packed:
            blk_k = _pack_keys(d_blk, cols, idx_bits)
            if bitonic:
                # LSM: sorted top-kd_pad of the tile; GMM: one sorted
                # merge into the running scratch buffer.
                ak_ref[...] = merge_sorted(
                    ak_ref[...], topk_keys(blk_k, kd_pad)
                )
            else:
                if bucket_rounds > 0:
                    blk_k = _bucket_reduce(blk_k, kd, bucket_rounds)
                ok_ref[...] = _merge_body_packed(kd, ok_ref[...], blk_k)
        elif bitonic:
            tile_d, tile_i = bitonic_topk(
                (d_blk, cols), kd_pad, dist_idx_less, (BIG, IDX_FILL)
            )
            run_d, run_i = bitonic_merge_sorted(
                (ad_ref[...], ai_ref[...]), (tile_d, tile_i), dist_idx_less
            )
            ad_ref[...] = run_d
            ai_ref[...] = run_i
        else:
            run_d, run_i = _merge_body(kd, od_ref[...], oi_ref[...], d_blk, cols)
            od_ref[...] = run_d
            oi_ref[...] = run_i

    if causal:
        # Tiles strictly above the block diagonal contribute nothing:
        # skip the matmul + merge entirely (the FPGA has no such early
        # exit; this is a free TPU-side win from static grid predication).
        @pl.when(j * block_m <= i * block_n + (block_n - 1))
        def _live():
            _do_tile()
    else:
        _do_tile()

    if bitonic:
        # Single unpack/write per (b, i) row-block — the scratch
        # accumulator replaces the revisited-output-block pattern.
        @pl.when(j == pl.num_programs(2) - 1)
        def _final():
            if packed:
                ok_ref[...] = ak_ref[..., :kd]
            else:
                od_ref[...] = ad_ref[..., :kd]
                oi_ref[...] = ai_ref[..., :kd]


@functools.partial(
    jax.jit,
    static_argnames=("kd", "block_n", "block_m", "interpret", "m_valid",
                     "causal", "packed", "mxu_bf16", "bucket_rounds",
                     "kernel_merge"),
)
def digc_topk_pallas(
    x: jax.Array,
    y: jax.Array,
    pos_bias: Optional[jax.Array] = None,
    *,
    kd: int,
    block_n: int = 128,
    block_m: int = 256,
    interpret: Optional[bool] = None,
    m_valid: Optional[int] = None,
    causal: bool = False,
    packed: bool = False,
    mxu_bf16: bool = False,
    bucket_rounds: int = 0,
    kernel_merge: Optional[str] = None,
):
    """Run the fused kernel with batch as the leading grid dimension.

    x (B, N, D) or (N, D) (promoted to B=1 and squeezed back), y
    likewise, pos_bias (B, N, M) / (N, M). Inputs must be pre-padded:
    N % block_n == 0, M % block_m == 0 (use ``ops.digc_topk`` for the
    padding wrapper). Returns (dist, idx), each (B, N, kd) — (N, kd)
    for unbatched input — sorted ascending by distance. ``m_valid`` is
    the true (unpadded) co-node count; columns >= m_valid are masked to
    BIG inside the kernel.

    ``kernel_merge``: "bitonic" (default; partial bitonic LSM + sorted
    GMM, exact when unpacked) or "legacy" (kd-pass extraction merge).
    ``bucket_rounds`` implies/requires the legacy packed path.
    ``interpret=None`` resolves to compiled on TPU, interpret elsewhere.
    """
    if kernel_merge is None:
        kernel_merge = "legacy" if bucket_rounds > 0 else "bitonic"
    if kernel_merge not in KERNEL_MERGES:
        raise ValueError(
            f"unknown kernel_merge {kernel_merge!r}; expected one of "
            f"{KERNEL_MERGES}"
        )
    if bucket_rounds > 0:
        # The preconditions the kernel used to check (and silently skip
        # on) are wrapper-level contract violations now.
        if kernel_merge != "legacy":
            raise ValueError(
                "bucket_rounds pre-reduction belongs to the legacy merge; "
                f"got kernel_merge={kernel_merge!r} with "
                f"bucket_rounds={bucket_rounds}"
            )
        if not packed:
            raise ValueError("bucket_rounds requires packed=True keys")
        if block_m % kd != 0 or block_m // kd < 2:
            raise ValueError(
                "bucket_rounds requires block_m % kd == 0 and "
                f"block_m // kd >= 2; got block_m={block_m}, kd={kd}"
            )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
        y = y[None]
        if pos_bias is not None:
            pos_bias = pos_bias[None]
    b, n, feat = x.shape
    m = y.shape[1]
    assert y.shape[0] == b, (x.shape, y.shape)
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    if packed and m > 65536:
        raise ValueError("packed keys hold u16 indices: require M <= 65536")
    m_real = m_valid if m_valid is not None else m
    idx_bits = idx_bits_for(m_real) if packed else 16
    kd_pad = next_pow2(kd)
    grid = (b, n // block_n, m // block_m)

    kernel = functools.partial(
        _digc_kernel,
        kd=kd,
        kd_pad=kd_pad,
        m_total=m_valid if m_valid is not None else m,
        block_m=block_m,
        block_n=block_n,
        has_pos=pos_bias is not None,
        causal=causal,
        packed=packed,
        mxu_bf16=mxu_bf16,
        kernel_merge=kernel_merge,
        idx_bits=idx_bits,
        bucket_rounds=bucket_rounds,
    )
    # Leading None squeezes the batch dim out of the refs: each program
    # instance sees the same 2D tile shapes as the single-image kernel.
    in_specs = [
        pl.BlockSpec((None, block_n, feat), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((None, block_m, feat), lambda b, i, j: (b, j, 0)),
    ]
    args = [x, y]
    if pos_bias is not None:
        in_specs.append(
            pl.BlockSpec((None, block_n, block_m), lambda b, i, j: (b, i, j))
        )
        args.append(pos_bias)

    run_spec = pl.BlockSpec((None, block_n, kd), lambda b, i, j: (b, i, 0))
    if packed:
        out_shape = [jax.ShapeDtypeStruct((b, n, kd), jnp.int32)]
        out_specs = [run_spec]
    else:
        out_shape = [
            jax.ShapeDtypeStruct((b, n, kd), jnp.float32),
            jax.ShapeDtypeStruct((b, n, kd), jnp.int32),
        ]
        out_specs = [run_spec, run_spec]
    scratch_shapes = []
    if kernel_merge == "bitonic":
        if packed:
            scratch_shapes = [pltpu.VMEM((block_n, kd_pad), jnp.int32)]
        else:
            scratch_shapes = [
                pltpu.VMEM((block_n, kd_pad), jnp.float32),
                pltpu.VMEM((block_n, kd_pad), jnp.int32),
            ]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(*args)
    if packed:
        dist, idx = _unpack_keys(outs[0], idx_bits)
    else:
        dist, idx = outs[0], outs[1]
    if squeeze:
        dist, idx = dist[0], idx[0]
    return dist, idx
