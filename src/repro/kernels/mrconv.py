"""Fused max-relative graph convolution (MRConv) kernel.

The consumer of DIGC's neighbor lists inside every ViG Grapher block:

    agg[i] = max_{j in N(i)} (y[idx[i, j]] - x[i])

TPU adaptation: arbitrary row gathers are the classic weak spot of the
vector unit, so the gather is expressed as a one-hot contraction on the
MXU (`onehot(idx) @ Y`) — the standard TPU embedding-gather idiom. The
co-node table streams through VMEM in blocks; each (node-block,
co-block) tile contributes its rows via a masked one-hot matmul and a
running elementwise max, so neither the full one-hot matrix nor an
(N, k, D) gathered tensor ever materializes.

grid = (N/bn, M/bm); per-tile work: bn*k x bm one-hot + MXU contraction
(bn*k, bm) @ (bm, D). Validated in interpret mode vs ref.mr_aggregate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mrconv_kernel(x_ref, idx_ref, y_ref, o_ref, *, block_m: int, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, NEG, jnp.float32)

    x = x_ref[...].astype(jnp.float32)  # (bn, D)
    y = y_ref[...].astype(jnp.float32)  # (bm, D)
    idx = idx_ref[...]  # (bn, k) global co-node ids
    bn, d = x.shape
    bm = y.shape[0]

    # one-hot rows for neighbors that live in THIS co-block
    local = idx - j * block_m  # (bn, k)
    flat = local.reshape(bn * k)
    cols = lax.broadcasted_iota(jnp.int32, (bn * k, bm), 1)
    onehot = (cols == flat[:, None]).astype(y.dtype)  # 0 rows if out of block
    gathered = lax.dot_general(
        onehot, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bn, k, d)
    in_block = (local >= 0) & (local < bm)  # (bn, k)
    rel = gathered - x[:, None, :]
    rel = jnp.where(in_block[:, :, None], rel, NEG)
    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(rel, axis=1))


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def mrconv_pallas(x: jax.Array, y: jax.Array, idx: jax.Array, *,
                  block_n: int = 128, block_m: int = 512,
                  interpret: bool = True) -> jax.Array:
    """x: (N, D) nodes, y: (M, D) co-nodes, idx: (N, k) neighbor ids
    -> (N, D) max-relative aggregate. Requires N % block_n == 0 and
    M % block_m == 0 (see ops.mrconv for the padding wrapper)."""
    n, d = x.shape
    m = y.shape[0]
    k = idx.shape[1]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (n // block_n, m // block_m)
    kernel = functools.partial(_mrconv_kernel, block_m=block_m, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(x, idx.astype(jnp.int32), y)
