"""Fused max-relative graph convolution (MRConv) kernel.

The consumer of DIGC's neighbor lists inside every ViG Grapher block:

    agg[i] = max_{j in N(i)} (y[idx[i, j]] - x[i])

TPU adaptation: arbitrary row gathers are the classic weak spot of the
vector unit, so the gather is expressed as a one-hot contraction on the
MXU (`onehot(idx) @ Y`) — the standard TPU embedding-gather idiom. The
co-node table streams through VMEM in blocks; each (node-block,
co-block) tile contributes its rows via a masked one-hot matmul and a
running elementwise max, so neither the full one-hot matrix nor an
(N, k, D) gathered tensor ever materializes.

grid = (B, N/bn, M/bm) with batch as the leading ("parallel") grid
dimension; per-tile work: bn*k x bm one-hot + MXU contraction
(bn*k, bm) @ (bm, D). Validated in interpret mode vs ref.mr_aggregate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.compat import tpu_compiler_params

NEG = -1e30


def _mrconv_kernel(x_ref, idx_ref, y_ref, o_ref, *, block_m: int, k: int):
    # grid = (B, N/bn, M/bm); batch blocks are squeezed out of the refs.
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, NEG, jnp.float32)

    x = x_ref[...].astype(jnp.float32)  # (bn, D)
    y = y_ref[...].astype(jnp.float32)  # (bm, D)
    idx = idx_ref[...]  # (bn, k) global co-node ids
    bn, d = x.shape
    bm = y.shape[0]

    # one-hot rows for neighbors that live in THIS co-block
    local = idx - j * block_m  # (bn, k)
    flat = local.reshape(bn * k)
    cols = lax.broadcasted_iota(jnp.int32, (bn * k, bm), 1)
    onehot = (cols == flat[:, None]).astype(y.dtype)  # 0 rows if out of block
    gathered = lax.dot_general(
        onehot, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bn, k, d)
    in_block = (local >= 0) & (local < bm)  # (bn, k)
    rel = gathered - x[:, None, :]
    rel = jnp.where(in_block[:, :, None], rel, NEG)
    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(rel, axis=1))


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def mrconv_pallas(x: jax.Array, y: jax.Array, idx: jax.Array, *,
                  block_n: int = 128, block_m: int = 512,
                  interpret: bool = True) -> jax.Array:
    """x: (B, N, D) nodes, y: (B, M, D) co-nodes, idx: (B, N, k)
    neighbor ids -> (B, N, D) max-relative aggregate; (N, D)-rank inputs
    are promoted to B=1 and squeezed back. Requires N % block_n == 0 and
    M % block_m == 0 (see ops.mrconv for the padding wrapper)."""
    squeeze = x.ndim == 2
    if squeeze:
        x, y, idx = x[None], y[None], idx[None]
    b, n, d = x.shape
    m = y.shape[1]
    k = idx.shape[2]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (b, n // block_n, m // block_m)
    kernel = functools.partial(_mrconv_kernel, block_m=block_m, k=k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_n, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_n, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_m, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_n, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), jnp.float32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(x, idx.astype(jnp.int32), y)
    return out[0] if squeeze else out
