"""jit'd public wrappers for the Pallas kernels (padding + NSM)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.digc_topk import digc_topk_pallas
from repro.kernels.mrconv import mrconv_pallas


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def mrconv(x: jax.Array, y: jax.Array, idx: jax.Array, *,
           block_n: int = 128, block_m: int = 512,
           interpret: bool = True) -> jax.Array:
    """Fused max-relative aggregation with automatic padding.
    x: (N, D), y: (M, D), idx: (N, k) -> (N, D)."""
    n, d = x.shape
    m = y.shape[0]
    block_n = min(block_n, _ceil_to(n, 8))
    block_m = min(block_m, _ceil_to(m, 128))
    n_pad = _ceil_to(n, block_n)
    m_pad = _ceil_to(m, block_m)
    x_p = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    y_p = jnp.pad(y, ((0, m_pad - m), (0, 0)))
    idx_p = jnp.pad(idx, ((0, n_pad - n), (0, 0)))
    out = mrconv_pallas(x_p, y_p, idx_p, block_n=block_n, block_m=block_m,
                        interpret=interpret)
    return out[:n].astype(x.dtype)


def digc_topk(
    x: jax.Array,
    y: jax.Array,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[jax.Array] = None,
    block_n: int = 128,
    block_m: int = 256,
    interpret: bool = True,
    return_dists: bool = False,
    causal: bool = False,
    packed: bool = False,
    mxu_bf16: bool = False,
    bucket_rounds: int = 0,
):
    """Fused-kernel DIGC with automatic padding and dilated selection.

    x: (N, D) nodes, y: (M, D) co-nodes, optional pos_bias (N, M).
    Returns idx (N, k) [, dist (N, k)].
    """
    n, feat = x.shape
    m = y.shape[0]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    block_n = min(block_n, _ceil_to(n, 8))
    block_m = min(block_m, _ceil_to(m, 128))
    n_pad = _ceil_to(n, block_n)
    m_pad = _ceil_to(m, block_m)
    x_p = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    y_p = jnp.pad(y, ((0, m_pad - m), (0, 0)))
    p_p = None
    if pos_bias is not None:
        p_p = jnp.pad(pos_bias, ((0, n_pad - n), (0, m_pad - m)))
    dist, idx = digc_topk_pallas(
        x_p,
        y_p,
        p_p,
        kd=kd,
        block_n=block_n,
        block_m=block_m,
        interpret=interpret,
        m_valid=m,
        causal=causal,
        packed=packed,
        mxu_bf16=mxu_bf16,
        bucket_rounds=bucket_rounds,
    )
    dist = dist[:n, ::dilation]
    idx = idx[:n, ::dilation]
    if return_dists:
        return idx, dist
    return idx
