"""jit'd public wrappers for the Pallas kernels (padding + NSM).

Both wrappers are batched-first: (B, N, D) inputs map straight onto the
kernels' leading batch grid dimension; (N, D) inputs are promoted to
B=1 and squeezed back. This module also registers the ``pallas``
GraphBuilder (DESIGN.md §4), including its fused MRConv aggregation.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.builder import DigcSpec, GraphBuilder, promote_batch, register
from repro.kernels.digc_topk import digc_topk_pallas
from repro.kernels.mrconv import mrconv_pallas


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _auto_interpret(interpret):
    """interpret=None -> compiled on a TPU backend, interpret elsewhere."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def mrconv(x: jax.Array, y: jax.Array, idx: jax.Array, *,
           block_n: int = 128, block_m: int = 512,
           interpret: Optional[bool] = None) -> jax.Array:
    """Fused max-relative aggregation with automatic padding.
    x: (B, N, D) | (N, D), y: (B, M, D) | (M, D), idx: (B, N, k) | (N, k)
    -> aggregate of x's rank."""
    if not (x.ndim == y.ndim == idx.ndim) or x.ndim not in (2, 3):
        raise ValueError(
            "mrconv expects (N, D)/(M, D)/(N, k) or uniformly batched "
            f"(B, ...) inputs; got {x.shape}, {y.shape}, {idx.shape}"
        )
    squeeze = x.ndim == 2
    if squeeze:
        x, y, idx = x[None], y[None], idx[None]
    b, n, d = x.shape
    m = y.shape[1]
    block_n = min(block_n, _ceil_to(n, 8))
    block_m = min(block_m, _ceil_to(m, 128))
    n_pad = _ceil_to(n, block_n)
    m_pad = _ceil_to(m, block_m)
    x_p = jnp.pad(x, ((0, 0), (0, n_pad - n), (0, 0)))
    y_p = jnp.pad(y, ((0, 0), (0, m_pad - m), (0, 0)))
    idx_p = jnp.pad(idx, ((0, 0), (0, n_pad - n), (0, 0)))
    out = mrconv_pallas(x_p, y_p, idx_p, block_n=block_n, block_m=block_m,
                        interpret=_auto_interpret(interpret))
    out = out[:, :n].astype(x.dtype)
    return out[0] if squeeze else out


def digc_topk(
    x: jax.Array,
    y: jax.Array,
    *,
    k: int,
    dilation: int = 1,
    pos_bias: Optional[jax.Array] = None,
    block_n: Optional[int] = None,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
    return_dists: bool = False,
    causal: bool = False,
    packed: bool = False,
    mxu_bf16: bool = False,
    bucket_rounds: int = 0,
    kernel_merge: Optional[str] = None,
):
    """Fused-kernel DIGC with automatic padding and dilated selection.

    x: (B, N, D) | (N, D) nodes, y co-nodes, optional pos_bias
    (B, N, M) | (N, M). Returns idx (B, N, k) [, dist] matching x's rank.
    Tile sizes default to the workload-adaptive VMEM-budgeted choice
    (``perfmodel.kernel_tile_defaults``) instead of one fixed shape.
    ``kernel_merge`` selects the LSM/GMM realization ("bitonic" default,
    "legacy" kd-pass); ``interpret=None`` is compiled-on-TPU auto.
    """
    x3, y3, p3, squeeze = promote_batch(x, y, pos_bias)
    _, n, feat = x3.shape
    m = y3.shape[1]
    kd = k * dilation
    if kd > m:
        raise ValueError(f"k*dilation={kd} exceeds number of co-nodes M={m}")
    if block_n is None or block_m is None:
        from repro.core.perfmodel import kernel_tile_defaults

        bn_auto, bm_auto = kernel_tile_defaults(n, m, feat, kd)
        block_n = bn_auto if block_n is None else block_n
        block_m = bm_auto if block_m is None else block_m
    block_n = min(block_n, _ceil_to(n, 8))
    block_m = min(block_m, _ceil_to(m, 128))
    n_pad = _ceil_to(n, block_n)
    m_pad = _ceil_to(m, block_m)
    x_p = jnp.pad(x3, ((0, 0), (0, n_pad - n), (0, 0)))
    y_p = jnp.pad(y3, ((0, 0), (0, m_pad - m), (0, 0)))
    p_p = None
    if p3 is not None:
        p_p = jnp.pad(p3, ((0, 0), (0, n_pad - n), (0, m_pad - m)))
    dist, idx = digc_topk_pallas(
        x_p,
        y_p,
        p_p,
        kd=kd,
        block_n=block_n,
        block_m=block_m,
        interpret=_auto_interpret(interpret),
        m_valid=m,
        causal=causal,
        packed=packed,
        mxu_bf16=mxu_bf16,
        bucket_rounds=bucket_rounds,
        kernel_merge=kernel_merge,
    )
    dist = dist[:, :n, ::dilation]
    idx = idx[:, :n, ::dilation]
    if squeeze:
        dist, idx = dist[0], idx[0]
    if return_dists:
        return idx, dist
    return idx


# --------------------------------------------------------------------------
# Registry entry (DESIGN.md §4).


def _build_pallas(x, y, pos_bias, spec: DigcSpec):
    return digc_topk(
        x, y, k=spec.k, dilation=spec.dilation, pos_bias=pos_bias,
        causal=spec.causal, return_dists=True,
        block_n=spec.block_n,  # None = workload-adaptive VMEM-budgeted tiles
        block_m=spec.block_m,
        interpret=spec.interpret,  # None = compiled on TPU, interpret off-TPU
        packed=bool(spec.packed),
        mxu_bf16=bool(spec.mxu_bf16),
        bucket_rounds=spec.bucket_rounds if spec.bucket_rounds is not None else 0,
        kernel_merge=spec.kernel_merge,
    )


register(GraphBuilder(
    name="pallas",
    build=_build_pallas,
    knobs=frozenset({
        "block_n", "block_m", "interpret", "packed", "mxu_bf16",
        "bucket_rounds", "kernel_merge",
    }),
    exact=True,  # packed / bucket_rounds knobs opt into approximation
    supports_pos_bias=True,
    supports_causal=True,
    aggregate=mrconv,  # fused gather-aggregate kernel
    doc="fused Pallas kernel: distance + streaming top-kd in VMEM, "
        "batch as leading grid dim",
))
