"""Pure-jnp oracle for the DIGC kernels (Algorithm 1, no blocking)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def pairwise_sq_dists(x, y, pos_bias=None):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d = (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * (x @ y.T)
        + jnp.sum(y * y, -1)[None, :]
    )
    if pos_bias is not None:
        d = d + pos_bias
    return d


def digc_reference(
    x: jax.Array,
    y: jax.Array,
    pos_bias: Optional[jax.Array] = None,
    *,
    kd: int,
):
    """Full-matrix top-kd: returns (dist, idx), each (N, kd), ascending."""
    d_xy = pairwise_sq_dists(x, y, pos_bias)
    neg, idx = lax.top_k(-d_xy, kd)
    return -neg, idx.astype(jnp.int32)
