# Launchers: meshes, dry-run, roofline, train/serve drivers.
