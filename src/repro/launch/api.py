"""Model API bundle: uniform (param_spec, loss, prefill, decode) per
family, used by the train/serve drivers and the dry-run."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax.numpy as jnp

from repro.models import encdec as ed
from repro.models import transformer as tr
from repro.models.config import ModelConfig


class ModelAPI(NamedTuple):
    param_spec: Callable[[], Any]
    loss_fn: Callable  # (params, batch, cfg) -> (loss, metrics)
    prefill_fn: Callable  # (params, batch) -> (logits, cache)
    decode_fn: Callable  # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable  # (batch, max_len) -> cache


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "audio":
        def prefill_fn(params, batch):
            memory = ed.encode(params, batch["frames"], cfg)
            logits, (self_kv, mem_kv) = ed.decode_forward(
                params, batch["tokens"], memory, cfg, return_cache=True
            )
            return logits, (self_kv, mem_kv)

        def init_cache(batch, max_len, enc_len=None):
            enc_len = enc_len or cfg.encdec.max_source_positions
            return ed.encdec_init_cache(cfg, batch, max_len, enc_len)

        return ModelAPI(
            param_spec=lambda: ed.encdec_param_spec(cfg),
            loss_fn=ed.encdec_loss_fn,
            prefill_fn=prefill_fn,
            decode_fn=lambda p, c, t, pos: ed.encdec_decode_step(p, c, t, pos, cfg),
            init_cache=init_cache,
        )

    def prefill_fn(params, batch):
        return tr.prefill(
            params, batch["tokens"], cfg, positions=batch.get("positions")
        )

    return ModelAPI(
        param_spec=lambda: tr.param_spec(cfg),
        loss_fn=tr.loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=lambda p, c, t, pos, positions=None: tr.decode_step(
            p, c, t, pos, cfg, positions=positions
        ),
        init_cache=lambda batch, max_len: tr.init_cache(cfg, batch, max_len),
    )
