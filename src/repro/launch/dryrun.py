import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on
the production meshes and record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch ID ...] [--shape ID ...] [--multi-pod | --single-pod | --both]
        [--out results/dryrun] [--force]

The 512 placeholder CPU devices exist ONLY in this process (the env var
above is set before any jax import). Results are cached per cell as
JSON so reruns resume where they stopped.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import make_cell  # noqa: E402
from repro.models.module import use_mesh  # noqa: E402


def _compile_cell(arch, shape_id, mesh, cfg):
    cell = make_cell(arch, shape_id, mesh, cfg=cfg)
    with use_mesh(mesh, cell["rules"]):
        lowered = jax.jit(
            cell["fn"], in_shardings=cell["in_shardings"]
        ).lower(*cell["args"])
        compiled = lowered.compile()
    return compiled


def _measure(compiled):
    hlo = compiled.as_text()
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll["total_bytes"]),
        "coll_by_kind": coll["bytes_by_kind"],
        "coll_counts": coll["counts"],
    }


def _depth_unit(cfg):
    """(unit size in layers, depths for the two probe compiles)."""
    if cfg.family == "hybrid":
        u = len(cfg.hybrid.pattern)
        return u, (u, 2 * u)
    return 1, (2, 4)


def _with_depth(cfg, n_layers):
    kw = {"num_layers": n_layers, "scan_layers": False}
    return cfg.replace(**kw)


def _extrapolate(base: dict, probe_hi: dict, d_lo: int, d_hi: int,
                 full_layers: int, unit: int) -> dict:
    """Linear-in-depth extrapolation of per-device roofline terms.

    XLA's HloCostAnalysis counts while-loop bodies once, so the
    full-depth scanned compile under-reports flops. The two *unrolled*
    probe compiles at depths d_lo < d_hi give the exact per-layer cost;
    totals at the real depth follow linearly (layer costs are
    depth-independent by construction)."""
    out = {}
    units_lo = d_lo / unit
    units_hi = d_hi / unit
    units_full = full_layers / unit
    for key in ("flops", "hbm_bytes", "collective_bytes"):
        per_unit = (probe_hi[key] - base[key]) / (units_hi - units_lo)
        out[key] = base[key] + per_unit * (units_full - units_lo)
    return out


def run_cell(arch: str, shape_id: str, *, multi_pod: bool, out_dir: Path,
             force: bool = False, cfg=None, tag: str = "",
             probes: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape_id}__{mesh_name}{tag}"
    out_path = out_dir / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = cfg or get_config(arch)
    ok, why = cell_supported(cfg, shape_id)
    rec = {
        "arch": arch, "shape": shape_id, "mesh": mesh_name,
        "kind": SHAPES[shape_id][2], "seq_len": SHAPES[shape_id][0],
        "global_batch": SHAPES[shape_id][1],
    }
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        _write(out_path, rec)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        # 1) full-depth scanned compile: proves the production config
        #    lowers+compiles and yields the true memory footprint.
        compiled = _compile_cell(arch, shape_id, mesh, cfg)
        mem = compiled.memory_analysis()
        full_meas = _measure(compiled)
        del compiled

        # 2) two unrolled probe compiles -> exact per-layer terms.
        if probes:
            unit, (d_lo, d_hi) = _depth_unit(cfg)
            lo = _measure(_compile_cell(arch, shape_id, mesh, _with_depth(cfg, d_lo)))
            hi = _measure(_compile_cell(arch, shape_id, mesh, _with_depth(cfg, d_hi)))
            terms = _extrapolate(lo, hi, d_lo, d_hi, cfg.num_layers, unit)
        else:
            terms = {k: full_meas[k] for k in
                     ("flops", "hbm_bytes", "collective_bytes")}

        roof_terms = {
            "compute_s": terms["flops"] / rl.PEAK_FLOPS,
            "memory_s": terms["hbm_bytes"] / rl.HBM_BW,
            "collective_s": terms["collective_bytes"] / rl.ICI_BW,
        }
        bound = max(
            ("compute", "memory", "collective"),
            key=lambda k: roof_terms[f"{k}_s"],
        )
        mf = rl.model_flops(cfg, rec["kind"], rec["seq_len"],
                            rec["global_batch"], n_chips)
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "per_device": terms,
            "per_device_scanned_raw": {
                k: full_meas[k] for k in
                ("flops", "hbm_bytes", "collective_bytes")
            },
            "coll_by_kind": full_meas["coll_by_kind"],
            "roofline": {**roof_terms, "bound": bound},
            "model_flops_per_chip": mf,
            "useful_flop_frac": (mf / terms["flops"]) if terms["flops"] else None,
        })
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
            "compile_s": round(time.time() - t0, 1),
        })
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ARCH_IDS))
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    pods = []
    if args.single_pod or not args.multi_pod:
        pods.append(False)
    if args.multi_pod or not args.single_pod:
        pods.append(True)

    out_dir = Path(args.out)
    failures = 0
    for arch in args.arch:
        for shape_id in args.shape:
            for multi_pod in pods:
                t0 = time.time()
                rec = run_cell(arch, shape_id, multi_pod=multi_pod,
                               out_dir=out_dir, force=args.force)
                jax.clear_caches()
                status = rec["status"]
                if status == "error":
                    failures += 1
                    print(f"[FAIL] {arch} {shape_id} mp={multi_pod}: "
                          f"{rec['error']}", flush=True)
                else:
                    extra = ""
                    if status == "ok":
                        r = rec["roofline"]
                        extra = (f" bound={r['bound']}"
                                 f" c={r['compute_s']:.2e}s"
                                 f" m={r['memory_s']:.2e}s"
                                 f" x={r['collective_s']:.2e}s"
                                 f" compile={rec['compile_s']}s")
                    print(f"[{status.upper()}] {arch} {shape_id} "
                          f"mp={multi_pod}{extra}", flush=True)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
