"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing
the single real CPU device."""

from __future__ import annotations

import jax

try:  # jax >= 0.5; older versions have neither AxisType nor the kwarg
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    import math

    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint sets xla_force_host_platform_device_count"
        )
    kwargs = {}
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devices[:n], **kwargs
    )


def describe(mesh) -> str:
    return f"mesh(shape={dict(zip(mesh.axis_names, mesh.devices.shape))}, devices={mesh.devices.size})"
