"""Generate the EXPERIMENTS.md roofline table from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_s(v):
    if v is None:
        return "-"
    if v == 0:
        return "0"
    return f"{v:.2e}"


def load(out_dir: Path):
    recs = []
    for p in sorted(out_dir.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def bottleneck_sentence(rec) -> str:
    r = rec.get("roofline") or {}
    b = r.get("bound")
    kind = rec["kind"]
    if b == "collective":
        if kind == "train":
            return "FSDP all-gathers + grad all-reduce dominate; move to coarser per-layer gathers / overlap"
        return "decode all-gathers of sharded KV dominate; widen batch-per-chip or cache-local attention layout"
    if b == "memory":
        if kind == "decode":
            return "KV/state cache sweep is inherent at batch-bound decode; raise batch or quantize cache"
        return "HBM-bound: increase arithmetic intensity (fusion, larger per-chip batch)"
    return "compute-bound: already at the MXU roofline; only algorithmic cuts help"


def table(recs, mesh: str) -> str:
    rows = [
        "| arch | shape | kind | compute s | memory s | collective s | bound | MODEL/HLO flops | per-dev HBM GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | - | - | - | "
                f"SKIP | - | - |"
            )
            continue
        if rec["status"] == "error":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | - | - | - | "
                f"ERROR | - | - |"
            )
            continue
        r = rec["roofline"]
        uf = rec.get("useful_flop_frac")
        mem = rec["memory"]["temp_bytes"] / 1e9
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['bound']}** | "
            f"{uf:.2f} | {mem:.1f} |"
        )
    return "\n".join(rows)


def summary(recs) -> dict:
    out = {"ok": 0, "skipped": 0, "error": 0}
    for r in recs:
        out[r["status"]] += 1
    return out


def compare(base_dir: Path, opt_dir: Path, mesh: str = "pod16x16") -> str:
    """Baseline vs optimized dominant-term table (§Perf evidence)."""
    base = {(r["arch"], r["shape"]): r for r in load(base_dir)
            if r["mesh"] == mesh}
    opt = {(r["arch"], r["shape"]): r for r in load(opt_dir)
           if r["mesh"] == mesh}
    rows = [
        "| arch | shape | baseline bound | baseline s | optimized bound | optimized s | gain |",
        "|---|---|---|---|---|---|---|",
    ]
    for key, o in sorted(opt.items()):
        b = base.get(key)
        if not b or b["status"] != "ok" or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        tb = rb[f"{rb['bound']}_s"]
        to = ro[f"{ro['bound']}_s"]
        gain = tb / to if to else float("inf")
        rows.append(
            f"| {key[0]} | {key[1]} | {rb['bound']} | {fmt_s(tb)} | "
            f"{ro['bound']} | {fmt_s(to)} | {gain:.2f}x |"
        )
    return "\n".join(rows)


def main():
    if len(sys.argv) > 3 and sys.argv[3] == "--compare":
        print(compare(Path(sys.argv[1]), Path(sys.argv[2])))
        return
    out_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    recs = load(out_dir)
    print("## Dry-run summary:", summary(recs))
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(table(recs, mesh))
    print("\n### Bottleneck notes\n")
    seen = set()
    for rec in recs:
        if rec["status"] != "ok" or rec["mesh"] != "pod16x16":
            continue
        key = (rec["arch"], rec["shape"])
        if key in seen:
            continue
        seen.add(key)
        print(f"- **{rec['arch']} / {rec['shape']}** "
              f"({rec['roofline']['bound']}-bound): {bottleneck_sentence(rec)}")


if __name__ == "__main__":
    main()
