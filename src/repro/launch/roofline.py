"""Roofline terms from compiled dry-run artifacts.

    compute term    = per-chip HLO FLOPs / peak FLOP/s
    memory term     = per-chip HLO bytes accessed / HBM bandwidth
    collective term = per-chip collective bytes / ICI link bandwidth

cost_analysis() on the SPMD-partitioned executable reports *per-device*
flops / bytes (verified empirically), so the chips factor is already
applied. Collective bytes are parsed from the optimized HLO text
(collectives only exist post-partitioning): per op we take the result
shape bytes, x2 for all-reduce (ring reduce+broadcast), x(g-1)/g ring
efficiency where the replica group size g is parseable.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective traffic by op kind."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        shapes = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind:
            continue
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = None
        mg = _GROUP_RE.search(line)
        if mg:
            g = int(mg.group(2))
        ring = (g - 1) / g if g and g > 1 else 1.0
        if kind == "all-reduce":
            nbytes = int(2 * nbytes * ring)
        elif kind in ("all-gather", "reduce-scatter"):
            nbytes = int(nbytes * ring)
        counts[kind] += 1
        out[kind] += nbytes
    return {"bytes_by_kind": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str

    def to_dict(self):
        return self.__dict__.copy()


def analyze(compiled, *, hlo_text=None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": coll["total_bytes"] / ICI_BW,
    }
    bound = max(terms, key=terms.get)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(coll["total_bytes"]),
        compute_s=terms["compute"],
        memory_s=terms["memory"],
        collective_s=terms["collective"],
        bound=bound,
    )


def model_flops(cfg, kind: str, seq_len: int, global_batch: int,
                n_chips: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = params
    (active for MoE), D = tokens — per chip."""
    n_params = active_param_count(cfg)
    tokens = global_batch * (seq_len if kind != "decode" else 1)
    mult = 6 if kind == "train" else 2
    return mult * n_params * tokens / n_chips


def active_param_count(cfg) -> int:
    """Active (per-token) parameter count from the config."""
    d = cfg.d_model
    v = cfg.vocab_size
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        heads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        per = d * (2 * d_in + 2 * s.n_groups * s.d_state + heads)
        per += s.d_conv * conv_dim + conv_dim + 3 * heads + d_in + d_in * d
        return emb + cfg.num_layers * per

    # attention
    dh = cfg.dh
    if cfg.mla:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        attn = d * cfg.num_heads * qk + d * m.kv_lora + d * m.qk_rope_dim
        attn += m.kv_lora * cfg.num_heads * (m.qk_nope_dim + m.v_dim)
        attn += cfg.num_heads * m.v_dim * d
    else:
        attn = d * dh * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * dh * d

    # channel mixer (active)
    if cfg.moe:
        mo = cfg.moe
        mlp = 3 * d * mo.d_expert * (mo.top_k + mo.num_shared)
    elif cfg.activation == "swiglu":
        mlp = 3 * d * cfg.d_ff
    else:
        mlp = 2 * d * cfg.d_ff

    if cfg.family == "hybrid":
        w = cfg.hybrid.lru_width or d
        rec = 2 * d * w + 2 * w * w + w * d + cfg.hybrid.d_conv * w
        pat = cfg.hybrid.pattern
        n_rec = sum(1 for p in pat if p == "rec")
        frac_rec = n_rec / len(pat)
        per = frac_rec * (rec + mlp) + (1 - frac_rec) * (attn + mlp)
        total = emb + cfg.num_layers * per
        return int(total)

    per = attn + mlp
    total = emb + cfg.num_layers * per
    if cfg.family == "audio":
        total += cfg.encdec.enc_layers * per + cfg.num_layers * attn  # cross-attn
    return int(total)
