"""Serving driver: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 8 --slots 4
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, get_smoke
from repro.launch.api import get_api
from repro.models.module import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("whisper serving needs frames; see tests/test_archs.py")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(args.seed))
    max_len = args.max_len or (args.prompt_len + args.new_tokens + 8)
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=max_len)

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    finished = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in finished)
    print(f"served {len(finished)} requests / {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s, {args.slots} slots)")
    return finished


if __name__ == "__main__":
    main()
