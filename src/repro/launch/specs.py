"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(arch x input-shape) dry-run cell. No device allocation happens here —
everything is abstract until .lower().compile()."""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.api import get_api
from repro.models.config import ModelConfig
from repro.models.module import (
    DEFAULT_RULES,
    abstract_params,
    make_shardings,
    mesh_axes_for,
    rules_for,
    _drop_indivisible,
)
from repro.train.optimizer import OptConfig, OptState
from repro.train.trainer import make_train_step


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard(mesh: Mesh, shape, spec_entries) -> NamedSharding:
    ps = _drop_indivisible(shape, P(*spec_entries), mesh)
    return NamedSharding(mesh, ps)


def shard_batch_tree(tree, mesh: Mesh, rules=DEFAULT_RULES):
    """Batch inputs: dim0 = batch per the active rules (default
    (pod, data); batch-over-model policies add the model axis)."""
    bd = tuple(a for a in _as_tuple(rules.get("batch", ("pod", "data")))
               if a in mesh.axis_names)

    def one(x):
        entries = [bd] + [None] * (x.ndim - 1)
        return _shard(mesh, x.shape, entries)

    return jax.tree_util.tree_map(one, tree)


def _as_tuple(v):
    return (v,) if isinstance(v, str) else tuple(v)


def shard_cache_tree(tree, mesh: Mesh):
    """Decode caches: stacked (L, B, T, ...) leaves. Batch over
    (pod,data); for KV-like leaves shard heads over model when they
    divide, else the sequence dim (sequence-parallel decode)."""
    bd = _batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape[model] if model else 1

    def one(x):
        entries: list[Any] = [None] * x.ndim
        if x.ndim >= 2:
            entries[1] = bd  # batch after layers dim
        if model and x.ndim >= 3:
            # Model-axis placement order matters (§Perf iteration D2.1):
            # kv-heads (ndim-2) is collective-free for attention; the
            # sequence dim (2) costs one small LSE-combine psum
            # (flash-decode); head_dim (last) would shard the attention
            # CONTRACTION and is never chosen.
            candidates = []
            if x.ndim >= 4:
                candidates.append(x.ndim - 2)  # kv heads
            candidates.append(2)  # sequence
            for d in candidates:
                if d < x.ndim and x.shape[d] % msize == 0 and x.shape[d] >= msize:
                    entries[d] = model
                    break
        return _shard(mesh, x.shape, entries)

    return jax.tree_util.tree_map(one, tree)


def make_cell(arch: str, shape_id: str, mesh: Mesh, *,
              cfg: Optional[ModelConfig] = None,
              rules=DEFAULT_RULES):
    """Build (step_fn, abstract args, in_shardings) for one dry-run cell.

    Returns dict with keys: fn, args (tuple of ShapeDtypeStruct trees),
    in_shardings (matching tuple), kind.
    """
    cfg = cfg or get_config(arch)
    if rules is DEFAULT_RULES:
        rules = rules_for(cfg)
    seq_len, global_batch, kind = SHAPES[shape_id]
    api = get_api(cfg)
    spec_tree = api.param_spec()
    params_abs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cfg.compute_dtype)
        if jnp.issubdtype(s.dtype, jnp.floating) else jax.ShapeDtypeStruct(s.shape, s.dtype),
        abstract_params(spec_tree),
    )
    params_sh = make_shardings(spec_tree, mesh, rules)

    if kind == "train":
        batch = _train_batch_abs(cfg, seq_len, global_batch)
        batch_sh = shard_batch_tree(batch, mesh, rules)
        oc = OptConfig()
        train_step = make_train_step(cfg, oc, loss_fn=api.loss_fn)
        opt_abs = OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=_as_f32(params_abs),
            nu=_as_f32(params_abs),
            master=_as_f32(params_abs),
        )
        opt_sh = OptState(
            step=NamedSharding(mesh, P()),
            mu=params_sh,
            nu=params_sh,
            master=params_sh,
        )
        return {
            "fn": train_step,
            "args": (params_abs, opt_abs, batch),
            "in_shardings": (params_sh, opt_sh, batch_sh),
            "kind": kind,
            "cfg": cfg,
            "rules": rules,
        }

    if kind == "prefill":
        batch = _prefill_batch_abs(cfg, seq_len, global_batch)
        batch_sh = shard_batch_tree(batch, mesh, rules)
        return {
            "fn": lambda params, batch: api.prefill_fn(params, batch),
            "args": (params_abs, batch),
            "in_shardings": (params_sh, batch_sh),
            "kind": kind,
            "cfg": cfg,
            "rules": rules,
        }

    # decode: one new token against a cache of length seq_len
    cache_abs = jax.eval_shape(
        functools.partial(
            _init_cache_host, api=api, cfg=cfg, batch=global_batch,
            max_len=seq_len,
        )
    )
    cache_sh = shard_cache_tree(cache_abs, mesh)
    tokens = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    tokens_sh = shard_batch_tree(tokens, mesh, rules)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    args = [params_abs, cache_abs, tokens, pos_abs]
    shardings = [params_sh, cache_sh, tokens_sh, pos_sh]
    fn = api.decode_fn
    if cfg.mrope_sections:
        positions = jax.ShapeDtypeStruct((3, global_batch, 1), jnp.int32)
        positions_sh = _shard(mesh, positions.shape,
                              [None, _batch_axes(mesh), None])
        args.append(positions)
        shardings.append(positions_sh)
        fn = lambda p, c, t, pos, positions: api.decode_fn(
            p, c, t, pos, positions=positions
        )
    return {
        "fn": fn,
        "args": tuple(args),
        "in_shardings": tuple(shardings),
        "kind": kind,
        "cfg": cfg,
        "rules": rules,
    }


def _init_cache_host(batch, max_len, *, api, cfg):
    return api.init_cache(batch, max_len)


def _as_f32(tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree
    )


def _train_batch_abs(cfg: ModelConfig, seq_len: int, global_batch: int):
    b, s = global_batch, seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.compute_dtype)
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return batch


def _prefill_batch_abs(cfg: ModelConfig, seq_len: int, global_batch: int):
    b, s = global_batch, seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.compute_dtype)
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return batch
