"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config registry, param init (or elastic checkpoint
resume), synthetic data pipeline with prefetch, jit'd train step on the
active mesh, straggler monitor, async sharded checkpointing.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config, get_smoke
from repro.data.pipeline import DataConfig, lm_pipeline
from repro.distributed.straggler import StragglerMonitor
from repro.launch.api import get_api
from repro.models.module import (
    abstract_params,
    init_params,
    make_shardings,
    use_mesh,
)
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("use examples/train_vig.py-style drivers for enc-dec")
    api = get_api(cfg)
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)
    train_step = make_train_step(cfg, oc, loss_fn=api.loss_fn,
                                 accum_steps=args.accum)

    spec_tree = api.param_spec()
    rng = jax.random.PRNGKey(args.seed)
    params = init_params(spec_tree, rng)
    opt_state = init_train_state(params)
    start_step = 0

    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state_like = {"params": params, "opt": opt_state}
            restored, start_step = ckpt.restore(args.ckpt_dir, state_like)
            params, opt_state = restored["params"], restored["opt"]
            print(f"resumed from step {start_step}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab_size=cfg.vocab_size, seed=args.seed)
    pipe = lm_pipeline(dc, start_step=start_step)
    monitor = StragglerMonitor()
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    jit_step = jax.jit(train_step)
    losses = []
    try:
        for step, batch in pipe:
            if step >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            with monitor.step_timer():
                params, opt_state, metrics = jit_step(params, opt_state, batch)
                metrics = jax.device_get(metrics)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} lr {metrics['lr']:.2e} "
                      f"median_step {monitor.stats()['median_s']*1e3:.0f}ms",
                      flush=True)
            if saver and (step + 1) % args.ckpt_every == 0:
                saver.save(step + 1, {"params": params, "opt": opt_state})
    finally:
        pipe.close()
        if saver:
            saver.wait()

    first = np.mean(losses[: max(len(losses) // 5, 1)])
    last = np.mean(losses[-max(len(losses) // 5, 1):])
    print(f"loss first-mean {first:.4f} -> last-mean {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
