# Model definitions: module system, layers, families, ViG.
