"""Model configuration dataclasses covering all assigned families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""

    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) dims."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Griffin / RecurrentGemma: (rec, rec, attn) repeating pattern."""

    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: Optional[int] = None  # defaults to d_model
    window: int = 2048
    d_conv: int = 4
    c_factor: float = 8.0  # RG-LRU gate exponent scale


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder; frontend is a stub (frame
    embeddings arrive precomputed)."""

    enc_layers: int = 4
    max_source_positions: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    attention: str = "full"  # full | local | knn
    window: int = 0
    knn_neighbors: int = 64
    # norms / activations / embeddings
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    activation: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple[int, ...]] = None  # qwen2-vl
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # numerics / structure
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"  # none | full | dots
    q_chunk: int = 512
    logit_softcap: float = 0.0
    # Sharding policy: when num_heads doesn't divide the TP axis (e.g.
    # 20 heads on 16-way model), shard the batch over (data, model) for
    # the WHOLE model instead of head-sharding — avoids both replicated
    # attention and per-layer activation resharding (§Perf T3.2).
    shard_batch_over_model: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM/hybrid/knn are O(1)/O(k) per
        decode step in sequence length at fixed state.)"""
        return self.family in ("ssm", "hybrid") or self.attention == "knn"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
