"""Whisper-style encoder-decoder backbone.

The audio frontend (conv1d stem over mel spectrograms) is a STUB per
the assignment: ``input_specs`` supplies precomputed frame embeddings
(B, S_enc, d_model). Encoder adds sinusoidal positions; decoder uses a
learned positional table, causal self-attention + cross-attention to
the encoder memory, GELU MLPs, LayerNorm."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import (
    attention_apply,
    attention_spec,
    embed_apply,
    embed_spec,
    mlp_apply,
    mlp_spec,
    norm_apply,
    norm_spec,
    sinusoidal_positions,
    unembed_apply,
)
from repro.models.module import scan_or_unroll, spec
from repro.models.transformer import stack_specs

MAX_DEC_POS = 8192 * 8  # learned decoder positions (covers decode_32k)


def _enc_layer_spec(cfg: ModelConfig):
    return {
        "ln1": norm_spec(cfg),
        "attn": attention_spec(cfg),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }


def _dec_layer_spec(cfg: ModelConfig):
    return {
        "ln1": norm_spec(cfg),
        "self_attn": attention_spec(cfg),
        "ln2": norm_spec(cfg),
        "cross_attn": attention_spec(cfg),
        "ln3": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }


def encdec_param_spec(cfg: ModelConfig):
    enc_layers = cfg.encdec.enc_layers
    return {
        "embed": embed_spec(cfg),
        "dec_pos": spec((MAX_DEC_POS, cfg.d_model), (None, "embed"), init="normal"),
        "enc": stack_specs(_enc_layer_spec(cfg), enc_layers),
        "enc_norm": norm_spec(cfg),
        "dec": stack_specs(_dec_layer_spec(cfg), cfg.num_layers),
        "final_norm": norm_spec(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames (B, S_enc, D) precomputed embeddings -> memory (B,S_enc,D)."""
    b, s, d = frames.shape
    x = frames.astype(cfg.compute_dtype) + sinusoidal_positions(s, d).astype(
        cfg.compute_dtype
    )
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        h = carry
        a, _ = attention_apply(
            lp["attn"], norm_apply(lp["ln1"], h, cfg), cfg,
            positions=positions, causal=False,
        )
        h = h + a
        h = h + mlp_apply(lp["mlp"], norm_apply(lp["ln2"], h, cfg), cfg)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = scan_or_unroll(body, x, params["enc"], cfg.scan_layers)
    return norm_apply(params["enc_norm"], x, cfg)


def _memory_kv(lp, memory, cfg: ModelConfig):
    dt = cfg.compute_dtype
    mk = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wk"].astype(dt))
    mv = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wv"].astype(dt))
    if cfg.qkv_bias:
        mk = mk + lp["cross_attn"]["bk"].astype(dt)
        mv = mv + lp["cross_attn"]["bv"].astype(dt)
    return mk, mv


def decode_forward(params, tokens, memory, cfg: ModelConfig, *,
                   return_cache: bool = False):
    """Teacher-forced decoder pass. tokens (B,S_dec)."""
    b, s = tokens.shape
    x = embed_apply(params["embed"], tokens, cfg)
    x = x + params["dec_pos"][:s].astype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        h = carry
        a, kv = attention_apply(
            lp["self_attn"], norm_apply(lp["ln1"], h, cfg), cfg, positions=positions
        )
        h = h + a
        mk, mv = _memory_kv(lp, memory, cfg)
        c, _ = attention_apply(
            lp["cross_attn"], norm_apply(lp["ln2"], h, cfg), cfg,
            positions=positions, memory=(mk, mv),
        )
        h = h + c
        h = h + mlp_apply(lp["mlp"], norm_apply(lp["ln3"], h, cfg), cfg)
        return h, (kv if return_cache else None, (mk, mv) if return_cache else None)

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, (self_kv, mem_kv) = scan_or_unroll(body, x, params["dec"], cfg.scan_layers)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    if return_cache:
        return logits, (self_kv, mem_kv)
    return logits


def encdec_loss_fn(params, batch, cfg: ModelConfig):
    """batch: frames (B,S_enc,D), tokens (B,S_dec), labels, mask."""
    from repro.models.transformer import softmax_xent

    memory = encode(params, batch["frames"], cfg)
    logits = decode_forward(params, batch["tokens"], memory, cfg)
    nll = softmax_xent(logits, batch["labels"])
    mask = batch.get("mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {}


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    dt = cfg.compute_dtype
    kvh, dh = cfg.num_kv_heads, cfg.dh
    layers = cfg.num_layers
    return {
        "k": jnp.zeros((layers, batch, max_len, kvh, dh), dt),
        "v": jnp.zeros((layers, batch, max_len, kvh, dh), dt),
        "mk": jnp.zeros((layers, batch, enc_len, kvh, dh), dt),
        "mv": jnp.zeros((layers, batch, enc_len, kvh, dh), dt),
    }


def encdec_decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decoder token against self-cache + precomputed memory KV."""
    b = tokens.shape[0]
    x = embed_apply(params["embed"], tokens, cfg)
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(
        cfg.compute_dtype
    )
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(carry, xs):
        h = carry
        lp, lc = xs
        a, new_kv = attention_apply(
            lp["self_attn"], norm_apply(lp["ln1"], h, cfg), cfg,
            positions=positions, cache={"k": lc["k"], "v": lc["v"]}, pos=pos,
        )
        h = h + a
        c, _ = attention_apply(
            lp["cross_attn"], norm_apply(lp["ln2"], h, cfg), cfg,
            positions=positions, memory=(lc["mk"].astype(cfg.compute_dtype),
                                         lc["mv"].astype(cfg.compute_dtype)),
        )
        h = h + c
        h = h + mlp_apply(lp["mlp"], norm_apply(lp["ln3"], h, cfg), cfg)
        return h, {"k": new_kv["k"], "v": new_kv["v"], "mk": lc["mk"], "mv": lc["mv"]}

    x, new_cache = scan_or_unroll(body, x, (params["dec"], cache), cfg.scan_layers)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    return logits, new_cache
