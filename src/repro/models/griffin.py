"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local attention,
interleaved (rec, rec, attn). Train/prefill uses an associative scan
(log-time recurrence); decode carries (h, conv) state per recurrent
layer and a rolling window KV cache per attention layer."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.module import spec


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def rglru_spec(cfg: ModelConfig):
    d = cfg.d_model
    w = _lru_width(cfg)
    k = cfg.hybrid.d_conv
    return {
        "w_x": spec((d, w), ("embed", "mlp")),
        "w_gate_branch": spec((d, w), ("embed", "mlp")),
        "conv_w": spec((k, w), ("conv", "mlp"), init="fanin"),
        "conv_b": spec((w,), ("mlp",), init="zeros"),
        "w_input_gate": spec((w, w), ("mlp", None), init="fanin"),
        "b_input_gate": spec((w,), (None,), init="zeros"),
        "w_rec_gate": spec((w, w), ("mlp", None), init="fanin"),
        "b_rec_gate": spec((w,), (None,), init="zeros"),
        "lam": spec((w,), ("mlp",), init="normal", scale=1.0),
        "w_out": spec((w, d), ("mlp", "embed")),
    }


def _causal_conv(u, w, b):
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + u.shape[1], :] * w[i] for i in range(k)) + b


def _gates(params, u, cfg, dt):
    c = cfg.hybrid.c_factor
    i_gate = jax.nn.sigmoid(
        u.astype(jnp.float32) @ params["w_input_gate"].astype(jnp.float32)
        + params["b_input_gate"].astype(jnp.float32)
    )
    r_gate = jax.nn.sigmoid(
        u.astype(jnp.float32) @ params["w_rec_gate"].astype(jnp.float32)
        + params["b_rec_gate"].astype(jnp.float32)
    )
    log_a = c * r_gate * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * u.astype(jnp.float32))
    return a, beta


def rglru_apply(params, x, cfg: ModelConfig, *, state: Optional[dict] = None):
    """Recurrent block. state = {"h": (B,W), "conv": (B,K-1,W)} for decode."""
    dt = cfg.compute_dtype
    k = cfg.hybrid.d_conv
    ub = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(dt))
    gate_branch = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"].astype(dt))
    )

    if state is None:
        u = _causal_conv(ub, params["conv_w"].astype(dt), params["conv_b"].astype(dt))
        a, beta = _gates(params, u, cfg, dt)  # (B,S,W) fp32

        # h_t = a_t h_{t-1} + beta_t: associative scan over time
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_sc, h = lax.associative_scan(combine, (a, beta), axis=1)
        out = jnp.einsum(
            "bsw,wd->bsd", (gate_branch.astype(jnp.float32) * h).astype(dt),
            params["w_out"].astype(dt),
        )
        seq = x.shape[1]
        tail = ub[:, -(k - 1):, :] if seq >= k - 1 else jnp.pad(
            ub, ((0, 0), (k - 1 - seq, 0), (0, 0))
        )
        final = {"h": h[:, -1].astype(jnp.float32), "conv": tail.astype(jnp.float32)}
        return out, final

    # ---- decode
    window = jnp.concatenate([state["conv"].astype(dt), ub], axis=1)  # (B,K,W)
    u = (
        jnp.einsum("bkw,kw->bw", window, params["conv_w"].astype(dt))
        + params["conv_b"].astype(dt)
    )[:, None, :]
    a, beta = _gates(params, u, cfg, dt)  # (B,1,W)
    h = state["h"] * a[:, 0] + beta[:, 0]
    out = jnp.einsum(
        "bsw,wd->bsd", (gate_branch.astype(jnp.float32) * h[:, None]).astype(dt),
        params["w_out"].astype(dt),
    )
    conv_new = jnp.concatenate([state["conv"][:, 1:], ub.astype(jnp.float32)], axis=1)
    return out, {"h": h, "conv": conv_new}


def rglru_init_state(cfg: ModelConfig, batch: int):
    w = _lru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.hybrid.d_conv - 1, w), jnp.float32),
    }
