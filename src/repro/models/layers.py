"""Layer library: norms, rotary embeddings, attention (GQA/MQA/local/
KNN/MLA), MLPs. Pure functions over param dicts from module.ParamSpec."""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.module import active_mesh, constrain, spec

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Norms


def norm_spec(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": spec((d,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        return {
            "scale": spec((d,), ("embed",), init="ones"),
            "bias": spec((d,), ("embed",), init="zeros"),
        }
    if cfg.norm == "nonparam_ln":  # OLMo: no learnable affine
        return {}
    raise ValueError(cfg.norm)


def norm_apply(params, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(x32 * x32, -1, keepdims=True)
        out = x32 * lax.rsqrt(var + 1e-6) * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(x32, -1, keepdims=True)
        var = jnp.var(x32, -1, keepdims=True)
        out = (x32 - mean) * lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(
                jnp.float32
            )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)


def _rope_freqs(dh_half: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(dh_half, dtype=jnp.float32) / dh_half))


def rope_angles(positions: jax.Array, dh: int, theta: float,
                mrope_sections: Optional[tuple[int, ...]] = None) -> jax.Array:
    """positions: (B, S) or (3, B, S) for M-RoPE -> angles (B, S, dh//2)."""
    half = dh // 2
    freqs = _rope_freqs(half, theta)  # (half,)
    if mrope_sections is None:
        return positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    assert positions.ndim == 3, "M-RoPE needs (3, B, S) position ids"
    assert sum(mrope_sections) == half, (mrope_sections, half)
    parts = []
    start = 0
    for i, sec in enumerate(mrope_sections):
        f = freqs[start : start + sec]
        parts.append(positions[i][..., None].astype(jnp.float32) * f)
        start += sec
    return jnp.concatenate(parts, axis=-1)  # (B, S, half)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); angles: (B, S, dh//2). NeoX half-rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding


def embed_spec(cfg: ModelConfig):
    s = {"tokens": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                        init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        s["unembed"] = spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            init="fanin")
    return s


def embed_apply(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["tokens"], tokens, axis=0).astype(cfg.compute_dtype)
    return constrain(x, ("batch", "act_seq", "act_embed"))


def unembed_apply(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["tokens"].T
    else:
        w = params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cfg.compute_dtype))
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, ("batch", "act_seq", "act_vocab"))


# ---------------------------------------------------------------------------
# MLP


def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "wi_gate": spec((d, f), ("embed", "mlp")),
            "wi_up": spec((d, f), ("embed", "mlp")),
            "wo": spec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": spec((d, f), ("embed", "mlp")),
        "bi": spec((f,), ("mlp",), init="zeros"),
        "wo": spec((f, d), ("mlp", "embed")),
        "bo": spec((d,), ("embed",), init="zeros"),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(dt))
        h = jax.nn.silu(g) * u
        h = constrain(h, ("batch", "act_seq", "act_heads"))
        return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dt)) + params["bi"].astype(dt)
    h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "act_seq", "act_heads"))
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt)) + params["bo"].astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, optional bias, qk-norm, local window, KNN)


def attention_spec(cfg: ModelConfig):
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.dh
    s = {
        "wq": spec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": spec((d, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, kvh, dh), ("embed", "kv_heads", "head_dim")),
        "wo": spec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = spec((h, dh), ("heads", "head_dim"), init="zeros")
        s["bk"] = spec((kvh, dh), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = spec((kvh, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = spec((dh,), ("head_dim",), init="ones")
        s["k_norm"] = spec((dh,), ("head_dim",), init="ones")
    return s


def _rms_head(x, scale):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, -1, keepdims=True)
    return (x32 * lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def _qkv(params, x, cfg: ModelConfig, positions, *, rope: bool = True):
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms_head(q, params["q_norm"])
        k = _rms_head(k, params["k_norm"])
    if rope and cfg.rope_theta > 0:  # rope_theta == 0: absolute positions
        ang = rope_angles(positions, cfg.dh, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
    return q, k, v


def _repeat_kv(k, num_heads):
    """(B,S,KVH,dh) -> (B,S,H,dh) by repetition for grouped-query attn."""
    kvh = k.shape[2]
    if kvh == num_heads:
        return k
    return jnp.repeat(k, num_heads // kvh, axis=2)


def mha_chunked(q, k, v, *, causal: bool, window: int = 0,
                q_offset: Any = 0, kv_len: Optional[jax.Array] = None,
                q_chunk: int = 512):
    """Memory-bounded exact attention: iterate query chunks, full softmax
    over keys per chunk. q: (B,Sq,H,dh), k/v: (B,Skv,KVH,dv).

    Grouped-query form: KV heads are NEVER repeated/materialized — the
    einsum carries the (kv_head, group) split, so MQA (granite, 48x) and
    GQA (qwen3, 8x) avoid the repeated-KV memory blowup and GSPMD keeps
    the cache sharding instead of re-sharding to q-heads (§Perf D2.2).

    q_offset: absolute position of q[0] relative to k[0] (decode uses
    cache_len). kv_len: valid key prefix (masks cache tail).
    """
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    dv = v.shape[-1]  # may differ from dh (MLA: qk 192, v 128)
    scale = dh**-0.5
    qc = min(q_chunk, sq)
    while sq % qc:
        qc //= 2
    nc = sq // qc
    kpos = jnp.arange(skv)

    def chunk(carry, qi):
        qblk, start = qi  # (B,qc,H,dh), scalar
        qg = qblk.reshape(b, qc, kvh, g, dh)
        logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32)
        logits = logits * scale
        qpos = q_offset + start + jnp.arange(qc)
        mask = jnp.ones((qc, skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqt,btkd->bqkgd", w, v)
        return carry, out.reshape(b, qc, h, dv)

    if nc == 1:
        _, out = chunk(None, (q, jnp.int32(0)))
        return out
    qs = q.reshape(b, nc, qc, h, dh).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nc, dtype=jnp.int32) * qc
    _, outs = lax.scan(chunk, None, (qs, starts))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def attention_apply(params, x, cfg: ModelConfig, *, positions,
                    cache: Optional[dict] = None, pos: Any = None,
                    memory: Optional[tuple] = None, causal: bool = True):
    """Full attention forward.

    train/prefill: cache=None -> (out, (k, v)) so callers may build caches.
    decode: cache={"k","v"} (B,T,KVH,dh) + scalar `pos` -> (out, new_cache).
    memory: (mk, mv) for cross-attention (q from x, kv precomputed).
    """
    dt = cfg.compute_dtype
    window = cfg.window if cfg.attention == "local" else 0

    if memory is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dt)
        k, v = memory
        out = mha_chunked(q, k, v, causal=False, q_chunk=cfg.q_chunk)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), None

    if cache is None:
        q, k, v = _qkv(params, x, cfg, positions)
        # NOTE §Perf T3.1 (refuted): constraining just the attention
        # region to batch-over-model sharding forces a full activation
        # reshard into and out of every layer (collective term 3.3s ->
        # 46.9s). The working policy is rule-driven whole-model batch
        # sharding (ModelConfig.shard_batch_over_model, §Perf T3.2).
        q = constrain(q, ("batch", "act_seq", "act_heads", None))
        k = constrain(k, ("batch", "act_seq", "act_kv", None))
        out = mha_chunked(
            q, k, v, causal=causal, window=window, q_chunk=cfg.q_chunk
        )
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return out, (k, v)

    # ---- decode: single new token against the cache (grouped-query,
    # no KV repetition: the cache keeps its seq/kv-head sharding and the
    # softmax/AV contraction reduces across shards — flash-decode).
    # ``pos`` is a scalar (every row writes/attends at one position) or
    # a (B,) per-slot vector — a mixed-length slot batch decodes in ONE
    # call, each row writing its own cache slot and masking at its own
    # length (the serving engine's per-tick collapse, DESIGN.md §9).
    q, k_new, v_new = _qkv(params, x, cfg, positions)
    b = q.shape[0]
    kvh, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    t = cache["k"].shape[1]
    vec = jnp.ndim(pos) == 1  # per-slot position vector
    if window > 0:
        slot = pos % t  # rolling buffer for local attention
    else:
        slot = pos
    if vec:
        hit = jnp.arange(t)[None, :] == slot[:, None]  # (B, T)
        k_cache = jnp.where(
            hit[:, :, None, None], k_new.astype(cache["k"].dtype), cache["k"]
        )
        v_cache = jnp.where(
            hit[:, :, None, None], v_new.astype(cache["v"].dtype), cache["v"]
        )
    else:
        k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
        v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
    qg = q.reshape(b, 1, kvh, g, cfg.dh)
    logits = jnp.einsum(
        "bqkgd,btkd->bkgqt", qg, k_cache.astype(dt)
    ).astype(jnp.float32) * cfg.dh**-0.5
    logits = constrain(logits, ("batch", "act_kv", None, None, "act_cache"))
    kpos = jnp.arange(t)
    if vec:
        pv, sv = pos[:, None], slot[:, None]  # (B, 1) against kpos (T,)
        if window > 0:
            abs_pos = jnp.where(
                kpos[None, :] <= sv, pv - sv + kpos[None, :],
                pv - sv - t + kpos[None, :],
            )
            mask = (abs_pos >= 0) & (abs_pos <= pv) & (abs_pos > pv - window)
        else:
            mask = kpos[None, :] <= pv  # (B, T)
        logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    else:
        if window > 0:
            # rolling buffer: slot s holds absolute position derived from pos
            abs_pos = jnp.where(kpos <= slot, pos - slot + kpos, pos - slot - t + kpos)
            mask = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)
        else:
            mask = kpos <= pos
        logits = jnp.where(mask[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w, v_cache.astype(dt))
    out = out.reshape(b, 1, cfg.num_heads, cfg.dh)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, {"k": k_cache, "v": v_cache}


def knn_attention_apply(params, x, cfg: ModelConfig, *, positions,
                        cache: Optional[dict] = None, pos: Any = None):
    """DIGC-backed sparse attention (beyond-paper; attention='knn')."""
    from repro.core.knn_attention import knn_attention_decode, knn_attention_mha

    dt = cfg.compute_dtype
    q, k, v = _qkv(params, x, cfg, positions)
    kk = _repeat_kv(k, cfg.num_heads)
    vv = _repeat_kv(v, cfg.num_heads)
    if cache is None:
        def per_batch(qb, kb, vb):
            return knn_attention_mha(
                qb, kb, vb, num_neighbors=cfg.knn_neighbors, causal=True
            )

        out = jax.vmap(per_batch)(q, kk, vv)
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return out, (k, v)
    t = cache["k"].shape[1]
    if jnp.ndim(pos) == 1:  # per-slot position vector (one call per tick)
        hit = jnp.arange(t)[None, :] == pos[:, None]  # (B, T)
        k_cache = jnp.where(
            hit[:, :, None, None], k.astype(cache["k"].dtype), cache["k"]
        )
        v_cache = jnp.where(
            hit[:, :, None, None], v.astype(cache["v"].dtype), cache["v"]
        )
        pos_b = pos
    else:
        k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, 1)
        v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, 1)
        pos_b = jnp.full((q.shape[0],), pos, jnp.int32)
    kk = _repeat_kv(k_cache.astype(dt), cfg.num_heads)
    vv = _repeat_kv(v_cache.astype(dt), cfg.num_heads)

    def per_batch(qb, kb, vb, pb):
        return knn_attention_decode(
            qb, kb, vb, pb + 1, num_neighbors=cfg.knn_neighbors
        )

    out = jax.vmap(per_batch)(q[:, 0], kk, vv, pos_b)  # (B,H,dh)
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(dt))[:, None]
    return out, {"k": k_cache, "v": v_cache}
