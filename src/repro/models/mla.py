"""Multi-head Latent Attention (DeepSeek-V2). KV compressed to a small
latent (kv_lora) + a shared RoPE key; decode uses the absorbed form so
the cache stays (B, T, kv_lora + rope_dim) — the memory win that lets
V2-Lite serve long contexts."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import NEG_INF, apply_rope, mha_chunked, rope_angles
from repro.models.module import spec


def mla_spec(cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": spec((d, h, qk), ("embed", "heads", "head_dim")),
        "w_dkv": spec((d, m.kv_lora), ("embed", "kv_lora")),
        "w_kpe": spec((d, m.qk_rope_dim), ("embed", "head_dim")),
        "kv_norm": spec((m.kv_lora,), ("kv_lora",), init="ones"),
        "w_uk": spec((m.kv_lora, h, m.qk_nope_dim), ("kv_lora", "heads", "head_dim")),
        "w_uv": spec((m.kv_lora, h, m.v_dim), ("kv_lora", "heads", "head_dim")),
        "wo": spec((h, m.v_dim, d), ("heads", "head_dim", "embed")),
    }


def _rms(x, scale):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, -1, keepdims=True)
    return (x32 * lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def _compress(params, x, cfg: ModelConfig, positions):
    """x -> (c_kv (B,S,lora), k_pe (B,S,rope)) cache entries."""
    m = cfg.mla
    dt = cfg.compute_dtype
    c_kv = jnp.einsum("bsd,dl->bsl", x, params["w_dkv"].astype(dt))
    c_kv = _rms(c_kv, params["kv_norm"])
    k_pe = jnp.einsum("bsd,dr->bsr", x, params["w_kpe"].astype(dt))
    ang = rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], ang)[:, :, 0, :]
    return c_kv, k_pe


def _queries(params, x, cfg: ModelConfig, positions):
    m = cfg.mla
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope = q[..., : m.qk_nope_dim]
    q_pe = apply_rope(
        q[..., m.qk_nope_dim :],
        rope_angles(positions, m.qk_rope_dim, cfg.rope_theta),
    )
    return q_nope, q_pe


def mla_apply(params, x, cfg: ModelConfig, *, positions,
              cache: Optional[dict] = None, pos: Any = None):
    """Returns (out, cache_entries). Cache = {"c_kv", "k_pe"}."""
    m = cfg.mla
    dt = cfg.compute_dtype
    h = cfg.num_heads
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5

    if cache is None:
        # train / prefill: expand per-head keys and values from the latent.
        c_kv, k_pe = _compress(params, x, cfg, positions)
        q_nope, q_pe = _queries(params, x, cfg, positions)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, params["w_uk"].astype(dt))
        v = jnp.einsum("bsl,lhk->bshk", c_kv, params["w_uv"].astype(dt))
        q_cat = jnp.concatenate([q_nope, q_pe], -1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], q_pe.shape[:1] + k_pe.shape[1:2] + (h, m.qk_rope_dim))],
            -1,
        )
        out = mha_chunked(q_cat, k_cat, v, causal=True, q_chunk=cfg.q_chunk)
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return out, (c_kv, k_pe)

    # decode: absorbed attention directly in the latent space. ``pos``
    # is a scalar or a (B,) per-slot vector (mixed-length slot batches
    # decode in one call; each row writes/masks at its own position).
    c_new, kpe_new = _compress(params, x, cfg, positions)
    t = cache["c_kv"].shape[1]
    if jnp.ndim(pos) == 1:
        hit = jnp.arange(t)[None, :] == pos[:, None]  # (B, T)
        c_cache = jnp.where(
            hit[:, :, None], c_new.astype(cache["c_kv"].dtype), cache["c_kv"]
        )
        kpe_cache = jnp.where(
            hit[:, :, None], kpe_new.astype(cache["k_pe"].dtype),
            cache["k_pe"],
        )
        mask = jnp.arange(t)[None, :] <= pos[:, None]  # (B, T)
        mask_b = mask[:, None, None, :]
    else:
        c_cache = lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, 1
        )
        kpe_cache = lax.dynamic_update_slice_in_dim(
            cache["k_pe"], kpe_new.astype(cache["k_pe"].dtype), pos, 1
        )
        mask_b = (jnp.arange(t) <= pos)[None, None, None, :]
    q_nope, q_pe = _queries(params, x, cfg, positions)  # (B,1,H,*)
    # absorb W_uk into the query: q_lat = q_nope @ W_uk^T per head
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, params["w_uk"].astype(dt))
    logits = (
        jnp.einsum("bshl,btl->bhst", q_lat, c_cache.astype(dt))
        + jnp.einsum("bshr,btr->bhst", q_pe, kpe_cache.astype(dt))
    ).astype(jnp.float32) * scale
    logits = jnp.where(mask_b, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btl->bshl", w, c_cache.astype(dt))
    out = jnp.einsum("bshl,lhk->bshk", ctx, params["w_uv"].astype(dt))
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, {"c_kv": c_cache, "k_pe": kpe_cache}
