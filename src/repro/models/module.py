"""Minimal param-spec module system.

Models declare their parameters as a nested dict of ``ParamSpec`` leaves
(shape / dtype / logical axes / initializer). From one spec tree we
derive:

  * ``init_params``     -- materialized arrays (smoke tests, examples)
  * ``abstract_params`` -- ShapeDtypeStructs (the multi-pod dry-run
    lowers 72B-parameter models without allocating a byte)
  * ``logical_axes``    -- pytree of logical axis-name tuples
  * ``make_shardings``  -- NamedShardings from logical->mesh rules

Logical axis names are mapped to mesh axes by a rules dict
(MaxText-style), so the same model definition runs on any mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# ---------------------------------------------------------------------------
# Param specs


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names (len == ndim)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | fanin
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="fanin", dtype=jnp.float32, scale=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract_params(spec_tree):
    return _tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree)


def logical_axes(spec_tree):
    return _tree_map(lambda s: s.axes, spec_tree)


def _init_leaf(s: ParamSpec, key) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        sd = s.scale if s.scale is not None else 0.02
        return (jax.random.normal(key, s.shape) * sd).astype(s.dtype)
    if s.init == "embed":
        sd = s.scale if s.scale is not None else 1.0
        return (jax.random.normal(key, s.shape) * sd).astype(s.dtype)
    if s.init == "fanin":
        fan_in = s.shape[0] if len(s.shape) >= 1 else 1
        # contraction dim is the first axis by convention here
        sd = s.scale if s.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape) * sd).astype(s.dtype)
    raise ValueError(f"unknown init {s.init!r}")


def init_params(spec_tree, rng: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(rng, max(len(leaves), 1))
    arrays = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


# ---------------------------------------------------------------------------
# Sharding rules

# Default logical-axis -> mesh-axis mapping. "model" carries tensor/expert
# parallelism; "data" carries FSDP (ZeRO-3) sharding of the d_model /
# embed dimension of parameters; batch is sharded over (pod, data).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    # attention fallback when heads % TP != 0: batch takes the model
    # axis too (data+model first so single-pod meshes fully shard)
    "attn_batch": ("data", "model", "pod"),
    "embed": "data",  # FSDP axis for params
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_lora": None,
    "head_dim": None,
    "state": None,
    "conv": None,
    "seq": None,
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_vocab": "model",
    "act_kv": None,
    "act_cache": "model",  # decode logits' cache-seq dim (flash-decode)
    "stage": "stage",
    "layers": None,
}


def rules_for(cfg) -> dict:
    """Sharding rules adjusted for the config's parallelism policy."""
    if getattr(cfg, "shard_batch_over_model", False):
        r = dict(DEFAULT_RULES)
        r["batch"] = ("data", "model", "pod")
        r["act_heads"] = None  # heads replicated; batch covers model
        r["act_kv"] = None
        r["act_vocab"] = None  # logits batch-sharded instead
        r["act_cache"] = None
        return r
    return DEFAULT_RULES


def mesh_axes_for(axes: Sequence[Optional[str]], rules: Mapping[str, Any],
                  mesh: Mesh) -> PartitionSpec:
    """Translate logical axes to a PartitionSpec valid for `mesh`."""
    names = set(mesh.axis_names)
    out = []
    for ax in axes:
        target = rules.get(ax) if ax is not None else None
        if target is None:
            out.append(None)
            continue
        if isinstance(target, str):
            out.append(target if target in names else None)
        else:  # tuple of axes; keep the ones present in this mesh
            kept = tuple(t for t in target if t in names)
            out.append(kept if kept else None)
    return PartitionSpec(*out)


def make_shardings(spec_tree, mesh: Mesh, rules: Mapping[str, Any] = DEFAULT_RULES):
    def one(s: ParamSpec):
        ps = mesh_axes_for(s.axes, rules, mesh)
        ps = _drop_indivisible(s.shape, ps, mesh)
        return NamedSharding(mesh, ps)

    return _tree_map(one, spec_tree)


def _drop_indivisible(shape, ps: PartitionSpec, mesh: Mesh) -> PartitionSpec:
    """Drop mesh axes that don't divide the dim (keeps GSPMD happy without
    padding surprises; e.g. kv_heads=1 can't shard 16 ways)."""
    out = []
    for dim, entry in zip(shape, tuple(ps) + (None,) * (len(shape) - len(ps))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        kept = []
        for a in axes:
            size = mesh.shape[a]
            if dim % (total * size) == 0:
                kept.append(a)
                total *= size
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*out)


def scan_or_unroll(body, carry, xs, use_scan: bool):
    """`lax.scan` or a Python unroll with identical semantics.

    The dry-run unrolls because XLA's HloCostAnalysis counts a while
    loop body ONCE (trip count unknown at that level) — unrolled HLO
    gives exact per-step flops/bytes/collective totals."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys_list = []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys_list.append(y)
    ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys_list)
    return carry, ys


# Explicit (mesh, rules) context for activation sharding constraints.
# Must be active while the step function is *traced* (jit(...).lower
# under `with use_mesh(mesh)`), which is how launch/dryrun.py drives it.
_ACTIVE_MESH: list[tuple[Mesh, Mapping[str, Any]]] = []


class use_mesh:
    """Context manager making `mesh` (+ sharding rules) visible to
    `constrain`."""

    def __init__(self, mesh: Mesh, rules: Optional[Mapping[str, Any]] = None):
        self.mesh = mesh
        self.rules = rules if rules is not None else DEFAULT_RULES

    def __enter__(self):
        _ACTIVE_MESH.append((self.mesh, self.rules))
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()
        return False


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[-1][0] if _ACTIVE_MESH else None


def active_rules() -> Mapping[str, Any]:
    return _ACTIVE_MESH[-1][1] if _ACTIVE_MESH else DEFAULT_RULES


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[Mapping[str, Any]] = None) -> jax.Array:
    """Activation sharding constraint by logical axes. No-op when no
    mesh context is active (single-device smoke tests)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    rules = rules if rules is not None else active_rules()
    ps = mesh_axes_for(axes, rules, mesh)
    ps = _drop_indivisible(x.shape, ps, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))
