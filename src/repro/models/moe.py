"""Mixture-of-Experts with explicit expert parallelism.

Distributed path (inside jit, via shard_map over the full mesh):
  * activations arrive batch-sharded over ("pod","data") and replicated
    over "model"; expert weights are sharded over "model" (E_loc = E /
    |model| experts per rank).
  * every rank routes its local tokens, gathers the ones destined for
    its *local* experts into fixed-capacity buffers (static shapes),
    runs the batched expert GEMMs, scatters weighted outputs back, and
    a psum over "model" combines expert contributions.
  * capacity cf=1.25: overflowing tokens are dropped (standard GShard
    semantics); the drop fraction is returned as a metric.

Single-device / no-mesh path: dense compute of all experts weighted by
the (zeroed) router probs — mathematically the capacity-unlimited
reference used by the tests.

Router is fp32; aux load-balance loss (Switch-style) is returned.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.models.config import ModelConfig
from repro.models.module import active_mesh, spec


def moe_spec(cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_expert
    s = {
        "router": spec((d, e), ("embed", None), init="fanin", dtype=jnp.float32),
        "w_gate": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        fs = m.d_expert * m.num_shared
        s["shared"] = {
            "wi_gate": spec((d, fs), ("embed", "mlp")),
            "wi_up": spec((d, fs), ("embed", "mlp")),
            "wo": spec((fs, d), ("mlp", "embed")),
        }
    return s


def _router(params, tokens, m):
    """tokens (T, D) -> (gates (T,k), sel (T,k), aux_loss, probs)."""
    logits = tokens.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    # Switch load-balance loss: E * sum_e f_e * p_e
    e = probs.shape[-1]
    dispatch = jax.nn.one_hot(sel[:, 0], e)  # primary assignment
    f_e = jnp.mean(dispatch, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return gates, sel, aux, probs


def _dense_moe(params, x, cfg: ModelConfig):
    """Reference path: every expert on every token (tiny configs only)."""
    m = cfg.moe
    dt = cfg.compute_dtype
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    gates, sel, aux, _ = _router(params, tokens, m)
    e = m.num_experts
    # combine weights (T, E): gate where selected else 0
    comb = jnp.zeros((tokens.shape[0], e), jnp.float32)
    comb = comb.at[jnp.arange(tokens.shape[0])[:, None], sel].add(gates)
    h_g = jnp.einsum("td,edf->tef", tokens, params["w_gate"].astype(dt))
    h_u = jnp.einsum("td,edf->tef", tokens, params["w_up"].astype(dt))
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(dt))
    out = jnp.einsum("ted,te->td", y_e.astype(jnp.float32), comb).astype(dt)
    metrics = {"moe_aux": aux, "moe_drop_frac": jnp.float32(0.0)}
    return out.reshape(b, s, d), metrics


def _local_expert_moe(x_loc, router_w, w_gate, w_up, w_down, *, m, dt,
                      axis_name: str, n_shards: int):
    """shard_map body. x_loc (b_loc, s, d) replicated over `axis_name`;
    w_* are the local expert shards (E_loc, ...)."""
    b, s, d = x_loc.shape
    tokens = x_loc.reshape(-1, d)
    t = tokens.shape[0]
    e_loc = w_gate.shape[0]
    e = e_loc * n_shards
    rank = lax.axis_index(axis_name)
    e0 = rank * e_loc

    logits = tokens.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, -1)
    gates, sel = lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    dispatch1 = jax.nn.one_hot(sel[:, 0], e)
    aux = e * jnp.sum(jnp.mean(dispatch1, 0) * jnp.mean(probs, 0))

    cap = max(int(t * m.top_k / e * m.capacity_factor), 4)
    # local expert ids; out-of-range -> e_loc (overflow bucket)
    lid = sel - e0  # (T, k)
    in_range = (lid >= 0) & (lid < e_loc)
    lid_c = jnp.where(in_range, lid, 0)
    # position of each (t, j) within its expert, priority by token order
    onehot = jax.nn.one_hot(lid_c, e_loc, dtype=jnp.int32) * in_range[..., None]
    flat = onehot.reshape(t * m.top_k, e_loc)
    pos = jnp.cumsum(flat, axis=0) - flat  # entries before this one
    pos_sel = jnp.sum(pos * flat, axis=1).reshape(t, m.top_k)
    keep = in_range & (pos_sel < cap)
    dropped = jnp.sum(in_range & (pos_sel >= cap)).astype(jnp.float32)

    slot = jnp.where(keep, lid_c * cap + pos_sel, e_loc * cap)  # overflow row
    # dispatch: buffers (E_loc*cap + 1, d)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, m.top_k)).reshape(-1)
    buf = jnp.zeros((e_loc * cap + 1, d), dt)
    buf = buf.at[slot.reshape(-1)].add(tokens[tok_idx].astype(dt))
    buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))
    y_flat = jnp.concatenate([y.reshape(e_loc * cap, d), jnp.zeros((1, d), dt)], 0)

    gathered = y_flat[slot.reshape(-1)].reshape(t, m.top_k, d)
    out = jnp.sum(gathered.astype(jnp.float32) * jnp.where(keep, gates, 0.0)[..., None], axis=1)
    out = lax.psum(out.astype(dt), axis_name)
    # aux identical on all ranks (same tokens); dropped differs -> psum
    dropped = lax.psum(dropped, axis_name) / jnp.float32(t * m.top_k)
    return out.reshape(b, s, d), aux, dropped


def moe_apply(params, x, cfg: ModelConfig, *, mesh=None, model_axis="model"):
    """Returns (out, metrics). Distributed iff a mesh with a >1 `model`
    axis is active."""
    m = cfg.moe
    dt = cfg.compute_dtype
    mesh = mesh or active_mesh()
    out_metrics = {}

    if mesh is not None and model_axis in mesh.axis_names and mesh.shape[model_axis] > 1:
        n_shards = mesh.shape[model_axis]
        assert m.num_experts % n_shards == 0, (m.num_experts, n_shards)
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        body = functools.partial(
            _local_expert_moe, m=m, dt=dt, axis_name=model_axis, n_shards=n_shards
        )
        mapped = compat.shard_map(
            body,
            mesh,
            in_specs=(
                P(batch_axes or None, None, None),
                P(None, None),
                P(model_axis, None, None),
                P(model_axis, None, None),
                P(model_axis, None, None),
            ),
            out_specs=(P(batch_axes or None, None, None), P(), P()),
        )
        out, aux, drop = mapped(
            x, params["router"], params["w_gate"], params["w_up"], params["w_down"]
        )
        # shard_map replicates aux across ranks; take as-is
        out_metrics = {"moe_aux": aux, "moe_drop_frac": drop}
    else:
        out, out_metrics = _dense_moe(params, x, cfg)

    if m.num_shared:
        sh = params["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["wi_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, sh["wi_up"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sh["wo"].astype(dt))
    return out, out_metrics
