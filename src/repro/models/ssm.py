"""Mamba-2 (state-space duality / SSD) layer.

Chunked SSD for train/prefill (intra-chunk quadratic + inter-chunk
linear state recurrence) and an O(1)-per-token stateful decode step.
Shapes follow the minimal-SSD formulation: heads H = d_inner/head_dim,
scalar decay per head, B/C shared across heads (n_groups=1).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.module import spec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, heads, conv_dim


def ssm_spec(cfg: ModelConfig):
    s, d_in, heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": spec(
            (d, 2 * d_in + 2 * s.n_groups * s.d_state + heads), ("embed", "mlp")
        ),
        "conv_w": spec((s.d_conv, conv_dim), ("conv", "mlp"), init="fanin"),
        "conv_b": spec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": spec((heads,), ("heads",), init="zeros"),
        "d_skip": spec((heads,), ("heads",), init="ones"),
        "dt_bias": spec((heads,), ("heads",), init="zeros"),
        "norm": spec((d_in,), ("mlp",), init="ones"),
        "out_proj": spec((d_in, d), ("mlp", "embed")),
    }


def _split(zxbcdt, cfg):
    s, d_in, heads, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """xbc (B,S,C), w (K,C): depthwise causal conv along S."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale):
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(y32 * y32, -1, keepdims=True)
    return (y32 * lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk: int):
    """Minimal SSD. xh (B,S,H,P), dt (B,S,H), a (H,) negative,
    b/c (B,S,N). Returns y (B,S,H,P), final state (B,H,N,P)."""
    bsz, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    xd = xh * dt[..., None]  # fold dt into inputs
    la = dt * a  # (B,S,H) log-decay per step
    # chunked views
    xd_c = xd.reshape(bsz, nc, q, h, p)
    la_c = la.reshape(bsz, nc, q, h)
    b_c = bmat.reshape(bsz, nc, q, n)
    c_c = cmat.reshape(bsz, nc, q, n)
    cum = jnp.cumsum(la_c, axis=2)  # (B,nc,q,H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)  # (B,nc,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, lmat, xd_c)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) B_j xd_j^T
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", b_c, decay_states, xd_c)

    # inter-chunk recurrence over nc (sequential scan)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def step(hprev, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    init = jnp.zeros((bsz, h, n, p), xh.dtype)
    hfinal, hprevs = lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state before chunk

    # inter-chunk contribution: C_i · h_prev scaled by exp(cum_i)
    y_off = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", c_c, jnp.exp(cum), hprevs
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, hfinal


def ssm_apply(params, x, cfg: ModelConfig, *, state: Optional[dict] = None):
    """Mamba-2 block.

    train/prefill: state=None -> (out, final_state) where final_state =
    {"h": (B,H,N,P), "conv": (B,K-1,convdim)}.
    decode: state given, x is (B,1,D) -> (out, new_state).
    """
    s, d_in, heads, conv_dim = _dims(cfg)
    dt_ = cfg.compute_dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xbc_raw, dtp = _split(zxbcdt, cfg)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,)

    if state is None:
        xbc = _causal_conv(xbc_raw, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_))
        xin = xbc[..., :d_in]
        bmat = xbc[..., d_in : d_in + s.d_state].astype(jnp.float32)
        cmat = xbc[..., d_in + s.d_state :].astype(jnp.float32)
        dt = jax.nn.softplus(dtp.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
        bsz, seq = x.shape[:2]
        xh = xin.reshape(bsz, seq, heads, s.head_dim).astype(jnp.float32)
        y, hfinal = _ssd_chunked(xh, dt, a, bmat, cmat, s.chunk)
        y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
        y = y.reshape(bsz, seq, d_in).astype(dt_)
        y = _gated_norm(y, z, params["norm"])
        out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
        k = s.d_conv
        conv_tail = xbc_raw[:, -(k - 1) :, :] if seq >= k - 1 else jnp.pad(
            xbc_raw, ((0, 0), (k - 1 - seq, 0), (0, 0))
        )
        return out, {"h": hfinal.astype(jnp.float32), "conv": conv_tail}

    # ---- decode (single token)
    conv_prev = state["conv"]  # (B, K-1, convdim)
    k = s.d_conv
    window = jnp.concatenate([conv_prev.astype(dt_), xbc_raw], axis=1)  # (B,K,convdim)
    w = params["conv_w"].astype(dt_)
    xbc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(dt_)
    )[:, None, :]
    xin = xbc[..., :d_in]
    bmat = xbc[..., d_in : d_in + s.d_state].astype(jnp.float32)[:, 0]  # (B,N)
    cmat = xbc[..., d_in + s.d_state :].astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(
        dtp[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    bsz = x.shape[0]
    xh = xin.reshape(bsz, heads, s.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    h_new = state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", bmat, dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat, h_new)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, d_in).astype(dt_)
    y = _gated_norm(y, z, params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    conv_new = jnp.concatenate([conv_prev[:, 1:], xbc_raw.astype(conv_prev.dtype)], axis=1)
    return out, {"h": h_new, "conv": conv_new}


def ssm_init_state(cfg: ModelConfig, batch: int):
    s, d_in, heads, conv_dim = _dims(cfg)
    return {
        "h": jnp.zeros((batch, heads, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.float32),
    }
