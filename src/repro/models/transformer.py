"""Decoder-only LM assembly for every assigned family.

One config-driven model: dense GQA (qwen/olmo/granite), qk-norm
(qwen3), MLA+MoE (deepseek), routed MoE (qwen3-moe), SSD (mamba2),
RG-LRU hybrid (recurrentgemma), M-RoPE VLM backbone (qwen2-vl).

Layers are scanned (stacked params, `lax.scan`) so the lowered HLO is
O(1) in depth — required to compile 88-94 layer models quickly — with a
configurable remat policy on the block body.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.griffin import rglru_apply, rglru_init_state, rglru_spec
from repro.models.layers import (
    attention_apply,
    attention_spec,
    embed_apply,
    embed_spec,
    knn_attention_apply,
    mlp_apply,
    mlp_spec,
    norm_apply,
    norm_spec,
    unembed_apply,
)
from repro.models.mla import mla_apply, mla_spec
from repro.models.module import ParamSpec, constrain, is_spec, scan_or_unroll
from repro.models.moe import moe_apply, moe_spec
from repro.models.ssm import ssm_apply, ssm_init_state, ssm_spec


# ---------------------------------------------------------------------------
# Param specs


def stack_specs(tree, n: int):
    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale)

    return jax.tree_util.tree_map(one, tree, is_leaf=is_spec)


def _attn_spec(cfg: ModelConfig):
    return mla_spec(cfg) if cfg.mla else attention_spec(cfg)


def _mixer_layer_spec(cfg: ModelConfig, kind: str):
    """One residual layer: temporal mixer + channel mixer."""
    if kind == "ssm":
        return {"ln1": norm_spec(cfg), "ssm": ssm_spec(cfg)}
    s = {"ln1": norm_spec(cfg), "ln2": norm_spec(cfg)}
    s["mix"] = rglru_spec(cfg) if kind == "rec" else _attn_spec(cfg)
    s["mlp"] = moe_spec(cfg) if (cfg.moe and kind == "attn_moe") else mlp_spec(cfg)
    return s


def _layer_kind(cfg: ModelConfig):
    if cfg.family == "ssm":
        return "ssm"
    if cfg.moe:
        return "attn_moe"
    return "attn"


def param_spec(cfg: ModelConfig):
    p: dict[str, Any] = {"embed": embed_spec(cfg), "final_norm": norm_spec(cfg)}
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        n_groups = cfg.num_layers // len(pat)
        rem = cfg.num_layers - n_groups * len(pat)
        group = {
            f"l{i}_{kind}": _mixer_layer_spec(cfg, "rec" if kind == "rec" else "attn")
            for i, kind in enumerate(pat)
        }
        p["groups"] = stack_specs(group, n_groups)
        if rem:
            p["rem"] = {
                f"l{i}_rec": _mixer_layer_spec(cfg, "rec") for i in range(rem)
            }
        return p
    p["layers"] = stack_specs(_mixer_layer_spec(cfg, _layer_kind(cfg)), cfg.num_layers)
    return p


# ---------------------------------------------------------------------------
# Blocks


def _apply_mixer(lp, x, cfg: ModelConfig, kind: str, *, positions,
                 cache=None, pos=None):
    """Temporal mixing sublayer. Returns (out, cache_entry)."""
    if kind == "ssm":
        return ssm_apply(lp["ssm"], x, cfg, state=cache)
    if kind == "rec":
        return rglru_apply(lp["mix"], x, cfg, state=cache)
    if cfg.mla:
        return mla_apply(lp["mix"], x, cfg, positions=positions, cache=cache, pos=pos)
    if cfg.attention == "knn":
        return knn_attention_apply(
            lp["mix"], x, cfg, positions=positions, cache=cache, pos=pos
        )
    return attention_apply(lp["mix"], x, cfg, positions=positions, cache=cache, pos=pos)


def _block(lp, x, cfg: ModelConfig, kind: str, *, positions, cache=None, pos=None):
    """One residual layer: x + mixer(norm(x)); x + mlp(norm(x))."""
    metrics = {}
    h = norm_apply(lp["ln1"], x, cfg)
    mix_out, cache_entry = _apply_mixer(
        lp, h, cfg, kind, positions=positions, cache=cache, pos=pos
    )
    x = x + mix_out
    if kind != "ssm":  # mamba2 blocks have no separate channel mixer
        h = norm_apply(lp["ln2"], x, cfg)
        if cfg.moe and kind != "rec":
            mlp_out, metrics = moe_apply(lp["mlp"], h, cfg)
        else:
            mlp_out = mlp_apply(lp["mlp"], h, cfg)
        x = x + mlp_out
    x = constrain(x, ("batch", "act_seq", "act_embed"))
    aux = metrics.get("moe_aux", jnp.float32(0.0))
    drop = metrics.get("moe_drop_frac", jnp.float32(0.0))
    return x, cache_entry, aux, drop


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward (train / prefill)


def forward(params, tokens, cfg: ModelConfig, *, positions=None,
            return_cache: bool = False):
    """tokens (B,S) -> logits (B,S,V) [+ layer caches for prefill]."""
    b, s = tokens.shape[-2:] if tokens.ndim >= 2 else (1, tokens.shape[0])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions, (3, b, s))
    x = embed_apply(params["embed"], tokens, cfg)

    aux_total = jnp.float32(0.0)
    drop_total = jnp.float32(0.0)
    caches = None

    if cfg.family == "hybrid":
        x, caches, aux_total, drop_total = _hybrid_forward(
            params, x, cfg, positions, return_cache
        )
    else:
        kind = _layer_kind(cfg)

        def body(carry, lp):
            h = carry
            h, cache_entry, aux, drop = _block(
                lp, h, cfg, kind, positions=positions
            )
            ys = (cache_entry if return_cache else None, aux, drop)
            return h, ys

        body = _remat(body, cfg)
        x, (caches, auxs, drops) = scan_or_unroll(
            body, x, params["layers"], cfg.scan_layers
        )
        aux_total = jnp.sum(auxs)
        drop_total = jnp.mean(drops)

    x = norm_apply(params["final_norm"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    metrics = {"moe_aux": aux_total, "moe_drop_frac": drop_total}
    if return_cache:
        return logits, caches, metrics
    return logits, metrics


def _hybrid_forward(params, x, cfg: ModelConfig, positions, return_cache):
    pat = cfg.hybrid.pattern

    def group_body(carry, gp):
        h = carry
        entries = {}
        for i, kind in enumerate(pat):
            lp = gp[f"l{i}_{kind}"]
            h, ce, _, _ = _block(lp, h, cfg, kind, positions=positions)
            entries[f"l{i}_{kind}"] = ce if return_cache else None
        return h, entries

    group_body = _remat(group_body, cfg)
    x, group_caches = scan_or_unroll(
        group_body, x, params["groups"], cfg.scan_layers
    )
    rem_caches = {}
    if "rem" in params:
        for name, lp in params["rem"].items():
            x, ce, _, _ = _block(lp, x, cfg, "rec", positions=positions)
            rem_caches[name] = ce if return_cache else None
    caches = {"groups": group_caches, "rem": rem_caches} if return_cache else None
    return x, caches, jnp.float32(0.0), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Loss


def softmax_xent(logits, labels):
    """CE without gathering along the (model-sharded) vocab axis: the
    gather would force SPMD to replicate the full logits tensor (13 GB/
    device at olmo train_4k). The iota-match reduction is shard-local;
    only the scalar per-token sums cross shards."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    viota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(viota == labels[..., None], logits.astype(jnp.float32), 0.0),
        axis=-1,
    )
    return logz - gold


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: tokens (B,S), labels (B,S), mask (B,S)."""
    logits, metrics = forward(params, batch["tokens"], cfg,
                              positions=batch.get("positions"))
    nll = softmax_xent(logits, batch["labels"])
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = loss + 0.01 * metrics["moe_aux"]
    return loss, metrics


# ---------------------------------------------------------------------------
# KV / state caches + decode


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Functional decode cache, leading `layers` dim where scanned."""
    dt = cfg.compute_dtype

    def attn_entry():
        if cfg.mla:
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((batch, max_len, m.kv_lora), dt),
                "k_pe": jnp.zeros((batch, max_len, m.qk_rope_dim), dt),
            }
        t = max_len if cfg.attention != "local" else min(cfg.window, max_len)
        return {
            "k": jnp.zeros((batch, t, cfg.num_kv_heads, cfg.dh), dt),
            "v": jnp.zeros((batch, t, cfg.num_kv_heads, cfg.dh), dt),
        }

    if cfg.family == "ssm":
        one = ssm_init_state(cfg, batch)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one
        )
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        n_groups = cfg.num_layers // len(pat)
        rem = cfg.num_layers - n_groups * len(pat)
        group = {}
        for i, kind in enumerate(pat):
            one = rglru_init_state(cfg, batch) if kind == "rec" else attn_entry()
            group[f"l{i}_{kind}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one
            )
        return {
            "groups": group,
            "rem": {f"l{i}_rec": rglru_init_state(cfg, batch) for i in range(rem)},
        }
    one = attn_entry()
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one
    )


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, *, positions=None):
    """One decode step. tokens (B,1); pos int32 — a scalar (write slot /
    absolute position for every row) **or a (B,) per-slot vector**: a
    mixed-length slot batch decodes in one call, each row writing its
    cache at (and attending up to) its own position. Returns
    (logits (B,1,V), new_cache)."""
    b = tokens.shape[0]
    if positions is None:
        if jnp.ndim(pos) == 1:
            positions = jnp.reshape(pos, (b, 1)).astype(jnp.int32)
        else:
            positions = jnp.full((b, 1), pos, jnp.int32)
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions, (3, b, 1))
    x = embed_apply(params["embed"], tokens, cfg)

    if cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cache, x, cfg, positions, pos)
    else:
        kind = _layer_kind(cfg)

        def body(carry, xs):
            h = carry
            lp, layer_cache = xs
            h, new_entry, _, _ = _block(
                lp, h, cfg, kind, positions=positions, cache=layer_cache, pos=pos
            )
            return h, new_entry

        x, new_cache = scan_or_unroll(
            body, x, (params["layers"], cache), cfg.scan_layers
        )

    x = norm_apply(params["final_norm"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    return logits, new_cache


def _hybrid_decode(params, cache, x, cfg: ModelConfig, positions, pos):
    pat = cfg.hybrid.pattern

    def group_body(carry, xs):
        h = carry
        gp, gc = xs
        new_entries = {}
        for i, kind in enumerate(pat):
            name = f"l{i}_{kind}"
            h, ce, _, _ = _block(
                gp[name], h, cfg, kind, positions=positions, cache=gc[name], pos=pos
            )
            new_entries[name] = ce
        return h, new_entries

    x, new_groups = scan_or_unroll(
        group_body, x, (params["groups"], cache["groups"]), cfg.scan_layers
    )
    new_rem = {}
    for name, lp in params.get("rem", {}).items():
        x, ce, _, _ = _block(
            lp, x, cfg, "rec", positions=positions, cache=cache["rem"][name], pos=pos
        )
        new_rem[name] = ce
    return x, {"groups": new_groups, "rem": new_rem}


def prefill(params, tokens, cfg: ModelConfig, *, max_len: Optional[int] = None,
            positions=None):
    """Run the prompt, return (logits, cache ready for decode_step at
    pos = S)."""
    logits, caches, _ = forward(
        params, tokens, cfg, positions=positions, return_cache=True
    )
    if cfg.family in ("ssm", "hybrid"):
        return logits, caches  # states are already decode-ready
    s = tokens.shape[1]
    max_len = max_len or s
    window = cfg.window if cfg.attention == "local" else 0

    # stacked caches have a leading `layers` dim: seq axis is 2.
    def pad_kv(kv):
        k, v = kv
        if window:
            k, v = k[:, :, -window:], v[:, :, -window:]
            tgt = min(window, max_len)
        else:
            tgt = max_len
        pad = tgt - k.shape[2]
        if pad > 0:
            pw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, pw)
            v = jnp.pad(v, pw)
        return {"k": k, "v": v}

    if cfg.mla:
        def pad_mla(kv):
            c, kp = kv
            pad = max_len - c.shape[2]
            if pad > 0:
                pw = ((0, 0), (0, 0), (0, pad), (0, 0))
                c = jnp.pad(c, pw)
                kp = jnp.pad(kp, pw)
            return {"c_kv": c, "k_pe": kp}

        cache = jax.tree_util.tree_map(
            pad_mla, caches, is_leaf=lambda t: isinstance(t, tuple)
        )
    else:
        cache = jax.tree_util.tree_map(
            pad_kv, caches, is_leaf=lambda t: isinstance(t, tuple)
        )
    return logits, cache
