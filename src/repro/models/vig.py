"""Vision GNN (ViG) backbones — isotropic and pyramid variants.

Each Grapher block re-runs DIGC on the current features (the *dynamic*
in DIGC) and aggregates neighbors with max-relative graph convolution,
exactly the pipeline the paper accelerates. The DIGC implementation is
a constructor choice resolved through the GraphBuilder registry
(`digc_impl` names any registered builder — reference | blocked |
pallas | cluster | axial | ... — or pass a full DigcSpec), mirroring
the paper's "modular similarity mechanism" claim. The model contains no
strategy-specific code: DIGC runs batched over (B, N, D) directly and
each builder brings its own fused aggregation if it has one.

Pyramid variants pool co-nodes by the stage reduction ratio r before
graph construction (paper §III-C: Y from spatial pooling, M = N / r^2).

Deviation from the torch reference: BatchNorm -> LayerNorm (stateless,
jit-friendly); this changes training dynamics, not DIGC structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.builder import DigcSpec, get_builder
from repro.core.digc import digc
from repro.core.graph import mr_aggregate
from repro.core.state import DigcState, state_entry
from repro.core.tuner import VigSchedule
from repro.models.module import spec


@dataclasses.dataclass(frozen=True)
class VigConfig:
    name: str
    variant: str  # isotropic | pyramid
    image_size: int = 224
    patch: int = 16
    in_chans: int = 3
    embed_dims: tuple[int, ...] = (192,)
    depths: tuple[int, ...] = (12,)
    reduce_ratios: tuple[int, ...] = (1,)
    k: int = 9
    max_dilation: int = 4
    use_dilation: bool = True
    num_classes: int = 1000
    digc_impl: str = "blocked"
    ffn_ratio: int = 4

    @property
    def base_grid(self) -> int:
        return self.image_size // self.patch

    def grid_at_stage(self, si: int) -> int:
        return max(self.base_grid // (2**si), 1)

    def replace(self, **kw) -> "VigConfig":
        return dataclasses.replace(self, **kw)


# ViG paper variants.
VIG_VARIANTS = {
    "vig_ti_iso": VigConfig("vig_ti_iso", "isotropic", embed_dims=(192,), depths=(12,)),
    "vig_s_iso": VigConfig("vig_s_iso", "isotropic", embed_dims=(320,), depths=(16,)),
    "vig_b_iso": VigConfig("vig_b_iso", "isotropic", embed_dims=(640,), depths=(16,)),
    "vig_ti_pyr": VigConfig(
        "vig_ti_pyr", "pyramid", patch=4, embed_dims=(48, 96, 240, 384),
        depths=(2, 2, 6, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_s_pyr": VigConfig(
        "vig_s_pyr", "pyramid", patch=4, embed_dims=(80, 160, 400, 640),
        depths=(2, 2, 6, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_m_pyr": VigConfig(
        "vig_m_pyr", "pyramid", patch=4, embed_dims=(96, 192, 384, 768),
        depths=(2, 2, 16, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_b_pyr": VigConfig(
        "vig_b_pyr", "pyramid", patch=4, embed_dims=(128, 256, 512, 1024),
        depths=(2, 2, 18, 2), reduce_ratios=(4, 2, 1, 1),
    ),
}


# ---------------------------------------------------------------------------
# Param spec


def _block_spec(d: int, ffn: int):
    return {
        "ln_g": {"scale": spec((d,), ("embed",), init="ones")},
        "fc_in": spec((d, d), ("embed", "mlp")),
        "fc_graph": spec((2 * d, d), ("mlp", "embed")),
        "fc_out": spec((d, d), ("embed", "mlp")),
        "ln_f": {"scale": spec((d,), ("embed",), init="ones")},
        "fc1": spec((d, ffn * d), ("embed", "mlp")),
        "fc2": spec((ffn * d, d), ("mlp", "embed")),
    }


def vig_param_spec(cfg: VigConfig):
    g0 = cfg.base_grid
    n0 = g0 * g0
    p: dict[str, Any] = {
        "stem": spec(
            (cfg.patch * cfg.patch * cfg.in_chans, cfg.embed_dims[0]),
            ("embed", "mlp"),
        ),
        "pos": spec((n0, cfg.embed_dims[0]), ("seq", "embed"), init="normal"),
        "head": spec((cfg.embed_dims[-1], cfg.num_classes), ("embed", "vocab")),
    }
    for si, (d, depth) in enumerate(zip(cfg.embed_dims, cfg.depths)):
        p[f"stage{si}"] = {
            f"block{bi}": _block_spec(d, cfg.ffn_ratio) for bi in range(depth)
        }
        if si + 1 < len(cfg.embed_dims):
            p[f"down{si}"] = spec(
                (4 * d, cfg.embed_dims[si + 1]), ("embed", "mlp")
            )
    return p


# ---------------------------------------------------------------------------
# Forward


def _ln(x, scale):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


def patchify(images: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, C) -> (B, N, patch*patch*C)."""
    b, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch * patch * c)


def _pool_conodes(x: jax.Array, grid: int, r: int) -> Optional[jax.Array]:
    """(B, N, D) on a grid -> average-pooled co-nodes (B, N/r^2, D).

    Returns None for r <= 1: co-nodes are the nodes themselves, and
    None is the registry's explicit self-graph marker (DESIGN.md §4).
    """
    if r <= 1:
        return None
    b, n, d = x.shape
    g2 = grid // r
    xg = x.reshape(b, g2, r, g2, r, d)
    return xg.mean(axis=(2, 4)).reshape(b, g2 * g2, d)


def _downsample(x: jax.Array, grid: int, w: jax.Array) -> jax.Array:
    """2x2 patch-merge + linear projection."""
    b, n, d = x.shape
    g2 = grid // 2
    xg = x.reshape(b, g2, 2, g2, 2, d).transpose(0, 1, 3, 2, 4, 5)
    xg = xg.reshape(b, g2 * g2, 4 * d)
    return xg @ w


def _dilation_for(cfg: VigConfig, global_block: int, m: int) -> int:
    if not cfg.use_dilation:
        return 1
    d = min(global_block // 4 + 1, cfg.max_dilation)
    while cfg.k * d > m and d > 1:
        d -= 1
    return d


def resolve_digc_spec(cfg: VigConfig,
                      digc_impl: Union[str, DigcSpec, None],
                      stage: int = 0) -> DigcSpec:
    """Normalize the model's DIGC choice to a DigcSpec.

    A spec that leaves ``k`` unset (the default) inherits cfg.k, so
    passing ``DigcSpec(impl="pallas")`` only picks the implementation;
    an explicit ``k`` in the spec wins over the config. A
    ``VigSchedule`` resolves to its entry for ``stage`` (per-stage
    tuned engine schedules, ``core.tuner.tune_schedule``).
    """
    choice = digc_impl if digc_impl is not None else cfg.digc_impl
    if isinstance(choice, VigSchedule):
        choice = choice.spec_for(stage)
    if isinstance(choice, DigcSpec):
        return choice if choice.k is not None else choice.replace(k=cfg.k)
    return DigcSpec(impl=choice, k=cfg.k)


def grapher_block(bp, x, cfg: VigConfig, grid: int, r: int, dilation: int,
                  digc_spec: Optional[DigcSpec] = None,
                  cache=None, layer_key: Optional[str] = None,
                  state: Optional[DigcState] = None):
    """x (B, N, D) -> ((B, N, D), state); one Grapher + FFN residual
    pair. The second return is the (possibly updated) ``DigcState`` —
    ``None`` when no state was passed.

    Graph construction runs batched through the registry — no per-sample
    closure, no strategy branching; the builder supplies its fused
    aggregation (e.g. the MRConv Pallas kernel) when it has one. Two
    ways to carry construction state across layers and requests:

    * ``state`` (a functional ``DigcState`` pytree, keyed by
      ``layer_key``) — the jit-native path: stateful builders read and
      return their entry *through* the trace, so warm starts work in
      compiled serving.
    * ``cache`` (a ``DigcCache``) — the legacy eager shim: host-side,
      bypassed under jit.
    """
    dspec = digc_spec if digc_spec is not None else resolve_digc_spec(cfg, None)
    h = _ln(x, bp["ln_g"]["scale"])
    h = h @ bp["fc_in"]
    cond = _pool_conodes(h, grid, r)  # None = self-graph
    m = cond.shape[1] if cond is not None else h.shape[1]
    k_eff = min(dspec.k, m // max(dilation, 1)) or 1
    if k_eff * dilation > m:
        dilation = 1
    # k/dilation/grid geometry are stage-derived: override whatever the
    # incoming spec carries (pyramid stages shrink the grid every
    # downsample, so a fixed user grid would go stale).
    dspec = dspec.replace(k=k_eff, dilation=dilation).with_grid(grid, grid)
    builder = get_builder(dspec.impl)
    # Centroid warm starts are shared per stage (same co-node geometry):
    # layer l+1 starts from layer l's centroids, the next request from
    # this one's — features drift slowly, so 2 Lloyd iterations suffice.
    if state is not None:
        idx, state = digc(h, cond, spec=dspec, state=state,
                          state_key=layer_key)  # (B, N, k)
    else:
        idx = digc(h, cond, spec=dspec, cache=cache,
                   cache_key=layer_key)  # (B, N, k)
    aggregate = builder.aggregate if builder.aggregate is not None else mr_aggregate
    agg = aggregate(h, cond if cond is not None else h, idx)
    h = jnp.concatenate([h, agg], axis=-1) @ bp["fc_graph"]
    h = jax.nn.gelu(h) @ bp["fc_out"]
    x = x + h
    f = _ln(x, bp["ln_f"]["scale"])
    f = jax.nn.gelu(f @ bp["fc1"]) @ bp["fc2"]
    return x + f, state


def vig_forward(params, images, cfg: VigConfig, *,
                digc_impl: Union[str, DigcSpec, "VigSchedule", None] = None,
                cache=None,
                state: Optional[DigcState] = None):
    """images (B, H, W, C) -> class logits (B, num_classes).

    ``digc_impl`` may be a registered builder name, a full DigcSpec, or
    a ``VigSchedule`` (per-stage tuned specs). Construction state
    across blocks and requests comes in two forms:

    * ``state`` — a functional ``DigcState`` (see ``init_vig_state``):
      the call returns ``(logits, new_state)`` and is fully
      jit-compatible; blocks in a stage share a state key, so layer
      l+1 warm-starts from layer l, and feeding the returned state into
      the next call warm-starts request-to-request *inside* the
      compiled program.
    * ``cache`` — the legacy eager ``DigcCache`` shim (host-side,
      bypassed under jit); returns logits only.
    """
    x = patchify(images, cfg.patch) @ params["stem"]
    x = x + params["pos"]
    grid = cfg.base_grid
    gb = 0
    for si, depth in enumerate(cfg.depths):
        spec = resolve_digc_spec(cfg, digc_impl, stage=si)
        r = cfg.reduce_ratios[si] if si < len(cfg.reduce_ratios) else 1
        m = (grid // max(r, 1)) ** 2
        for bi in range(depth):
            dil = _dilation_for(cfg, gb, m)
            x, state = grapher_block(
                params[f"stage{si}"][f"block{bi}"], x, cfg, grid, r, dil,
                digc_spec=spec, cache=cache, layer_key=f"stage{si}",
                state=state,
            )
            gb += 1
        if si + 1 < len(cfg.depths):
            x = _downsample(x, grid, params[f"down{si}"])
            grid //= 2
    pooled = jnp.mean(x, axis=1)
    logits = pooled @ params["head"]
    if state is not None:
        return logits, state
    return logits


def init_vig_state(cfg: VigConfig, batch: int,
                   digc_impl: Union[str, DigcSpec, "VigSchedule", None] = None,
                   *, per_slot: bool = False, mesh=None,
                   mesh_axis: str = "data") -> DigcState:
    """Allocate the functional DIGC state for a model + batch size.

    One entry per stage (the key ``grapher_block`` passes): a cold
    step counter always; a (B, C, D) centroid buffer when the stage's
    builder is the cluster tier (C from ``default_cluster_params`` on
    the stage's co-node count — the same derivation the builder uses,
    so shapes line up). The pytree structure this fixes is the compiled
    program's contract: changing batch size or impl means re-init.

    ``per_slot=True`` additionally allocates (batch,) per-row step
    counters on every entry — the multi-tenant serving layout
    (DESIGN.md §9): each batch row is a serving slot whose warm/cold
    validity is tracked independently, so the slot lifecycle
    (``DigcState.take_rows`` / ``put_rows`` / ``reset_rows``) can admit
    and evict tenants without cross-contaminating warm starts.

    ``mesh``/``mesh_axis`` place every entry for sharded construction
    (DESIGN.md §10): a stage whose spec carries a mesh (the ring tier)
    must see its state buffers resident where its ``shard_map`` body
    reads them. A spec that names its own mesh (``spec.mesh``) wins
    over the argument, so a mixed schedule (ring stage next to a
    single-device stage) places each stage where it runs. In a ViG
    forward the co-nodes are this call's own features (never a frozen
    gallery), so ring/blocked stages carry counters only — placement
    matters the moment a caller allocates gallery norms or centroids.
    """
    from repro.core.strategies import default_cluster_params

    rows = batch if per_slot else None
    entries = {}
    grid = cfg.base_grid
    for si in range(len(cfg.depths)):
        spec = resolve_digc_spec(cfg, digc_impl, stage=si)
        r = cfg.reduce_ratios[si] if si < len(cfg.reduce_ratios) else 1
        m = (grid // max(r, 1)) ** 2
        stage_mesh = spec.mesh if spec.mesh is not None else mesh
        stage_axis = (
            spec.axis_name if spec.axis_name is not None else mesh_axis
        )
        placement = dict(mesh=stage_mesh, axis_name=stage_axis)
        if spec.impl == "cluster":
            n_clusters, _ = default_cluster_params(
                m, spec.n_clusters, spec.n_probe
            )
            entries[f"stage{si}"] = state_entry(
                centroids_shape=(batch, n_clusters, cfg.embed_dims[si]),
                rows=rows, **placement,
            )
        else:
            entries[f"stage{si}"] = state_entry(rows=rows, **placement)
        if si + 1 < len(cfg.depths):
            grid //= 2
    return DigcState.init(entries)


def vig_loss_fn(params, batch, cfg: VigConfig):
    logits = vig_forward(params, batch["images"], cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold), {}


def count_digc_work(cfg: VigConfig):
    """Per-image DIGC workload (N, M, D, k, dilation) per block — feeds
    the paper-table benchmarks."""
    out = []
    grid = cfg.base_grid
    gb = 0
    for si, depth in enumerate(cfg.depths):
        r = cfg.reduce_ratios[si] if si < len(cfg.reduce_ratios) else 1
        n = grid * grid
        m = (grid // max(r, 1)) ** 2
        d = cfg.embed_dims[si]
        for _ in range(depth):
            dil = _dilation_for(cfg, gb, m)
            out.append({"stage": si, "N": n, "M": m, "D": d, "k": cfg.k,
                        "dilation": dil})
            gb += 1
        if si + 1 < len(cfg.depths):
            grid //= 2
    return out
