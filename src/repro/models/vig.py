"""Vision GNN (ViG) backbones — isotropic and pyramid variants.

Each Grapher block re-runs DIGC on the current features (the *dynamic*
in DIGC) and aggregates neighbors with max-relative graph convolution,
exactly the pipeline the paper accelerates. The DIGC implementation is
a constructor choice resolved through the GraphBuilder registry
(`digc_impl` names any registered builder — reference | blocked |
pallas | cluster | axial | ... — or pass a full DigcSpec), mirroring
the paper's "modular similarity mechanism" claim. The model contains no
strategy-specific code: DIGC runs batched over (B, N, D) directly and
each builder brings its own fused aggregation if it has one.

Pyramid variants pool co-nodes by the stage reduction ratio r before
graph construction (paper §III-C: Y from spatial pooling, M = N / r^2).

Deviation from the torch reference: BatchNorm -> LayerNorm (stateless,
jit-friendly); this changes training dynamics, not DIGC structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.builder import DigcSpec, get_builder
from repro.core.digc import digc
from repro.core.graph import mr_aggregate
from repro.core.state import DigcState, state_entry
from repro.core.tuner import VigSchedule
from repro.models.module import spec


class VigGridError(ValueError):
    """Typed config-time error for grid geometry a model cannot run:
    non-square / non-patch-aligned inputs, or a pyramid stage whose
    grid is not divisible by its reduce ratio or by the 2x downsample
    (the old failure mode was a bare reshape TypeError mid-forward)."""


@dataclasses.dataclass(frozen=True)
class VigConfig:
    name: str
    variant: str  # isotropic | pyramid
    image_size: int = 224
    patch: int = 16
    in_chans: int = 3
    embed_dims: tuple[int, ...] = (192,)
    depths: tuple[int, ...] = (12,)
    reduce_ratios: tuple[int, ...] = (1,)
    k: int = 9
    max_dilation: int = 4
    use_dilation: bool = True
    num_classes: int = 1000
    digc_impl: str = "blocked"
    ffn_ratio: int = 4

    @property
    def base_grid(self) -> int:
        return self.image_size // self.patch

    def grid_at_stage(self, si: int) -> int:
        return max(self.base_grid // (2**si), 1)

    def replace(self, **kw) -> "VigConfig":
        return dataclasses.replace(self, **kw)


# ViG paper variants.
VIG_VARIANTS = {
    "vig_ti_iso": VigConfig("vig_ti_iso", "isotropic", embed_dims=(192,), depths=(12,)),
    "vig_s_iso": VigConfig("vig_s_iso", "isotropic", embed_dims=(320,), depths=(16,)),
    "vig_b_iso": VigConfig("vig_b_iso", "isotropic", embed_dims=(640,), depths=(16,)),
    "vig_ti_pyr": VigConfig(
        "vig_ti_pyr", "pyramid", patch=4, embed_dims=(48, 96, 240, 384),
        depths=(2, 2, 6, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_s_pyr": VigConfig(
        "vig_s_pyr", "pyramid", patch=4, embed_dims=(80, 160, 400, 640),
        depths=(2, 2, 6, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_m_pyr": VigConfig(
        "vig_m_pyr", "pyramid", patch=4, embed_dims=(96, 192, 384, 768),
        depths=(2, 2, 16, 2), reduce_ratios=(4, 2, 1, 1),
    ),
    "vig_b_pyr": VigConfig(
        "vig_b_pyr", "pyramid", patch=4, embed_dims=(128, 256, 512, 1024),
        depths=(2, 2, 18, 2), reduce_ratios=(4, 2, 1, 1),
    ),
}


# ---------------------------------------------------------------------------
# Param spec


def _block_spec(d: int, ffn: int):
    return {
        "ln_g": {"scale": spec((d,), ("embed",), init="ones")},
        "fc_in": spec((d, d), ("embed", "mlp")),
        "fc_graph": spec((2 * d, d), ("mlp", "embed")),
        "fc_out": spec((d, d), ("embed", "mlp")),
        "ln_f": {"scale": spec((d,), ("embed",), init="ones")},
        "fc1": spec((d, ffn * d), ("embed", "mlp")),
        "fc2": spec((ffn * d, d), ("mlp", "embed")),
    }


def vig_param_spec(cfg: VigConfig):
    g0 = cfg.base_grid
    n0 = g0 * g0
    p: dict[str, Any] = {
        "stem": spec(
            (cfg.patch * cfg.patch * cfg.in_chans, cfg.embed_dims[0]),
            ("embed", "mlp"),
        ),
        "pos": spec((n0, cfg.embed_dims[0]), ("seq", "embed"), init="normal"),
        "head": spec((cfg.embed_dims[-1], cfg.num_classes), ("embed", "vocab")),
    }
    for si, (d, depth) in enumerate(zip(cfg.embed_dims, cfg.depths)):
        p[f"stage{si}"] = {
            f"block{bi}": _block_spec(d, cfg.ffn_ratio) for bi in range(depth)
        }
        if si + 1 < len(cfg.embed_dims):
            p[f"down{si}"] = spec(
                (4 * d, cfg.embed_dims[si + 1]), ("embed", "mlp")
            )
    return p


# ---------------------------------------------------------------------------
# Forward


def _ln(x, scale):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


def patchify(images: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, C) -> (B, N, patch*patch*C)."""
    b, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch * patch * c)


def _pool_conodes(x: jax.Array, grid: int, r: int) -> Optional[jax.Array]:
    """(B, N, D) on a grid -> average-pooled co-nodes (B, N/r^2, D).

    Returns None for r <= 1: co-nodes are the nodes themselves, and
    None is the registry's explicit self-graph marker (DESIGN.md §4).
    """
    if r <= 1:
        return None
    if grid % r:
        raise VigGridError(
            f"co-node pooling needs grid divisible by r={r}; got "
            f"grid={grid} (vig_stage_plans screens this at config time)"
        )
    b, n, d = x.shape
    g2 = grid // r
    xg = x.reshape(b, g2, r, g2, r, d)
    return xg.mean(axis=(2, 4)).reshape(b, g2 * g2, d)


def _downsample(x: jax.Array, grid: int, w: jax.Array) -> jax.Array:
    """2x2 patch-merge + linear projection."""
    if grid % 2:
        raise VigGridError(
            f"2x2 downsample needs an even grid; got grid={grid} "
            f"(vig_stage_plans screens this at config time)"
        )
    b, n, d = x.shape
    g2 = grid // 2
    xg = x.reshape(b, g2, 2, g2, 2, d).transpose(0, 1, 3, 2, 4, 5)
    xg = xg.reshape(b, g2 * g2, 4 * d)
    return xg @ w


def _dilation_for(cfg: VigConfig, global_block: int, m: int,
                  k: Optional[int] = None, *,
                  grid: Optional[int] = None,
                  base_grid: Optional[int] = None) -> int:
    if not cfg.use_dilation:
        return 1
    k = cfg.k if k is None else k
    d = global_block // 4 + 1
    cap = cfg.max_dilation
    if grid is not None and base_grid is not None:
        # Per-cell dilation schedule (DESIGN.md §13/§14): the stride
        # AND its cap ride the same resolution ramp as k, so a
        # high-resolution cell's dilated blocks keep the same
        # *relative* reach across the denser grid; at or below the
        # native grid both scalers return their inputs, so native
        # plans are untouched.
        d = _resolution_dilation(d, grid, base_grid)
        cap = _resolution_dilation(cap, grid, base_grid)
    d = min(d, cap)
    while k * d > m and d > 1:
        d -= 1
    return d


def _resolution_k(k: int, grid: int, base_grid: int) -> int:
    """The resolution-scaled neighbor count: ``n_knn = linspace(k, 2k)``
    in the resolution dimension (the ViG / PVG-DET idiom — more pixels
    per object means each node needs proportionally more neighbors to
    cover the same receptive field). k at the model's native grid,
    ramping linearly to 2k at twice the native grid, clamped to
    [k, 2k]; grids at or below native keep the model's k, so native
    forwards are byte-identical to the pre-multires behavior."""
    if grid <= base_grid:
        return k
    frac = min(1.0, (grid - base_grid) / base_grid)
    return int(round(k * (1.0 + frac)))


def _resolution_dilation(d: int, grid: int, base_grid: int) -> int:
    """The resolution-scaled dilation stride, mirroring
    ``_resolution_k``: d at the model's native grid, ramping linearly
    to 2d at twice the native grid, clamped to [d, 2d]. A dilated
    block's receptive reach is ~k*d node strides — on a denser grid the
    same stride covers a smaller fraction of the image, so the stride
    widens with resolution exactly as the neighbor count does (the
    PVG-DET ramp applied to the dilation schedule). Grids at or below
    native return ``d`` unchanged — native plans stay byte-identical."""
    if grid <= base_grid:
        return d
    frac = min(1.0, (grid - base_grid) / base_grid)
    return int(round(d * (1.0 + frac)))


def _pos_for_grid(pos: jax.Array, base_grid: int, grid: int) -> jax.Array:
    """Resample the learned (base_grid^2, D) positional embedding to a
    serving grid: reshape to 2D, bilinear-resize, flatten — the
    standard ViT/ViG practice for off-native resolutions. Deterministic
    (no RNG, no data dependence), so an engine forward and its B=1
    replay see bit-identical embeddings; a no-op at the native grid."""
    if grid == base_grid:
        return pos
    d = pos.shape[-1]
    pos2d = pos.reshape(base_grid, base_grid, d)
    out = jax.image.resize(pos2d, (grid, grid, d), method="bilinear")
    return out.reshape(grid * grid, d).astype(pos.dtype)


# ---------------------------------------------------------------------------
# Stage pipeline (DESIGN.md §12)
#
# The forward pass is an explicit pipeline of per-stage plans instead of
# an implicit layer loop: every piece of stage geometry a DIGC call
# depends on (grid, co-node pooling, per-block dilation and effective k)
# is derived ONCE here, so the model forward, the functional state
# allocator and the workload accounting all read the same plan — the
# cached-graph buffers in ``DigcState`` are sized by exactly the
# derivation that later writes them.


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One stage of the ViG pipeline: static geometry + resolved spec."""

    index: int
    depth: int
    grid: int
    r: int
    m: int  # co-nodes per image (grid/r)^2
    spec: DigcSpec  # stage spec, k/dilation still model-owned
    dilations: tuple[int, ...]  # per block, after the m-feasibility clamp
    k_effs: tuple[int, ...]  # per block effective neighbor count

    @property
    def key(self) -> str:
        """The state key every block of this stage shares."""
        return f"stage{self.index}"

    @property
    def n(self) -> int:
        return self.grid * self.grid


def _block_geometry(cfg: VigConfig, gb: int, m: int,
                    k: Optional[int] = None, *,
                    grid: Optional[int] = None,
                    base_grid: Optional[int] = None) -> tuple[int, int]:
    """(dilation, k_eff) for global block ``gb`` against ``m`` co-nodes
    — the single source of the k/dilation clamps the old layer loop
    applied inline. ``k`` overrides cfg.k (the resolution-scaled
    schedule feeds the stage's scaled k here); ``grid``/``base_grid``
    additionally scale the dilation schedule for off-native cells
    (``_resolution_dilation``), with the same m-feasibility clamps
    applied *after* scaling."""
    k = cfg.k if k is None else k
    dil = _dilation_for(cfg, gb, m, k, grid=grid, base_grid=base_grid)
    k_eff = min(k, m // max(dil, 1)) or 1
    if k_eff * dil > m:
        dil = 1
    return dil, k_eff


def vig_stage_plans(cfg: VigConfig,
                    digc_impl: Union[str, DigcSpec, "VigSchedule", None] = None,
                    *, grid: Optional[int] = None,
                    ) -> tuple[StagePlan, ...]:
    """Materialize the stage pipeline for a model + DIGC choice.

    ``grid`` is the serving patch grid (default: the config's native
    ``base_grid``) — the resolution-parametric hook: stage grids, m,
    the per-block (dilation, k_eff) clamps and the resolution-scaled
    k and dilation schedules (``_resolution_k`` /
    ``_resolution_dilation``) all derive from it, so one config serves
    any square input whose grid passes the divisibility screen.

    Raises ``VigGridError`` at config time (here, not mid-forward) when
    a stage's grid is not divisible by its reduce ratio or, for any
    stage but the last, by the 2x downsample — naming the stage and
    grid (e.g. 800^2 / patch 16 -> grid 50 -> 25 breaks the second
    downsample of a 4-stage pyramid).
    """
    plans = []
    grid = cfg.base_grid if grid is None else int(grid)
    if grid < 1:
        raise VigGridError(f"serving grid must be >= 1; got {grid}")
    gb = 0
    for si, depth in enumerate(cfg.depths):
        spec = resolve_digc_spec(cfg, digc_impl, stage=si)
        r = cfg.reduce_ratios[si] if si < len(cfg.reduce_ratios) else 1
        if r > 1 and grid % r:
            raise VigGridError(
                f"stage{si}: grid {grid} is not divisible by its "
                f"reduce ratio r={r} (model {cfg.name!r}); serve a "
                f"resolution whose stage grids divide, or drop the "
                f"pooling ratio"
            )
        if si + 1 < len(cfg.depths) and grid % 2:
            raise VigGridError(
                f"stage{si}: grid {grid} is odd but stage{si + 1} "
                f"needs the 2x2 downsample (model {cfg.name!r}); "
                f"serve a resolution divisible through every stage"
            )
        k_s = _resolution_k(spec.k, grid, cfg.grid_at_stage(si))
        spec = spec.replace(k=k_s)
        m = (grid // max(r, 1)) ** 2
        geo = tuple(
            _block_geometry(cfg, gb + bi, m, k_s, grid=grid,
                            base_grid=cfg.grid_at_stage(si))
            for bi in range(depth)
        )
        plans.append(StagePlan(
            index=si, depth=depth, grid=grid, r=r, m=m, spec=spec,
            dilations=tuple(g[0] for g in geo),
            k_effs=tuple(g[1] for g in geo),
        ))
        gb += depth
        if si + 1 < len(cfg.depths):
            grid //= 2
    return tuple(plans)


def resolve_digc_spec(cfg: VigConfig,
                      digc_impl: Union[str, DigcSpec, None],
                      stage: int = 0) -> DigcSpec:
    """Normalize the model's DIGC choice to a DigcSpec.

    A spec that leaves ``k`` unset (the default) inherits cfg.k, so
    passing ``DigcSpec(impl="pallas")`` only picks the implementation;
    an explicit ``k`` in the spec wins over the config. A
    ``VigSchedule`` resolves to its entry for ``stage`` (per-stage
    tuned engine schedules, ``core.tuner.tune_schedule``).
    """
    choice = digc_impl if digc_impl is not None else cfg.digc_impl
    if isinstance(choice, VigSchedule):
        choice = choice.spec_for(stage)
    if isinstance(choice, DigcSpec):
        return choice if choice.k is not None else choice.replace(k=cfg.k)
    return DigcSpec(impl=choice, k=cfg.k)


def grapher_block(bp, x, cfg: VigConfig, grid: int, r: int, dilation: int,
                  digc_spec: Optional[DigcSpec] = None,
                  cache=None, layer_key: Optional[str] = None,
                  state: Optional[DigcState] = None,
                  reuse_first: bool = True,
                  digc_capture: Optional[list] = None,
                  m_valid: Optional[jax.Array] = None):
    """x (B, N, D) -> ((B, N, D), state); one Grapher + FFN residual
    pair. The second return is the (possibly updated) ``DigcState`` —
    ``None`` when no state was passed.

    Graph construction runs batched through the registry — no per-sample
    closure, no strategy branching; the builder supplies its fused
    aggregation (e.g. the MRConv Pallas kernel) when it has one. Two
    ways to carry construction state across layers and requests:

    * ``state`` (a functional ``DigcState`` pytree, keyed by
      ``layer_key``) — the jit-native path: stateful builders read and
      return their entry *through* the trace, so warm starts work in
      compiled serving. ``reuse_first`` marks the first block of a
      stage within a forward pass — the gate point of the ``"tick"``
      stale-graph policy (DESIGN.md §12).
    * ``cache`` (a ``DigcCache``) — the legacy eager shim: host-side,
      bypassed under jit.

    ``digc_capture`` (a list) collects ``(layer_key, h, cond)`` per
    DIGC call — the probe hook the tuner's recall-floor verification
    and the recall-vs-drift bench replay against; works under jit when
    the caller returns the captured arrays as outputs.

    ``m_valid`` ((N,) or (B, N) bool) marks live nodes when the batch
    carries N-bucket pad nodes (DESIGN.md §13): pad co-node columns are
    BIG-norm-masked inside DIGC so they never enter a live row's top-k.
    Only meaningful for self-graph stages (r == 1 — pooling would mix
    pad and live nodes); the caller (``vig_forward``) screens that.
    """
    dspec = digc_spec if digc_spec is not None else resolve_digc_spec(cfg, None)
    h = _ln(x, bp["ln_g"]["scale"])
    h = h @ bp["fc_in"]
    cond = _pool_conodes(h, grid, r)  # None = self-graph
    m = cond.shape[1] if cond is not None else h.shape[1]
    k_eff = min(dspec.k, m // max(dilation, 1)) or 1
    if k_eff * dilation > m:
        dilation = 1
    # k/dilation/grid geometry are stage-derived: override whatever the
    # incoming spec carries (pyramid stages shrink the grid every
    # downsample, so a fixed user grid would go stale).
    dspec = dspec.replace(k=k_eff, dilation=dilation).with_grid(grid, grid)
    builder = get_builder(dspec.impl)
    if digc_capture is not None:
        digc_capture.append((layer_key, h, cond))
    # Centroid warm starts are shared per stage (same co-node geometry):
    # layer l+1 starts from layer l's centroids, the next request from
    # this one's — features drift slowly, so 2 Lloyd iterations suffice.
    if state is not None:
        idx, state = digc(h, cond, spec=dspec, state=state,
                          state_key=layer_key,
                          reuse_first=reuse_first,
                          m_valid=m_valid)  # (B, N, k)
    else:
        idx = digc(h, cond, spec=dspec, cache=cache,
                   cache_key=layer_key, m_valid=m_valid)  # (B, N, k)
    aggregate = builder.aggregate if builder.aggregate is not None else mr_aggregate
    agg = aggregate(h, cond if cond is not None else h, idx)
    h = jnp.concatenate([h, agg], axis=-1) @ bp["fc_graph"]
    h = jax.nn.gelu(h) @ bp["fc_out"]
    x = x + h
    f = _ln(x, bp["ln_f"]["scale"])
    f = jax.nn.gelu(f @ bp["fc1"]) @ bp["fc2"]
    return x + f, state


def run_stage(stage_params, x, cfg: VigConfig, plan: StagePlan, *,
              cache=None, state: Optional[DigcState] = None,
              digc_capture: Optional[list] = None,
              m_valid: Optional[jax.Array] = None):
    """Run one pipeline stage: ``plan.depth`` Grapher+FFN blocks over a
    fixed grid, sharing the stage's state key (layer l+1 warm-starts —
    or, under a reuse policy, serves — layer l's graph artifact)."""
    for bi in range(plan.depth):
        x, state = grapher_block(
            stage_params[f"block{bi}"], x, cfg, plan.grid, plan.r,
            plan.dilations[bi], digc_spec=plan.spec, cache=cache,
            layer_key=plan.key, state=state, reuse_first=(bi == 0),
            digc_capture=digc_capture, m_valid=m_valid,
        )
    return x, state


def vig_forward(params, images, cfg: VigConfig, *,
                digc_impl: Union[str, DigcSpec, "VigSchedule", None] = None,
                cache=None,
                state: Optional[DigcState] = None,
                digc_capture: Optional[list] = None,
                valid_mask: Optional[jax.Array] = None):
    """images (B, H, W, C) -> class logits (B, num_classes).

    ``digc_impl`` may be a registered builder name, a full DigcSpec, or
    a ``VigSchedule`` (per-stage tuned specs). The forward is an
    explicit stage pipeline (``vig_stage_plans`` / ``run_stage``,
    DESIGN.md §12): patchify → stem → per-stage Grapher blocks (with
    the graph index treated as a cached, versioned state artifact when
    the spec carries a ``reuse`` policy) → downsample → head.
    Construction state across blocks and requests comes in two forms:

    * ``state`` — a functional ``DigcState`` (see ``init_vig_state``):
      the call returns ``(logits, new_state)`` and is fully
      jit-compatible; blocks in a stage share a state key, so layer
      l+1 warm-starts from layer l, and feeding the returned state into
      the next call warm-starts request-to-request *inside* the
      compiled program.
    * ``cache`` — the legacy eager ``DigcCache`` shim (host-side,
      bypassed under jit); returns logits only.

    ``digc_capture`` (a list) collects every DIGC call's
    ``(layer_key, nodes, co_nodes)`` — the recall-verification probe
    hook (see ``grapher_block``).

    **Resolution-parametric** (DESIGN.md §13): the serving grid is
    inferred from the image shape — H == W, divisible by ``cfg.patch``
    (``VigGridError`` otherwise) — so one config + param set serves any
    square resolution whose grid passes ``vig_stage_plans``'s screen.
    Off-native grids bilinear-resample the positional embedding
    (``_pos_for_grid``) and scale k per stage (``_resolution_k``); the
    native grid runs byte-identical to the pre-multires forward.

    ``valid_mask`` ((N,) or (B, N) bool) marks live nodes when images
    were zero-padded up to an N-bucket: pad nodes are BIG-norm-masked
    out of every DIGC top-k and excluded from the mean pooling (all
    other compute is node-local). Supported only for single-stage
    models with r == 1 — pooling/downsampling would mix pad and live
    rows — enforced here with a ``VigGridError``.
    """
    b, hh, ww, _ = images.shape
    if hh != ww:
        raise VigGridError(
            f"vig_forward needs square inputs; got H={hh}, W={ww} "
            f"(pad to a square N-bucket upstream)"
        )
    if hh % cfg.patch:
        raise VigGridError(
            f"image size {hh} is not divisible by patch={cfg.patch}"
        )
    grid0 = hh // cfg.patch
    plans = vig_stage_plans(cfg, digc_impl, grid=grid0)
    if valid_mask is not None and (
        len(cfg.depths) > 1 or any(p.r > 1 for p in plans)
    ):
        raise VigGridError(
            f"valid_mask (N-bucket pad nodes) requires a single-stage "
            f"model with r=1 — pooling/downsampling mixes pad and live "
            f"rows; model {cfg.name!r} has depths={cfg.depths}, "
            f"reduce_ratios={cfg.reduce_ratios}"
        )
    x = patchify(images, cfg.patch) @ params["stem"]
    x = x + _pos_for_grid(params["pos"], cfg.base_grid, grid0)
    for plan in plans:
        x, state = run_stage(
            params[plan.key], x, cfg, plan, cache=cache, state=state,
            digc_capture=digc_capture, m_valid=valid_mask,
        )
        if plan.index + 1 < len(cfg.depths):
            x = _downsample(x, plan.grid, params[f"down{plan.index}"])
    if valid_mask is None:
        pooled = jnp.mean(x, axis=1)
    else:
        mask = jnp.asarray(valid_mask, bool)
        mask = mask[None, :] if mask.ndim == 1 else mask
        w = mask.astype(x.dtype)[..., None]
        pooled = jnp.sum(x * w, axis=1) / jnp.sum(
            w, axis=1
        ).clip(1.0)
    logits = pooled @ params["head"]
    if state is not None:
        return logits, state
    return logits


def init_vig_state(cfg: VigConfig, batch: int,
                   digc_impl: Union[str, DigcSpec, "VigSchedule", None] = None,
                   *, per_slot: bool = False, mesh=None,
                   mesh_axis: str = "data",
                   grid: Optional[int] = None) -> DigcState:
    """Allocate the functional DIGC state for a model + batch size.

    One entry per stage (the key ``grapher_block`` passes): a cold
    step counter always; a (B, C, D) centroid buffer when the stage's
    builder is the cluster tier (C from ``default_cluster_params`` on
    the stage's co-node count — the same derivation the builder uses,
    so shapes line up). The pytree structure this fixes is the compiled
    program's contract: changing batch size or impl means re-init.

    ``per_slot=True`` additionally allocates (batch,) per-row step
    counters on every entry — the multi-tenant serving layout
    (DESIGN.md §9): each batch row is a serving slot whose warm/cold
    validity is tracked independently, so the slot lifecycle
    (``DigcState.take_rows`` / ``put_rows`` / ``reset_rows``) can admit
    and evict tenants without cross-contaminating warm starts.

    ``mesh``/``mesh_axis`` place every entry for sharded construction
    (DESIGN.md §10): a stage whose spec carries a mesh (the ring tier)
    must see its state buffers resident where its ``shard_map`` body
    reads them. A spec that names its own mesh (``spec.mesh``) wins
    over the argument, so a mixed schedule (ring stage next to a
    single-device stage) places each stage where it runs. In a ViG
    forward the co-nodes are this call's own features (never a frozen
    gallery), so ring/blocked stages carry counters only — placement
    matters the moment a caller allocates gallery norms or centroids.

    ``grid`` sizes the state for an off-native serving resolution
    (DESIGN.md §13): the multi-resolution engine allocates one state
    per N-bucket, each sized by the plans that bucket's forward runs.
    """
    from repro.core.builder import reuse_params
    from repro.core.strategies import default_cluster_params

    rows = batch if per_slot else None
    entries = {}
    for plan in vig_stage_plans(cfg, digc_impl, grid=grid):
        spec = plan.spec
        stage_mesh = spec.mesh if spec.mesh is not None else mesh
        stage_axis = (
            spec.axis_name if spec.axis_name is not None else mesh_axis
        )
        alloc = dict(mesh=stage_mesh, axis_name=stage_axis, rows=rows)
        if spec.impl == "cluster":
            n_clusters, _ = default_cluster_params(
                plan.m, spec.n_clusters, spec.n_probe
            )
            alloc["centroids_shape"] = (
                batch, n_clusters, cfg.embed_dims[plan.index]
            )
        policy, _, _ = reuse_params(spec)
        if policy is not None:
            # Cached-graph buffers (DESIGN.md §12), sized by the
            # stage's FIRST block — the same derivation grapher_block
            # applies, so the shapes line up; a later block whose
            # clamped k_eff differs (tiny co-node counts) simply never
            # engages the cache (static shape check in the gate).
            alloc["graph_shape"] = (batch, plan.n, plan.k_effs[0])
        entries[plan.key] = state_entry(**alloc)
    return DigcState.init(entries)


def vig_loss_fn(params, batch, cfg: VigConfig):
    logits = vig_forward(params, batch["images"], cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold), {}


def count_digc_work(cfg: VigConfig, *, grid: Optional[int] = None):
    """Per-image DIGC workload (N, M, D, k, dilation) per block — feeds
    the paper-table benchmarks. Reads the same ``vig_stage_plans`` the
    forward executes (including, with ``grid=``, an off-native serving
    resolution and its scaled k), so the accounting can never drift
    from the model."""
    out = []
    for plan in vig_stage_plans(cfg, grid=grid):
        d = cfg.embed_dims[plan.index]
        for bi in range(plan.depth):
            out.append({
                "stage": plan.index, "N": plan.n, "M": plan.m, "D": d,
                "k": plan.spec.k, "dilation": plan.dilations[bi],
            })
    return out
