# Serving substrate: KV caches, slot-based continuous batching.
