# Serving substrate: KV caches, slot-based continuous batching for the
# LM path, and the ViG image engine serving every tier through a single
# donated jax.jit with cross-request functional DigcState (per-stage
# VigSchedule autotuning; the eager DigcCache path survives as the
# mode="eager" compatibility shim).
