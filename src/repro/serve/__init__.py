# Serving substrate: KV caches, slot-based continuous batching for the
# LM path, and the ViG image engine with cross-request DIGC state
# (DigcCache + autotuned construction schedule).
