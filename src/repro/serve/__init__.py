# Serving substrate: KV caches, slot-based continuous batching for the
# LM path (per-slot cache commit masks), and the multi-tenant bucketed
# ViG image engine (DESIGN.md §9): fixed slots, request batches padded
# to a static bucket set, one donated jax.jit + per-slot functional
# DigcState rows per bucket (per-bucket VigSchedule autotuning; the
# eager DigcCache path survives as the mode="eager" compatibility shim).
