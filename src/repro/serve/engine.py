"""Batched serving engines.

* ``ServeEngine`` — LM prefill + decode with a slot-based batch
  (continuous-batching-lite). Requests occupy fixed batch slots;
  finished slots are refilled from the queue without stalling in-flight
  decodes. Per-slot lengths are tracked host-side; the decode step
  itself is a single jit'd call over the full slot batch (static
  shapes — production TPU serving style).
* ``VigServeEngine`` — batched ViG image inference with cross-request
  DIGC state: a ``DigcCache`` persists cluster centroids (k-means warm
  starts) and co-node norms across requests, and the streaming-engine
  tile schedule is autotuned once per workload (``core/tuner.py``) and
  served from the tuner's JSON cache afterwards.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy-decoding engine over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = tr.init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Feed the prompt through decode steps (token-by-token prefill;
        simple and cache-layout-identical to decode)."""
        for t, tok in enumerate(req.prompt):
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slot, 0] = tok
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(t)
            )
        self.slot_pos[slot] = len(req.prompt)
        nxt = int(jnp.argmax(logits[slot, -1]))
        req.out_tokens.append(nxt)

    def step(self) -> int:
        """One engine tick: refill slots, one decode step for the whole
        batch. Returns number of active requests."""
        for s in range(self.slots):
            if self.slot_req[s] is None or self.slot_req[s].done:
                if self.queue:
                    req = self.queue.pop(0)
                    self.slot_req[s] = req
                    self._prefill_one(s, req)
        active = [s for s in range(self.slots)
                  if self.slot_req[s] is not None and not self.slot_req[s].done]
        if not active:
            return 0
        # batch decode: every active slot advances one token
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        # NOTE: slots share a scalar position in this engine tick; we use
        # the max position and rely on per-slot masks being equivalent
        # for slots at the same phase. For mixed-length batches the
        # decode step is issued per distinct position group.
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, members in sorted(groups.items()):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            for s in members:
                req = self.slot_req[s]
                nxt = int(jnp.argmax(logits[s, -1]))
                req.out_tokens.append(nxt)
                self.slot_pos[s] += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
        return len(active)

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue or any(
            r is not None and not r.done for r in self.slot_req
        ):
            self.step()
            for s, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    finished.append(r)
                    self.slot_req[s] = None
        return finished


# ---------------------------------------------------------------------------
# ViG image serving


class VigServeEngine:
    """Batched ViG inference with cross-request DIGC state.

    Each ``infer`` call runs one batched forward. Two pieces of
    graph-construction state persist across requests:

    * a ``DigcCache`` — cache-aware builders reuse it through
      ``vig_forward``: the cluster tier warm-starts its per-stage
      k-means from the previous request's centroids (2 Lloyd
      iterations instead of 5 from random init). Only cache-aware
      impls run eagerly (the host-side cache is bypassed under jit by
      design); impls with no reusable state — the exact tiers — serve
      through a jitted forward instead of paying eager dispatch for
      nothing.
    * an autotuned engine schedule — ``warmup()`` tunes the blocked
      tier's (block_n, block_m, merge, fuse_norms) on the model's
      stage-0 DIGC workload via ``core.tuner.DigcTuner`` and bakes the
      winning knobs into the serving spec; later engine instances with
      the same tuner path skip the measurement (JSON cache).
    """

    def __init__(self, cfg, params, *, digc_impl=None, batch: int = 8,
                 autotune: bool = True, tuner_path=None):
        from repro.core.engine import DigcCache
        from repro.models.vig import resolve_digc_spec

        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.spec = resolve_digc_spec(cfg, digc_impl)
        self.cache = DigcCache()
        self.autotune = autotune
        self.tuner_path = tuner_path
        self.tuned = None  # TuneResult once warmed up
        self.requests_served = 0
        self._jit_fwd = None  # (spec, jitted forward) for cache-less impls

    def warmup(self, rng_seed: int = 0):
        """Autotune the engine schedule on the stage-0 DIGC workload."""
        if not self.autotune or self.spec.impl != "blocked":
            return None
        from repro.core.tuner import DigcTuner
        from repro.models.vig import count_digc_work

        work = count_digc_work(self.cfg)[0]  # stage 0 dominates
        rng = np.random.default_rng(rng_seed)
        probe = jnp.asarray(
            rng.standard_normal((self.batch, work["N"], work["D"])),
            jnp.float32,
        )
        # Pyramid stages pool co-nodes (M = N / r^2): tune the real
        # (N, M) workload, not a self-graph stand-in.
        y_probe = None
        if work["M"] != work["N"]:
            y_probe = jnp.asarray(
                rng.standard_normal((self.batch, work["M"], work["D"])),
                jnp.float32,
            )
        spec = self.spec.replace(
            k=work["k"], dilation=work["dilation"],
            block_n=None, block_m=None, merge=None, fuse_norms=None,
        )
        tuner = DigcTuner(self.tuner_path)
        tuned, result = tuner.tune(probe, y_probe, spec=spec)
        self.spec = self.spec.replace(
            block_n=tuned.block_n, block_m=tuned.block_m,
            merge=tuned.merge, fuse_norms=tuned.fuse_norms,
        )
        self.tuned = result
        return result

    def infer(self, images) -> jax.Array:
        """images (B, H, W, C) -> logits (B, num_classes)."""
        from repro.core.builder import get_builder
        from repro.models.vig import vig_forward

        if self.autotune and self.tuned is None and self.spec.impl == "blocked":
            self.warmup()
        if get_builder(self.spec.impl).supports_cache:
            # Eager so the host-side DigcCache engages across requests.
            logits = vig_forward(
                self.params, images, self.cfg,
                digc_impl=self.spec, cache=self.cache,
            )
        else:
            # No reusable construction state: serve jitted.
            if self._jit_fwd is None or self._jit_fwd[0] != self.spec:
                spec = self.spec
                self._jit_fwd = (spec, jax.jit(
                    lambda p, im: vig_forward(p, im, self.cfg, digc_impl=spec)
                ))
            logits = self._jit_fwd[1](self.params, images)
        self.requests_served += int(images.shape[0])
        return logits

    def stats(self) -> dict:
        out = {"requests_served": self.requests_served,
               "digc_cache": self.cache.stats()}
        if self.tuned is not None:
            out["tuned"] = self.tuned.as_dict()
        return out
