"""Batched serving engine: prefill + decode with a slot-based batch
(continuous-batching-lite).

Requests occupy fixed batch slots; finished slots are refilled from the
queue without stalling in-flight decodes. Per-slot lengths are tracked
host-side; the decode step itself is a single jit'd call over the full
slot batch (static shapes — production TPU serving style).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy-decoding engine over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = tr.init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Feed the prompt through decode steps (token-by-token prefill;
        simple and cache-layout-identical to decode)."""
        for t, tok in enumerate(req.prompt):
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slot, 0] = tok
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(t)
            )
        self.slot_pos[slot] = len(req.prompt)
        nxt = int(jnp.argmax(logits[slot, -1]))
        req.out_tokens.append(nxt)

    def step(self) -> int:
        """One engine tick: refill slots, one decode step for the whole
        batch. Returns number of active requests."""
        for s in range(self.slots):
            if self.slot_req[s] is None or self.slot_req[s].done:
                if self.queue:
                    req = self.queue.pop(0)
                    self.slot_req[s] = req
                    self._prefill_one(s, req)
        active = [s for s in range(self.slots)
                  if self.slot_req[s] is not None and not self.slot_req[s].done]
        if not active:
            return 0
        # batch decode: every active slot advances one token
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        # NOTE: slots share a scalar position in this engine tick; we use
        # the max position and rely on per-slot masks being equivalent
        # for slots at the same phase. For mixed-length batches the
        # decode step is issued per distinct position group.
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, members in sorted(groups.items()):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            for s in members:
                req = self.slot_req[s]
                nxt = int(jnp.argmax(logits[s, -1]))
                req.out_tokens.append(nxt)
                self.slot_pos[s] += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
        return len(active)

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue or any(
            r is not None and not r.done for r in self.slot_req
        ):
            self.step()
            for s, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    finished.append(r)
                    self.slot_req[s] = None
        return finished
