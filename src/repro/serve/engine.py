"""Batched serving engines.

* ``ServeEngine`` — LM prefill + decode with a slot-based batch
  (continuous-batching-lite). Requests occupy fixed batch slots;
  finished slots are refilled from the queue without stalling in-flight
  decodes. Per-slot lengths are tracked host-side; the decode step
  itself is a single jit'd call over the full slot batch (static
  shapes — production TPU serving style).
* ``VigServeEngine`` — batched ViG image inference with cross-request
  DIGC state: a ``DigcCache`` persists cluster centroids (k-means warm
  starts) and co-node norms across requests, and the streaming-engine
  tile schedule is autotuned once per workload (``core/tuner.py``) and
  served from the tuner's JSON cache afterwards.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy-decoding engine over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = tr.init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: tr.decode_step(p, c, t, pos, cfg)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Feed the prompt through decode steps (token-by-token prefill;
        simple and cache-layout-identical to decode)."""
        for t, tok in enumerate(req.prompt):
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slot, 0] = tok
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(t)
            )
        self.slot_pos[slot] = len(req.prompt)
        nxt = int(jnp.argmax(logits[slot, -1]))
        req.out_tokens.append(nxt)

    def step(self) -> int:
        """One engine tick: refill slots, one decode step for the whole
        batch. Returns number of active requests."""
        for s in range(self.slots):
            if self.slot_req[s] is None or self.slot_req[s].done:
                if self.queue:
                    req = self.queue.pop(0)
                    self.slot_req[s] = req
                    self._prefill_one(s, req)
        active = [s for s in range(self.slots)
                  if self.slot_req[s] is not None and not self.slot_req[s].done]
        if not active:
            return 0
        # batch decode: every active slot advances one token
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        # NOTE: slots share a scalar position in this engine tick; we use
        # the max position and rely on per-slot masks being equivalent
        # for slots at the same phase. For mixed-length batches the
        # decode step is issued per distinct position group.
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, members in sorted(groups.items()):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            for s in members:
                req = self.slot_req[s]
                nxt = int(jnp.argmax(logits[s, -1]))
                req.out_tokens.append(nxt)
                self.slot_pos[s] += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
        return len(active)

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue or any(
            r is not None and not r.done for r in self.slot_req
        ):
            self.step()
            for s, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    finished.append(r)
                    self.slot_req[s] = None
        return finished


# ---------------------------------------------------------------------------
# ViG image serving


class VigServeEngine:
    """Batched ViG inference with cross-request DIGC state, served
    through a single donated ``jax.jit`` for **every** tier.

    Each ``infer`` call runs one batched forward. Two pieces of
    graph-construction state persist across requests:

    * a functional ``DigcState`` (``core/state.py``) — threaded
      in-and-out of the jitted forward, so stateful builders work
      *inside* the compiled program: the cluster tier warm-starts its
      per-stage k-means from the previous request's centroids (2 Lloyd
      iterations instead of 5, gated by a runtime step counter). The
      state argument is donated: XLA writes the new centroids into the
      old buffers, so steady-state serving allocates nothing for DIGC
      state. One compiled program + state pytree is kept per batch
      size.
    * a ``VigSchedule`` — ``warmup()`` tunes the blocked tier's engine
      knobs (block_n, block_m, merge, fuse_norms) **per pyramid
      stage** via ``core.tuner.DigcTuner.tune_schedule``; later engine
      instances with the same tuner path skip the measurement
      (host-keyed JSON cache).

    ``mode="eager"`` is the legacy compatibility shim: cache-aware
    tiers run eager with the host-side ``DigcCache`` (the PR-2
    behavior), everything else jits statelessly. It exists for parity
    testing and as an escape hatch; the jit path is the serving path.
    """

    def __init__(self, cfg, params, *, digc_impl=None, batch: int = 8,
                 autotune: bool = True, tuner_path=None, mode: str = "jit"):
        from repro.core.engine import DigcCache
        from repro.models.vig import resolve_digc_spec

        from repro.core.tuner import VigSchedule

        if mode not in ("jit", "eager"):
            raise ValueError(f"mode must be 'jit' or 'eager', got {mode!r}")
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.spec = resolve_digc_spec(cfg, digc_impl)
        self.mode = mode
        self.cache = DigcCache()  # engaged by the eager shim only
        self.autotune = autotune
        self.tuner_path = tuner_path
        # A pre-tuned VigSchedule may be passed directly as digc_impl
        # (e.g. tuned offline); warmup() then has nothing to do.
        self.schedule = digc_impl if isinstance(digc_impl, VigSchedule) else None
        self.tuned = None  # per-stage TuneResults once warmed up
        self.requests_served = 0
        self._jit_fwd = None  # eager shim's stateless fallback
        # jit mode: batch size -> [compiled forward, DigcState]
        self._compiled: dict[int, list] = {}

    def warmup(self, rng_seed: int = 0):
        """Autotune a per-stage engine schedule (blocked tier only).

        A no-op when a pre-tuned ``VigSchedule`` was passed at
        construction — warmup never clobbers a user-provided schedule.
        """
        if (not self.autotune or self.spec.impl != "blocked"
                or self.schedule is not None):
            return None
        from repro.core.tuner import DigcTuner
        from repro.models.vig import count_digc_work

        # One workload per stage: pooled stages tune the real (N, M)
        # pair, later pyramid stages get their own cached entries.
        stage_rows: dict[int, dict] = {}
        for row in count_digc_work(self.cfg):
            stage_rows.setdefault(row["stage"], row)
        tuner = DigcTuner(self.tuner_path)
        self.schedule, self.tuned = tuner.tune_schedule(
            [stage_rows[si] for si in sorted(stage_rows)],
            spec=self.spec, batch=self.batch, rng_seed=rng_seed,
        )
        # Forwards compiled before the schedule existed bake the old
        # spec: drop them so the next request recompiles with it.
        self._compiled.clear()
        self._jit_fwd = None
        return self.tuned

    def _impl_choice(self):
        return self.schedule if self.schedule is not None else self.spec

    def _infer_jit(self, images) -> jax.Array:
        from repro.models.vig import init_vig_state, vig_forward

        b = int(images.shape[0])
        if b not in self._compiled:
            choice = self._impl_choice()
            fwd = jax.jit(
                lambda p, im, st: vig_forward(
                    p, im, self.cfg, digc_impl=choice, state=st
                ),
                donate_argnums=(2,),
            )
            self._compiled[b] = [fwd, init_vig_state(self.cfg, b, choice)]
        fwd, state = self._compiled[b]
        logits, new_state = fwd(self.params, images, state)
        self._compiled[b][1] = new_state
        return logits

    def _infer_eager_shim(self, images) -> jax.Array:
        from repro.core.builder import get_builder
        from repro.models.vig import vig_forward

        if get_builder(self.spec.impl).supports_cache:
            # Eager so the host-side DigcCache engages across requests.
            return vig_forward(
                self.params, images, self.cfg,
                digc_impl=self.spec, cache=self.cache,
            )
        # No reusable construction state: serve jitted, stateless —
        # still through the tuned per-stage schedule when one exists,
        # so eager vs jit mode differ only in the state threading.
        choice = self._impl_choice()
        if self._jit_fwd is None or self._jit_fwd[0] is not choice:
            self._jit_fwd = (choice, jax.jit(
                lambda p, im: vig_forward(p, im, self.cfg, digc_impl=choice)
            ))
        return self._jit_fwd[1](self.params, images)

    def infer(self, images) -> jax.Array:
        """images (B, H, W, C) -> logits (B, num_classes)."""
        if (self.autotune and self.tuned is None and self.schedule is None
                and self.spec.impl == "blocked"):
            self.warmup()
        if self.mode == "eager":
            logits = self._infer_eager_shim(images)
        else:
            logits = self._infer_jit(images)
        self.requests_served += int(images.shape[0])
        return logits

    def state_steps(self) -> dict:
        """Per-batch-size view of the functional state's step counters."""
        return {b: c[1].steps() for b, c in self._compiled.items()}

    def stats(self) -> dict:
        out = {"requests_served": self.requests_served, "mode": self.mode,
               "digc_cache": self.cache.stats(),
               "digc_state": self.state_steps()}
        if self.schedule is not None:
            out["schedule"] = self.schedule.describe()
        if self.tuned is not None:
            out["tuned"] = [r.as_dict() for r in self.tuned]
        return out
