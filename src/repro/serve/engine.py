"""Batched serving engines.

* ``ServeEngine`` — LM prefill + decode with a slot-based batch
  (continuous-batching-lite). Requests occupy fixed batch slots;
  finished slots are refilled from the queue without stalling in-flight
  decodes. Per-slot lengths are tracked host-side; a decode tick is a
  **single** jit'd call over the full slot batch even when slot
  lengths differ (static shapes — production TPU serving style):
  ``decode_step`` takes the per-slot position *vector*, each row
  writing its cache at its own position. Every cache write still
  carries an explicit per-slot commit mask, so prefilling one slot can
  never clobber an in-flight neighbor's cache rows.
* ``VigServeEngine`` — multi-tenant ViG image serving with
  cross-request DIGC state (DESIGN.md §9): a host-side request queue
  feeds fixed slots, each engine tick pads the active slots to a small
  static **bucket** (default {1, 2, 4, 8}) and serves it through one
  donated jit program per bucket — at most |bucket set| compiled
  programs no matter how ragged the arrival stream. Per-slot
  ``DigcState`` rows (cluster centroids, gallery norms, per-row step
  counters) are gathered into the bucket batch and scattered back for
  live lanes only, so a tenant's warm start follows it across buckets
  and padding lanes never touch live state.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultError, FaultInfo
from repro.models.config import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def _merge_cache_rows(new, old, keep, cfg: ModelConfig):
    """Commit ``new`` cache rows only where ``keep`` (B,) is True.

    ``decode_step`` writes its k/v (or recurrent state) for **every**
    batch row — each at its own per-slot position now, but idle and
    draining slots still decode garbage tokens — so a per-slot engine
    must mask the commit, or inactive slots get garbage written into
    their caches. Leaves carry the batch axis at 1 when layer-stacked
    (the scan layout, (L, B, ...)) and at 0 for the unstacked hybrid
    remainder entries ((B, ...)).
    """

    def merge(axis):
        def f(n, o):
            shape = [1] * n.ndim
            shape[axis] = keep.shape[0]
            return jnp.where(keep.reshape(shape), n, o)

        return f

    if cfg.family == "hybrid":
        return {
            "groups": jax.tree_util.tree_map(
                merge(1), new["groups"], old["groups"]
            ),
            "rem": jax.tree_util.tree_map(merge(0), new["rem"], old["rem"]),
        }
    return jax.tree_util.tree_map(merge(1), new, old)


class ServeEngine:
    """Greedy-decoding engine over the functional model API."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = tr.init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.decode_calls = 0  # observability: jitted steps issued

        def _decode(p, c, t, pos, keep):
            logits, new_c = tr.decode_step(p, c, t, pos, cfg)
            return logits, _merge_cache_rows(new_c, c, keep, cfg)

        # The cache is donated: the commit-mask merge rewrites every
        # leaf, and the caller always replaces self.cache with the
        # result, so XLA may update the old buffers in place instead of
        # doubling the KV cache's memory traffic each step.
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt (prefill needs at "
                "least one token to produce a next-token distribution)"
            )
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1 "
                "(prefill always emits the first token)"
            )
        self.queue.append(req)

    def _step_decode(self, tokens, pos, members: list[int]):
        """One jitted decode committing only ``members``' cache rows.
        ``pos`` is the (slots,) per-slot position vector — a single
        call serves arbitrarily mixed-length slots (DESIGN.md §9)."""
        keep = np.zeros(self.slots, bool)
        keep[members] = True
        self.decode_calls += 1
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos, dtype=jnp.int32), jnp.asarray(keep),
        )
        return logits

    def _prefill_one(self, slot: int, req: Request):
        """Feed the prompt through decode steps (token-by-token prefill;
        simple and cache-layout-identical to decode). Only this slot's
        cache rows are committed — other slots may be mid-decode at
        overlapping positions."""
        for t, tok in enumerate(req.prompt):
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slot, 0] = tok
            logits = self._step_decode(
                tokens, np.full(self.slots, t, np.int32), [slot]
            )
        self.slot_pos[slot] = len(req.prompt)
        nxt = int(jnp.argmax(logits[slot, -1]))
        req.out_tokens.append(nxt)
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True  # budget met by the prefill token itself

    def step(self) -> int:
        """One engine tick: refill slots, one decode step for the whole
        batch. Returns number of active requests."""
        for s in range(self.slots):
            if self.slot_req[s] is None or self.slot_req[s].done:
                if self.queue:
                    req = self.queue.pop(0)
                    self.slot_req[s] = req
                    self._prefill_one(s, req)
        active = [s for s in range(self.slots)
                  if self.slot_req[s] is not None and not self.slot_req[s].done]
        if not active:
            return 0
        # batch decode: every active slot advances one token
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        # decode_step takes the per-slot position vector, so a tick over
        # arbitrarily mixed-length slots is ONE jitted call — each row
        # writes its cache at (and attends up to) its own position, and
        # the commit mask still restricts the write to the active slots
        # (call count pinned in the serve tests; the per-position-group
        # loop this replaced issued one call per distinct length).
        logits = self._step_decode(tokens, self.slot_pos.copy(), active)
        for s in active:
            req = self.slot_req[s]
            nxt = int(jnp.argmax(logits[s, -1]))
            req.out_tokens.append(nxt)
            self.slot_pos[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
        return len(active)

    def run(self) -> list[Request]:
        finished: list[Request] = []
        while self.queue or any(
            r is not None and not r.done for r in self.slot_req
        ):
            self.step()
            for s, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    finished.append(r)
                    self.slot_req[s] = None
        return finished


# ---------------------------------------------------------------------------
# ViG image serving


@dataclasses.dataclass
class VigRequest:
    """One image inference request.

    ``tenant`` names the state stream the request belongs to:
    consecutive requests of one tenant share a serving slot, so the
    cluster tier warm-starts request N+1's k-means from request N's
    centroids — but only within the tenant. ``tenant=None`` marks a
    one-shot anonymous request (always a cold slot).

    A quarantined request completes with ``done=True``,
    ``logits=None`` and the detected fault in ``fault`` (DESIGN.md
    §11) — failure is a typed per-request outcome, never an engine
    crash.

    ``tclass`` names the request's tenant *class* — the key into the
    engine's per-class ``slo_ms`` dict when the SLO-bounded admission
    queue is armed (DESIGN.md §14). With a scalar ``slo_ms`` (or the
    default synchronous engine) the class is inert.
    """

    uid: int
    image: np.ndarray  # (H, W, C) float
    tenant: Optional[Any] = None
    logits: Optional[np.ndarray] = None
    done: bool = False
    fault: Optional[FaultInfo] = None
    tclass: str = "default"


DEFAULT_BUCKETS = (1, 2, 4, 8)


class VigServeEngine:
    """Multi-tenant bucketed ViG inference with cross-request DIGC
    state, served through a single donated ``jax.jit`` per bucket.

    **The request path** (``submit``/``step``/``run``) is the
    multi-tenant engine (DESIGN.md §9): requests occupy fixed slots
    (``slots = max(buckets)``), each tick gathers the active slots,
    pads them to the smallest bucket that fits, and runs that bucket's
    compiled program. State is per **slot**, not per bucket: the
    canonical ``DigcState`` keeps one row per slot (with per-row step
    counters, ``init_vig_state(per_slot=True)``); each tick slices the
    active rows into the bucket batch and scatters the live lanes back,
    so

    * a tenant's warm start follows it even when the serving bucket
      changes tick to tick,
    * padding lanes (which replicate a live lane so their compute is
      well-conditioned) are never scattered back — they cannot clobber
      live state,
    * a slot reassigned to a new tenant is row-reset first — warm state
      never leaks between tenants.

    ``buckets=None`` disables padding: every tick compiles/serves at
    the exact active-batch size (the PR-3 one-program-per-batch-size
    behavior, kept as the benchmark baseline).

    **The multi-resolution lattice** (``image_sizes=``, DESIGN.md
    §13): the bucket grid gains an N dimension — each configured image
    size is an N-bucket whose patch count sizes its own per-slot state
    (``_slot_states[size]``) and programs. Admission resolves every
    request to the smallest cell that fits: an exact configured size
    serves unmasked (its program trace is byte-identical to a
    single-size engine's), a ragged size is zero-padded up to its cell
    with the pad nodes BIG-norm-masked out of every DIGC top-k and the
    mean pooling (single-stage r=1 models only — typed submit error
    otherwise). A tick serves ONE (size, pad-variant) cell — the
    head-of-queue's — so a mixed 224/448/800 trace compiles at most
    |buckets| x |image_sizes| programs and every served row still
    matches its own same-resolution B=1 replay bit-for-bit on CPU.
    Without an explicit ``image_sizes`` the engine is single-size and
    keeps the strict exact-shape submit contract.

    **Sharded mode** (``mesh=``, DESIGN.md §10): the engine goes
    mesh-native — the construction spec is threaded with the mesh
    (``mesh_axis`` names the co-node ring axis, ``mesh_batch_axis``
    optionally shards bucket rows data-parallel), the canonical slot
    state is allocated with matching ``PartitionSpec``s
    (``init_vig_state(mesh=)``), and every bucket program runs the
    distributed builder's ``shard_map`` inside the same donated jit.
    The slot/bucket/warm-gating lifecycle is unchanged: a ragged
    multi-tenant trace on an N-device mesh still compiles at most
    |bucket set| programs and each row still matches its own B=1
    replay bit-for-bit on CPU.

    **LRU state parking** (``park_capacity``, DESIGN.md §10): when a
    tenant is LRU-evicted from its slot, its state rows are copied to
    host memory (bounded by ``park_capacity`` tenants, oldest parked
    copy dropped first) and restored on re-admit — hot tenants survive
    slot churn warm instead of re-admitting cold. ``release()`` (an
    explicit disconnect) still drops state entirely, and
    ``park_capacity=0`` restores the PR-4 evict-means-cold behavior.

    **SLO-bounded admission scheduling** (``slo_ms``/``clock``/
    ``prefetch``/``bucket_cap``, DESIGN.md §14): a positive ``slo_ms``
    (scalar, or per tenant class via ``{class: ms}`` keyed by
    ``VigRequest.tclass``) arms the async admission queue — a tick
    dispatches a (size, masked) cell only when its earliest member
    deadline arrives or it holds a full slot width of tenants, so
    singleton arrivals coalesce into well-filled ticks instead of each
    padding up to a bucket. ``clock`` injects a deterministic time
    source (``serve.sched.VirtualClock``); ``buckets="auto"`` resolves
    the bucket set from the host tuner cache (the arrival-histogram
    optimizer — ``retune_buckets()`` re-derives and persists it from
    the live-lane histogram a served trace accumulated, capped at
    ``bucket_cap`` programs); ``prefetch`` lets the queue issue parked
    tenants' host->device row uploads ahead of their admitting tick.
    ``slo_ms=0`` (the default) is the legacy synchronous engine,
    byte-for-byte.

    **Fault tolerance** (``guards``/``fault_plan``/``deadline_ms``,
    DESIGN.md §11): every picked lane passes an admission finiteness
    screen and per-row state checks (integrity fingerprints + state
    finiteness) before reaching a compiled program; a failing lane is
    quarantined (request fails with a typed ``FaultInfo``, its slot
    cold-resets) or recovered (silent corruption → cold re-serve)
    without perturbing co-batched tenants. Program builds and parking
    restores retry with backoff; persistent build failures and
    repeated deadline misses walk the degradation ladder
    (``core.builder.fallback_chain``). ``fault_plan`` injects
    failures at the named sites for testing; ``guards=False`` keeps
    the unguarded PR-6 fast path.

    **The direct path** (``infer``) runs one batched forward per call
    with one compiled program + state per exact batch size — the PR-3
    API, still the right call for offline fixed-batch workloads.

    Two pieces of graph-construction state persist across requests:

    * a functional ``DigcState`` (``core/state.py``) — threaded
      in-and-out of the jitted forward, so stateful builders work
      *inside* the compiled program: the cluster tier warm-starts its
      per-stage k-means from the previous request's centroids (2 Lloyd
      iterations instead of 5, gated by a runtime step counter — per
      slot row on the request path). The state argument is donated:
      XLA writes the new centroids into the old buffers, so
      steady-state serving allocates nothing for DIGC state.
    * a ``VigSchedule`` — ``warmup()`` tunes the blocked tier's engine
      knobs (block_n, block_m, merge, fuse_norms) **per pyramid
      stage** via ``core.tuner.DigcTuner.tune_schedule``; the request
      path resolves the schedule **per bucket** (the workload key
      includes the batch size — a B=8 tile is not a B=1 tile). Later
      engine instances with the same tuner path skip the measurement
      (host-keyed JSON cache).

    ``mode="eager"`` is the legacy compatibility shim: cache-aware
    tiers run eager with the host-side ``DigcCache`` (the PR-2
    behavior), everything else jits statelessly. It exists for parity
    testing and as an escape hatch; the jit path is the serving path
    and the only one the multi-tenant request API supports.
    """

    def __init__(self, cfg, params, *, digc_impl=None, batch: int = 8,
                 autotune: bool = True, tuner_path=None, mode: str = "jit",
                 buckets: Optional[tuple] = DEFAULT_BUCKETS,
                 image_sizes: Optional[tuple] = None,
                 on_compile: Optional[Callable[[int], None]] = None,
                 mesh=None, mesh_axis: str = "data",
                 mesh_batch_axis: Optional[str] = None,
                 park_capacity: int = 8,
                 fault_plan=None, guards: bool = True,
                 deadline_ms: Optional[float] = None,
                 deadline_strikes: int = 2,
                 retry_attempts: int = 3, retry_backoff: float = 0.02,
                 slo_ms=0.0, clock: Optional[Callable[[], float]] = None,
                 prefetch: bool = True, bucket_cap: int = 4):
        from repro.core.builder import get_builder
        from repro.core.engine import DigcCache
        from repro.models.vig import resolve_digc_spec, vig_stage_plans

        from repro.core.tuner import VigSchedule

        if mode not in ("jit", "eager"):
            raise ValueError(f"mode must be 'jit' or 'eager', got {mode!r}")
        # buckets="auto" defers the choice to the host tuner cache (the
        # arrival-histogram bucket-set optimizer, DESIGN.md §14); it is
        # materialized below, after image_sizes resolve, so the lookup
        # can key on the full serving shape.
        self._auto_buckets = isinstance(buckets, str)
        if self._auto_buckets and buckets != "auto":
            raise ValueError(
                f"buckets must be a tuple, None, or 'auto': {buckets!r}")
        if buckets is not None and not self._auto_buckets:
            buckets = tuple(sorted(set(int(b) for b in buckets)))
            if not buckets or buckets[0] < 1:
                raise ValueError(f"buckets must be positive ints: {buckets!r}")
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.spec = resolve_digc_spec(cfg, digc_impl)
        self.mode = mode
        # -- multi-resolution lattice (DESIGN.md §13): the bucket grid
        # gains an N dimension. Each configured image size is an
        # N-bucket (N = (size/patch)^2 patch nodes); admission resolves
        # every request to the smallest size that fits and the engine
        # serves at most |buckets| x |image_sizes| compiled programs.
        # Each size's pyramid is screened here, at construction — an
        # odd-grid config must fail with the typed VigGridError naming
        # the stage and grid, not three ticks later inside a jit trace.
        # Lattice admission (ragged sizes padded up to a cell) is
        # opt-in via an explicit image_sizes; the default engine keeps
        # the strict exact-shape submit contract.
        self._lattice = image_sizes is not None
        if image_sizes is None:
            image_sizes = (cfg.image_size,)
        sizes = tuple(sorted(set(int(s) for s in image_sizes)))
        if not sizes or sizes[0] < cfg.patch:
            raise ValueError(
                f"image_sizes must be >= patch={cfg.patch}: {image_sizes!r}"
            )
        for s in sizes:
            if s % cfg.patch:
                raise ValueError(
                    f"image_sizes: {s} is not divisible by the model "
                    f"patch size {cfg.patch}"
                )
            vig_stage_plans(cfg, grid=s // cfg.patch)  # VigGridError here
        self.image_sizes = sizes
        self.bucket_cap = int(bucket_cap)
        if self._auto_buckets:
            buckets = self._auto_bucket_set(batch, tuner_path)
        # -- sharded mode (DESIGN.md §10): thread the mesh into the
        # construction spec, so every bucket program and the slot state
        # allocation see the same placement. mesh_axis names the
        # co-node ring axis; mesh_batch_axis optionally shards the
        # bucket rows data-parallel (every bucket must divide by it).
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.mesh_batch_axis = mesh_batch_axis
        if mesh is not None:
            if isinstance(digc_impl, VigSchedule):
                raise ValueError(
                    "mesh= applies one placement to every stage; a "
                    "pre-tuned VigSchedule carries per-stage specs — "
                    "set mesh/axis_name on its stage specs instead"
                )
            builder = get_builder(self.spec.impl)
            if not {"mesh", "axis_name"} <= builder.knobs:
                raise ValueError(
                    f"DIGC impl {self.spec.impl!r} is not mesh-native "
                    "(no mesh/axis_name knobs); sharded serving needs "
                    "a distributed builder (ring)"
                )
            if mesh_batch_axis is not None:
                if buckets is None:
                    # The exact-size policy serves every active count
                    # 1..slots; most of those cannot divide a >1-device
                    # batch axis, and failing mid-tick (after admission
                    # mutated slot state) is worse than refusing here.
                    raise ValueError(
                        "mesh_batch_axis requires a bucket set: the "
                        "exact-size policy (buckets=None) serves "
                        "arbitrary batch sizes, which cannot all "
                        "divide a sharded batch axis"
                    )
                dsz = int(mesh.shape[mesh_batch_axis])
                bad = [v for v in buckets if v < dsz]
                if bad:
                    # A bucket below the axis size cannot give every
                    # device a live row even after padding — that is a
                    # config error. Buckets that merely fail to *divide*
                    # the axis are fine: step() pads the tick to the
                    # next axis multiple (padding lanes replicate lane
                    # 0, exactly like bucket padding) instead of
                    # refusing at construction.
                    raise ValueError(
                        f"bucket sizes {bad} are smaller than the "
                        f"{mesh_batch_axis!r} mesh axis ({dsz} devices); "
                        "configure buckets >= the axis size (non-"
                        "dividing buckets are padded per tick)"
                    )
            self.spec = self.spec.replace(
                mesh=mesh, axis_name=mesh_axis, batch_axis=mesh_batch_axis
            )
        self.cache = DigcCache()  # engaged by the eager shim only
        self.autotune = autotune
        self.tuner_path = tuner_path
        # A pre-tuned VigSchedule may be passed directly as digc_impl
        # (e.g. tuned offline); warmup() then has nothing to do. Only a
        # *user-provided* schedule applies to every bucket — a
        # warmup()-tuned one is a measurement at self.batch and must
        # not leak into other buckets' programs (_bucket_choice).
        self._user_schedule = isinstance(digc_impl, VigSchedule)
        self.schedule = digc_impl if self._user_schedule else None
        self.tuned = None  # per-stage TuneResults once warmed up
        self.requests_served = 0
        self._jit_fwd = None  # eager shim's stateless fallback
        # jit mode, direct path: batch size -> [compiled forward, DigcState]
        self._compiled: dict[int, list] = {}

        # -- multi-tenant request path (jit mode) -----------------------
        self.buckets = buckets
        self.slots = max(buckets) if buckets is not None else batch
        self.on_compile = on_compile  # compile-counter hook (tests/ops)
        self.compile_count = 0  # programs built on the request path
        self.queue: list[VigRequest] = []
        self.slot_tenant: list[Optional[Any]] = [None] * self.slots
        self._tenant_slot: dict[Any, int] = {}
        self._slot_last_tick = [0] * self.slots
        self._tick = 0
        # canonical per-slot DigcState, one per N-bucket (lazy): row
        # buffers are sized by the size's stage plans, and the §9-§12
        # row lifecycle (gather/scatter, parking, quarantine, cached
        # graphs) is keyed (slot, N-bucket). ``_slot_state`` (below)
        # aliases the primary size — single-size engines see the
        # pre-multires attribute unchanged.
        self._slot_states: dict[int, Any] = {}  # size -> DigcState
        # programs/schedules key by ``_program_key``: the bare bucket
        # for a single-size engine (the pre-multires contract the
        # on_compile tests pin), (size, bucket) on the lattice, plus a
        # "pad" tag for the mask-threading variant.
        self._programs: dict[Any, Callable] = {}  # cell key -> compiled fwd
        self._bucket_schedules: dict[Any, Any] = {}
        self._bucket_tuned: dict[Any, list] = {}
        self.bucket_ticks: dict[int, int] = {}
        self.cell_ticks: dict[tuple, int] = {}  # (size, bucket) -> ticks
        # -- LRU state parking (DESIGN.md §10): host-side copies of
        # evicted tenants' state rows, restored on re-admit so hot
        # tenants survive slot churn warm. Bounded; park_capacity=0
        # disables (evictees re-admit cold, the PR-4 behavior).
        self.park_capacity = int(park_capacity)
        self._parked: "dict[Any, Any]" = {}  # tenant -> host DigcState rows
        self.park_hits = 0
        self.park_evictions = 0
        # -- SLO-bounded async admission (DESIGN.md §14) ----------------
        # A positive slo (scalar ms, or {tenant class: ms}) arms the
        # scheduler: submit() only enqueues, and a tick dispatches a
        # (size, masked) cell when its earliest member deadline arrives
        # or it can fill the full slot width — coalescing singleton
        # arrivals into well-filled ticks instead of padding them up.
        # slo_ms=0 (the default) keeps the legacy bind-on-next-tick
        # admission byte-for-byte: _select_cell short-circuits to the
        # head-of-queue cell and nothing else in the tick changes.
        self._slo_ms = (dict(slo_ms) if isinstance(slo_ms, dict)
                        else float(slo_ms))
        _slo_vals = (self._slo_ms.values()
                     if isinstance(self._slo_ms, dict) else [self._slo_ms])
        if any(float(v) < 0 for v in _slo_vals):
            raise ValueError(f"slo_ms must be >= 0: {slo_ms!r}")
        self._sched_active = any(float(v) > 0 for v in _slo_vals)
        self._clock = clock  # None = wall time; a VirtualClock in tests
        self._enq_seq = 0  # submit-order stamp (per-tenant FIFO anchor)
        self._next_deadline: Optional[float] = None
        self.deferrals = 0  # ticks that waited instead of dispatching
        # padding-waste accounting (stats(); feeds the bucket-set
        # optimizer): padded_lanes == sum over ticks of (width - live).
        self.live_lanes = 0
        self.padded_lanes = 0
        self.lane_hist: dict[tuple, int] = {}  # (size, live) -> ticks
        # -- prefetched parking restore (DESIGN.md §14): the queue
        # names who the next tick admits, so parked tenants' host rows
        # start their host->device upload ahead of the admitting tick.
        self._prefetch = bool(prefetch)
        self._park_prefetch: dict[Any, tuple] = {}  # tenant -> (host, dev)
        self.prefetch_issued = 0
        self.prefetch_hits = 0
        # last-tick observability (asserted by the property tests)
        self.last_lanes: list[int] = []
        self.last_resets: list[int] = []
        self.last_restores: list[int] = []
        self.last_bucket: Optional[int] = None
        self.last_cell: Optional[tuple] = None  # (size, bucket) last tick
        # -- fault tolerance (DESIGN.md §11) ----------------------------
        # fault_plan injects failures at named sites (tests/chaos);
        # guards=True arms the detection/recovery machinery — per-lane
        # finiteness screening, state-integrity fingerprints, the
        # deadline budget. guards=False keeps the PR-6 fast path (the
        # serve/guarded_* bench rows measure the difference).
        self.fault_plan = fault_plan
        self.guards = bool(guards)
        self.deadline_ms = deadline_ms
        self.deadline_strikes = int(deadline_strikes)
        self.retry_attempts = int(retry_attempts)
        self.retry_backoff = float(retry_backoff)
        self.quarantines = 0
        self.state_resets = 0
        self.deadline_misses = 0
        self.park_losses = 0
        self.retries = 0
        self.requests_failed = 0
        self.fallback_level = 0  # rungs descended on the ladder
        self.fault_log: list[FaultInfo] = []  # detected (not injected)
        self.last_quarantined: list[int] = []  # slots, last tick
        self._row_tokens: dict[str, dict[int, int]] = {}
        self._consecutive_misses = 0
        self._program_ticks: dict[Any, int] = {}  # cell key -> ticks served
        # -- stale-graph serving (DESIGN.md §12) ------------------------
        # Lane-granular reuse accounting, reconstructed host-side from
        # graph_age deltas after each tick (age resets to 0 on rebuild,
        # grows monotonically under reuse) — no extra device sync, the
        # logits pull already closed the tick.
        self.graph_reuses = 0
        self.graph_rebuilds = 0
        self._drift_sum = 0.0
        self._drift_n = 0
        self.last_drift: dict[str, float] = {}  # entry key -> mean drift

    # -- multi-resolution lattice plumbing (DESIGN.md §13) --------------

    @property
    def _slot_state(self):
        """The primary size's canonical slot state — the pre-multires
        attribute, kept as an alias so single-size callers (and the
        serve tests) keep reading/assigning one state object."""
        return self._slot_states.get(self.image_sizes[0])

    @_slot_state.setter
    def _slot_state(self, value):
        if value is None:
            self._slot_states.pop(self.image_sizes[0], None)
        else:
            self._slot_states[self.image_sizes[0]] = value

    def _multi_size(self) -> bool:
        return len(self.image_sizes) > 1

    def _req_size(self, req) -> int:
        return getattr(req, "_serve_size", self.image_sizes[0])

    def _req_mask(self, req):
        return getattr(req, "_serve_mask", None)

    def _program_key(self, bucket: int, size: Optional[int] = None,
                     masked: bool = False):
        """Cell key for programs/ticks/on_compile: the bare bucket on a
        single-size engine (the pre-multires contract), (size, bucket)
        on the lattice, with a "pad" tag for the mask variant."""
        size = self.image_sizes[0] if size is None else size
        if masked:
            return (size, bucket, "pad")
        if not self._multi_size():
            return bucket
        return (size, bucket)

    def _tick_width(self, bucket: int) -> int:
        """Static batch width of one tick's program: the bucket, padded
        up to the next ``mesh_batch_axis`` multiple when the rows are
        sharded data-parallel — a non-dividing bucket pads its tick
        (replicating lane 0) instead of failing at construction."""
        if self.mesh is None or self.mesh_batch_axis is None:
            return bucket
        dsz = int(self.mesh.shape[self.mesh_batch_axis])
        return -(-bucket // dsz) * dsz

    def _reset_rows_all(self, slots) -> None:
        """Cold-reset ``slots``' rows in every allocated N-bucket state
        (quarantine/release/admission: a slot's occupancy changes for
        all resolutions at once, so stale warm rows at *any* size must
        not survive into the next tenant)."""
        for size, st in self._slot_states.items():
            self._slot_states[size] = st.reset_rows(list(slots))
        self._refresh_tokens(slots)

    # -- SLO-bounded admission scheduling (DESIGN.md §14) ---------------

    def _now(self) -> float:
        """Scheduler time: the injected clock (a ``VirtualClock`` or
        any zero-arg callable) or wall ``time.monotonic``."""
        if self._clock is None:
            return time.monotonic()
        now = getattr(self._clock, "now", None)
        return now() if now is not None else self._clock()

    def _slo_s(self, req) -> float:
        """The request's admission budget in seconds: its tenant
        class's entry in the slo_ms dict (falling back to "default",
        then 0 = dispatch-now), or the scalar slo."""
        if isinstance(self._slo_ms, dict):
            ms = self._slo_ms.get(req.tclass, self._slo_ms.get("default", 0.0))
        else:
            ms = self._slo_ms
        return float(ms) / 1e3

    def _tkey(self, req):
        """Slot-identity of a request: its tenant, or a unique one-shot
        key for anonymous requests."""
        return req.tenant if req.tenant is not None else ("req", req.uid)

    def _cell_of(self, req) -> tuple:
        """The (size, masked) lattice cell a request resolved to."""
        return (self._req_size(req), self._req_mask(req) is not None)

    def _enqueue(self, req: VigRequest) -> None:
        """Admit a validated request to the queue, stamped with its
        arrival time and submit order (the deadline and FIFO anchors),
        and give the parking prefetcher a look at the new queue."""
        req._enq_t = self._now()
        req._enq_seq = self._enq_seq
        self._enq_seq += 1
        self.queue.append(req)
        self._prefetch_parked()

    def _select_cell(self, peek: bool = False):
        """Choose the (size, masked) cell the next tick serves and its
        eligible requests, or defer.

        Legacy (``slo_ms=0``): the head-of-queue's cell and every
        queued request that resolved to it — the bind-on-next-tick
        admission, unchanged byte-for-byte.

        Scheduler (any positive slo): each request carries a deadline
        (arrival + its class budget); a tenant's *effective* deadline
        is the min over all its queued requests, attributed to its
        head request (a tight-slo request queued behind a lax one
        pulls the head forward — FIFO never starves a deadline). A
        cell is **ripe** when its earliest member deadline has arrived
        or it holds a full slot width of distinct tenants; the ripe
        cell with the earliest (deadline, arrival) dispatches, and
        only tenant *head* requests are eligible, so per-tenant FIFO
        holds even across cells. With no ripe cell the tick defers:
        ``_next_deadline`` records when the earliest cell ripens
        (``run()`` advances the clock to it — a VirtualClock jumps,
        the wall clock sleeps).

        ``peek=True`` never defers — it returns the cell that WILL
        dispatch at the next deadline, which is what the parking
        prefetcher keys its uploads on."""
        if not self.queue:
            return None, None
        if not self._sched_active:
            cell = self._cell_of(self.queue[0])
            return cell, [r for r in self.queue if self._cell_of(r) == cell]
        heads: dict[Any, VigRequest] = {}
        eff: dict[Any, float] = {}
        for r in self.queue:
            tk = self._tkey(r)
            heads.setdefault(tk, r)
            dl = r._enq_t + self._slo_s(r)
            eff[tk] = min(eff.get(tk, dl), dl)
        cells: dict[tuple, list] = {}  # cell -> [deadline, tenants, seq]
        for tk, head in heads.items():
            info = cells.setdefault(self._cell_of(head),
                                    [float("inf"), 0, head._enq_seq])
            info[0] = min(info[0], eff[tk])
            info[1] += 1
            info[2] = min(info[2], head._enq_seq)
        now = self._now()
        ripe = [c for c, (dl, nt, _) in cells.items()
                if now >= dl - 1e-9 or nt >= self.slots]
        if not ripe:
            if not peek:
                self._next_deadline = min(i[0] for i in cells.values())
                return None, None
            ripe = list(cells)
        cell = min(ripe, key=lambda c: (cells[c][0], cells[c][2]))
        head_ids = {id(r) for r in heads.values()}
        eligible = [r for r in self.queue
                    if id(r) in head_ids and self._cell_of(r) == cell]
        if not peek:
            self._next_deadline = None
        return cell, eligible

    def next_deadline(self) -> Optional[float]:
        """The earliest admission deadline among queued requests, or
        None (empty queue, or scheduler not armed). A serving loop
        wakes at this time even with no new arrivals — replaying a
        trace, ``serve.sched.replay`` advances the clock here between
        arrivals so no queued cell overshoots its SLO."""
        if not self._sched_active or not self.queue:
            return None
        return min(r._enq_t + self._slo_s(r) for r in self.queue)

    def _advance_to_deadline(self) -> None:
        """Move time to the next admission deadline after a deferred
        tick: a clock with ``advance_to`` (VirtualClock) jumps —
        deterministic tests/benches; the wall clock sleeps the
        remainder."""
        target = self._next_deadline
        if target is None:
            return
        adv = getattr(self._clock, "advance_to", None)
        if adv is not None:
            adv(target)
            return
        delta = target - self._now()
        if delta > 0:
            time.sleep(min(delta, 60.0))

    def _prefetch_parked(self) -> None:
        """Issue the next tick's parking restores ahead of time: the
        admission queue names who the next tick admits, so a parked,
        unslotted tenant among the predicted admits starts its
        host->device row upload (``prefetch_park_rows``) now, off the
        admitting tick's critical path. Purely a placement hint —
        ``_unpark`` still passes the ``park.restore`` fault site and
        the §11 bind-time integrity screens, and consumes the device
        copy only when the restored host object is the very one the
        upload was issued from."""
        if not self._prefetch or not self._parked or not self.queue:
            return
        from repro.core.state import prefetch_park_rows

        _, eligible = self._select_cell(peek=True)
        for req in (eligible or [])[: self.slots]:
            tk = self._tkey(req)
            if (tk in self._parked and tk not in self._tenant_slot
                    and tk not in self._park_prefetch):
                host = self._parked[tk]
                self._park_prefetch[tk] = (host, prefetch_park_rows(host))
                self.prefetch_issued += 1

    def retune_buckets(self, max_programs: Optional[int] = None,
                       force: bool = True) -> tuple:
        """Re-derive the bucket set from the live-lane histogram this
        engine's served trace accumulated (``lane_hist``), via the
        arrival-histogram optimizer in ``core.tuner`` — persisted per
        host in the tuner cache exactly like ``VigSchedule``s, so the
        next engine constructed with ``buckets="auto"`` and the same
        tuner path starts on the optimized set. Takes effect live:
        programs for dropped buckets stay compiled but ``bucket_for``
        never picks them again; new buckets compile lazily on first
        use."""
        from repro.core.tuner import DigcTuner, optimal_bucket_set

        hist: dict[int, dict[int, int]] = {}
        for (sz, live), ticks in self.lane_hist.items():
            per = hist.setdefault(sz, {})
            per[live] = per.get(live, 0) + ticks
        cap = self.bucket_cap if max_programs is None else int(max_programs)
        costs = {s: (s // self.cfg.patch) ** 2 for s in self.image_sizes}
        if self.tuner_path is not None:
            new = DigcTuner(self.tuner_path).tune_bucket_set(
                hist, slots=self.slots, max_programs=cap, costs=costs,
                sizes=self.image_sizes, force=force)
        else:
            new = optimal_bucket_set(hist, slots=self.slots,
                                     max_programs=cap, costs=costs)
        self.buckets = new
        return new

    def _auto_bucket_set(self, slots: int, tuner_path) -> tuple:
        """Materialize ``buckets="auto"``: the host-persisted bucket
        set for this (slots, sizes, cap) serving shape when the tuner
        cache holds one (a previous trace's ``retune_buckets``), else
        the default ladder capped at ``slots``."""
        if tuner_path is not None:
            from repro.core.tuner import DigcTuner

            found = DigcTuner(tuner_path).lookup_bucket_set(
                slots=slots, sizes=self.image_sizes,
                max_programs=self.bucket_cap)
            if found is not None:
                return found
        return tuple(b for b in DEFAULT_BUCKETS if b < slots) + (slots,)

    # -- tuning ---------------------------------------------------------

    def _stage_rows(self, size: Optional[int] = None) -> list[dict]:
        """One workload row per stage: pooled stages tune the real
        (N, M) pair, later pyramid stages get their own entries.
        ``size`` selects the N-bucket (default: the native pyramid) —
        the rows carry that bucket's (N, M, k), so the tuner's workload
        key covers both lattice dimensions."""
        from repro.models.vig import count_digc_work

        grid = None if size is None else size // self.cfg.patch
        rows: dict[int, dict] = {}
        for row in count_digc_work(self.cfg, grid=grid):
            rows.setdefault(row["stage"], row)
        return [rows[si] for si in sorted(rows)]

    def warmup(self, rng_seed: int = 0):
        """Autotune a per-stage engine schedule (blocked tier only).

        Tunes the direct-path batch size; the request path additionally
        tunes per bucket, lazily, on each bucket's first tick. A no-op
        when a pre-tuned ``VigSchedule`` was passed at construction —
        warmup never clobbers a user-provided schedule.
        """
        if (not self.autotune or self.spec.impl != "blocked"
                or self.schedule is not None):
            return None
        from repro.core.tuner import DigcTuner

        tuner = DigcTuner(self.tuner_path)
        self.schedule, self.tuned = tuner.tune_schedule(
            self._stage_rows(),
            spec=self.spec, batch=self.batch, rng_seed=rng_seed,
        )
        # Forwards compiled before the schedule existed bake the old
        # spec: drop them so the next request recompiles with it.
        self._compiled.clear()
        self._jit_fwd = None
        return self.tuned

    def _impl_choice(self):
        return self.schedule if self.schedule is not None else self.spec

    def _bucket_choice(self, bucket: int, size: Optional[int] = None):
        """Resolve the DIGC impl/schedule for one (B, N) cell's program.

        The tuner's workload key includes the batch size AND the node
        counts (``_stage_rows(size)`` feeds the cell's own N/M), so
        lattice serving tunes **per cell** (``tune_bucket_schedules``),
        never reusing a schedule measured at a different batch or
        resolution — including the one ``warmup()`` measured at
        ``self.batch`` for the direct path (a warmup-tuned B=8 tile
        must not bake into the B=1 program; only a user-provided
        schedule applies everywhere).
        """
        if self._user_schedule:
            return self.schedule
        if self.spec.impl != "blocked" or not self.autotune:
            return self.spec
        size = self.image_sizes[0] if size is None else size

        def _skey(b):
            return b if not self._multi_size() else (size, b)

        if _skey(bucket) not in self._bucket_schedules:
            from repro.core.tuner import DigcTuner

            # First miss tunes every configured bucket at once (for
            # this size): a serving replica will compile them all
            # anyway, and the tuner's JSON cache makes later engines
            # free.
            targets = self.buckets if self.buckets is not None else (bucket,)
            tuner = DigcTuner(self.tuner_path)
            schedules, tuned = tuner.tune_bucket_schedules(
                self._stage_rows(size), spec=self.spec, buckets=targets,
            )
            self._bucket_schedules.update(
                {_skey(b): s for b, s in schedules.items()}
            )
            self._bucket_tuned.update(
                {_skey(b): t for b, t in tuned.items()}
            )
        return self._bucket_schedules[_skey(bucket)]

    # -- direct fixed-batch path (PR-3 API) -----------------------------

    def _infer_jit(self, images) -> jax.Array:
        from repro.models.vig import init_vig_state, vig_forward

        b = int(images.shape[0])
        if b not in self._compiled:
            choice = self._impl_choice()
            fwd = jax.jit(
                lambda p, im, st: vig_forward(
                    p, im, self.cfg, digc_impl=choice, state=st
                ),
                donate_argnums=(2,),
            )
            self._compiled[b] = [fwd, init_vig_state(self.cfg, b, choice)]
        fwd, state = self._compiled[b]
        logits, new_state = fwd(self.params, images, state)
        self._compiled[b][1] = new_state
        return logits

    def _infer_eager_shim(self, images) -> jax.Array:
        from repro.core.builder import get_builder
        from repro.models.vig import vig_forward

        if get_builder(self.spec.impl).supports_cache:
            # Eager so the host-side DigcCache engages across requests.
            return vig_forward(
                self.params, images, self.cfg,
                digc_impl=self.spec, cache=self.cache,
            )
        # No reusable construction state: serve jitted, stateless —
        # still through the tuned per-stage schedule when one exists,
        # so eager vs jit mode differ only in the state threading.
        choice = self._impl_choice()
        if self._jit_fwd is None or self._jit_fwd[0] is not choice:
            self._jit_fwd = (choice, jax.jit(
                lambda p, im: vig_forward(p, im, self.cfg, digc_impl=choice)
            ))
        return self._jit_fwd[1](self.params, images)

    def infer(self, images) -> jax.Array:
        """images (B, H, W, C) -> logits (B, num_classes).

        Direct fixed-batch path: one compiled program + state per exact
        batch size. Ragged multi-tenant traffic belongs on the request
        path (``submit``/``run``) instead.
        """
        if (self.autotune and self.tuned is None and self.schedule is None
                and self.spec.impl == "blocked"):
            self.warmup()
        if self.mode == "eager":
            logits = self._infer_eager_shim(images)
        else:
            logits = self._infer_jit(images)
        self.requests_served += int(images.shape[0])
        return logits

    # -- multi-tenant request path --------------------------------------

    def submit(self, req: VigRequest) -> None:
        """Enqueue a request for the next engine tick.

        Validates the image against the engine's model config up
        front: a malformed request must fail here, at the submitter,
        with a typed error naming the field — not as a shape error
        deep inside a jitted program three ticks later (where it would
        take co-batched tenants down with it).
        """
        img = np.asarray(req.image)
        if img.ndim != 3:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): expected a 3-d "
                f"(H, W, C) array, got ndim={img.ndim} shape={img.shape}"
            )
        h, w, c = img.shape
        if c != self.cfg.in_chans:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): {c} channels does "
                f"not match the engine config in_chans={self.cfg.in_chans}"
            )
        if h != w:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): non-square image "
                f"{img.shape}; the patch lattice needs H == W"
            )
        if not np.issubdtype(img.dtype, np.floating):
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): dtype {img.dtype} is "
                "not a float dtype; pass float32 pixel features"
            )
        # -- N-bucket resolution (DESIGN.md §13): an exact configured
        # size serves its own cell unmasked; a ragged size pads up to
        # the smallest cell that fits, carrying a per-node live mask so
        # DIGC BIG-norm-masks the pad nodes out of every top-k.
        if h in self.image_sizes:
            req._serve_size, req._serve_mask = h, None
            self._enqueue(req)
            return
        if not self._lattice:
            want = (self.cfg.image_size, self.cfg.image_size,
                    self.cfg.in_chans)
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): shape {img.shape} "
                f"does not match the engine config {want} "
                "(image_size, image_size, in_chans); construct the "
                "engine with image_sizes= to serve ragged resolutions"
            )
        if h % self.cfg.patch:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): size {h} is not "
                f"divisible by the model patch size {self.cfg.patch}"
            )
        fits = [s for s in self.image_sizes if s >= h]
        if not fits:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): size {h} exceeds "
                f"the largest configured image size "
                f"{self.image_sizes[-1]} (image_sizes={self.image_sizes})"
            )
        size = fits[0]
        self._check_pad_capable(req, h)
        g, g0 = size // self.cfg.patch, h // self.cfg.patch
        mask2d = np.zeros((g, g), bool)
        mask2d[:g0, :g0] = True
        req._serve_size, req._serve_mask = size, mask2d.reshape(-1)
        self._enqueue(req)

    def _check_pad_capable(self, req, h: int) -> None:
        """Typed submit-time screen for the padded (masked) path: pad
        nodes require a single-stage r=1 model (pooling/downsampling
        would mix pad and live rows) and a pad-capable DIGC tier
        (``GraphBuilder.supports_pad`` — the BIG-norm masking)."""
        from repro.core.builder import get_builder

        cfg = self.cfg
        if len(cfg.depths) > 1 or any(
            r > 1 for r in cfg.reduce_ratios[:len(cfg.depths)]
        ):
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): size {h} needs "
                f"pad nodes to reach the {self.image_sizes} cell set, "
                f"but model {cfg.name!r} has a multi-stage/pooled "
                f"pyramid (depths={cfg.depths}, "
                f"reduce_ratios={cfg.reduce_ratios}) that would mix pad "
                "and live rows — submit an exact configured size, or "
                "add this size to image_sizes"
            )
        impl = (self.schedule.spec_for(0).impl if self._user_schedule
                else self.spec.impl)
        if not get_builder(impl).supports_pad:
            raise ValueError(
                f"VigRequest.image (uid={req.uid}): size {h} needs pad "
                f"nodes, but DIGC impl {impl!r} does not support "
                "pad-node masking (m_valid); submit an exact configured "
                "size, or serve a pad-capable tier"
            )

    # -- fault tolerance (DESIGN.md §11) --------------------------------

    def _fire(self, site: str, value=None, **ctx):
        """Fault-injection hook: a no-op (returning ``value``
        unchanged) unless a ``FaultPlan`` was supplied."""
        if self.fault_plan is None:
            return value
        return self.fault_plan.fire(site, value=value, tick=self._tick, **ctx)

    def _retry(self, fn, what: str):
        """Bounded retry with exponential backoff for host-side
        transients (parking restore, program build). Re-raises the
        last error once the budget is spent."""
        last = None
        for attempt in range(self.retry_attempts):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — transient boundary
                last = e
                self.retries += 1
                if attempt + 1 < self.retry_attempts:
                    time.sleep(self.retry_backoff * (2 ** attempt))
        raise last

    def _token_key(self, size: int, key: str) -> str:
        """Integrity-token namespace: per (N-bucket, entry) on the
        lattice; the bare entry key on a single-size engine."""
        return key if not self._multi_size() else f"{size}:{key}"

    def _refresh_tokens(self, slots, size: Optional[int] = None) -> None:
        """Re-fingerprint ``slots``' state rows after a *sanctioned*
        write (admission reset, unpark restore, end-of-tick scatter).
        Any later mismatch is an unsanctioned mutation. ``size``
        restricts the refresh to one N-bucket's state (the per-tick
        scatter); ``None`` re-fingerprints every allocated bucket
        (slot-lifecycle writes touch them all)."""
        if not self.guards or not self._slot_states:
            return
        targets = (self._slot_states.items() if size is None
                   else [(size, self._slot_states[size])]
                   if size in self._slot_states else [])
        for sz, st in targets:
            fps = st.row_fingerprints(list(slots))
            for key, rows in fps.items():
                self._row_tokens.setdefault(
                    self._token_key(sz, key), {}
                ).update(rows)

    def _graph_stats_update(self, old_state, new_state, lanes) -> None:
        """Reconcile per-lane graph reuse/rebuild counters from one
        tick's state delta (stale-graph serving, DESIGN.md §12).

        ``graph_age`` is authoritative: the reuse gate in core/digc
        resets a row's age to 0 whenever its graph was rebuilt this
        call chain and grows it otherwise, so ``new_age == 0`` after a
        served tick means the lane paid a DIGC build and anything else
        means it rode the cached graph. Drift is recovered from the
        snapshot statistic the gate itself uses: on a rebuild the entry
        adopts the fresh ``graph_snap``, so the relative delta vs the
        previous snapshot is (approximately) the drift that tripped
        the gate. Both states are read at slot granularity — the
        bucket-shaped tick arrays are donated into the jit program and
        gone by the time this runs."""
        rows = np.asarray(lanes, dtype=np.int64)
        for key, new_e in new_state.entries.items():
            if new_e.graph_age is None:
                continue
            old_e = old_state.entries.get(key)
            if old_e is None or old_e.graph_age is None:
                continue
            new_age = np.asarray(new_e.graph_age)[rows]
            rebuilt = new_age == 0
            self.graph_rebuilds += int(rebuilt.sum())
            self.graph_reuses += int((~rebuilt).sum())
            old_snap = np.asarray(old_e.graph_snap)[rows]
            new_snap = np.asarray(new_e.graph_snap)[rows]
            # cold lanes carry the zero-initialized snapshot — their
            # first build is an admission, not drift
            warm = np.abs(old_snap) > 0
            drift = np.where(
                warm,
                np.abs(new_snap - old_snap) / np.maximum(np.abs(old_snap),
                                                         1e-9),
                0.0,
            )[warm]
            if drift.size:
                self.last_drift[key] = float(drift.mean())
                self._drift_sum += float(drift.sum())
                self._drift_n += int(drift.size)

    def _row_intact(self, slot: int, fps=None,
                    size: Optional[int] = None) -> bool:
        """Check ``slot``'s rows against their integrity tokens (for
        the ``size`` N-bucket being served). Rows never fingerprinted
        (no sanctioned write yet) are trusted. ``fps`` passes
        precomputed fingerprints so one tick's lanes share a single
        device->host pull."""
        size = self.image_sizes[0] if size is None else size
        st = self._slot_states.get(size)
        if st is None:
            return True
        if fps is None:
            fps = st.row_fingerprints([slot])
        for key, rows in fps.items():
            want = self._row_tokens.get(
                self._token_key(size, key), {}
            ).get(slot)
            if want is not None and rows[slot] != want:
                return False
        return True

    def _row_finite(self, slot: int, finite=None,
                    size: Optional[int] = None) -> bool:
        size = self.image_sizes[0] if size is None else size
        st = self._slot_states.get(size)
        if st is None:
            return True
        if finite is None:
            finite = st.rows_finite([slot])
        return finite[slot]

    def _quarantine(self, slot: int, req: VigRequest,
                    info: FaultInfo) -> None:
        """Fail one request with a typed ``FaultInfo`` and cold-reset
        its slot, leaving every co-batched tenant untouched: the faulty
        lane simply never reaches the compiled program."""
        req.fault = info
        req.logits = None
        req.done = True
        self.quarantines += 1
        self.requests_failed += 1
        self.fault_log.append(info)
        self.last_quarantined.append(slot)
        if self._slot_states:
            # A poisoned carry is suspect at every resolution the slot
            # holds rows for — reset them all (one counted reset).
            self._reset_rows_all([slot])
            self.state_resets += 1
        self._slot_last_tick[slot] = self._tick
        if req.tenant is None:
            self.slot_tenant[slot] = None
            self._tenant_slot.pop(("req", req.uid), None)

    def _degrade(self, info: FaultInfo) -> bool:
        """Descend one rung of the degradation ladder
        (``core.builder.fallback_chain``): drop every compiled program
        and rebuild at the next-simpler tier. Returns False when the
        ladder is exhausted."""
        from repro.core.builder import fallback_chain

        chain = fallback_chain(self._ladder_base_impl())
        if self.fallback_level >= len(chain):
            return False
        self.fallback_level += 1
        self._programs.clear()
        self._program_ticks.clear()
        self._consecutive_misses = 0
        self.fault_log.append(info)
        return True

    def _ladder_base_impl(self) -> str:
        choice = self._impl_choice()
        return (choice.spec_for(0).impl if hasattr(choice, "spec_for")
                else choice.impl)

    def release(self, tenant: Any) -> None:
        """Tenant disconnect: free its slot and cold-reset the rows, so
        the next occupant cannot warm-start from its state. A released
        tenant's parked copy (if any) is dropped too — disconnect means
        gone, unlike an LRU eviction (which parks)."""
        self._parked.pop(tenant, None)
        self._park_prefetch.pop(tenant, None)
        slot = self._tenant_slot.pop(tenant, None)
        if slot is None:
            return
        self.slot_tenant[slot] = None
        if self._slot_states:
            self._reset_rows_all([slot])

    # -- LRU state parking (DESIGN.md §10) ------------------------------

    def _park(self, tenant: Any, slot: int) -> None:
        """Copy an evicted tenant's state rows to host memory (bounded,
        LRU-dropped) so a later re-admit restores them warm. On the
        multi-resolution lattice the parked copy holds the slot's rows
        for **every** allocated N-bucket (``{size: rows}``) — a tenant
        re-admitted after serving at two resolutions gets both carries
        back; single-size engines park the bare rows (the pre-multires
        layout the parking tests read)."""
        if self.park_capacity <= 0 or not self._slot_states:
            return
        host = {
            size: jax.tree_util.tree_map(
                np.asarray, st.take_rows([slot])
            )
            for size, st in self._slot_states.items()
        }
        self._parked.pop(tenant, None)  # re-insert = most recent
        # a fresh park supersedes any in-flight prefetch of older rows
        self._park_prefetch.pop(tenant, None)
        self._parked[tenant] = (host if self._multi_size()
                                else host[self.image_sizes[0]])
        while len(self._parked) > self.park_capacity:
            oldest = next(iter(self._parked))
            del self._parked[oldest]
            self._park_prefetch.pop(oldest, None)
            self.park_evictions += 1

    def _unpark(self, tenant: Any, slot: int) -> bool:
        """Restore a parked tenant's rows into its freshly bound slot.
        Returns False (caller cold-resets) when nothing is parked. Only
        the *row* fields are restored — the scalar ``step`` stays the
        canonical entry's (it is the engine-global call counter, not a
        per-tenant value; per-row validity lives in ``row_step``).

        The restore passes the ``park.restore`` fault site: transient
        errors are retried with backoff; a ``None`` coming back after a
        parked copy existed is a parking-store **loss** — counted, and
        the tenant re-admits cold (the caller resets the slot)."""
        had_copy = tenant in self._parked
        host = self._parked.pop(tenant, None)
        prefetched = self._park_prefetch.pop(tenant, None)
        orig = host
        if host is not None:
            try:
                host = self._retry(
                    lambda: self._fire("park.restore", value=host,
                                       tenant=tenant),
                    "park restore",
                )
            except FaultError:
                host = None
        if host is None:
            if had_copy:
                # The parked rows existed but could not be restored —
                # account the loss; the cold reset that follows is the
                # recovery, not a silent fallback.
                self.park_losses += 1
                self.state_resets += 1  # the caller's cold reset is recovery
                self.fault_log.append(FaultInfo(
                    kind="parking_loss", site="park.restore",
                    tenant=tenant, tick=self._tick,
                    detail="parked rows unrecoverable; re-admitting cold",
                ))
            return False
        from repro.core.state import DigcState

        if prefetched is not None and host is orig:
            # The queue-driven prefetch already uploaded exactly these
            # host rows (identity-checked: a fault-site replacement
            # must re-upload) — bind the in-flight device copy instead,
            # taking the host->device transfer off the tick. The §11
            # integrity screens below (_refresh_tokens now, the batched
            # fingerprint/finiteness pull next tick) run against the
            # bound rows either way.
            host = prefetched[1]
            self.prefetch_hits += 1
        per_size = (host if self._multi_size()
                    else {self.image_sizes[0]: host})
        # N-buckets allocated since the park (no rows in the copy) must
        # not keep the *previous* occupant's rows: reset first, then
        # lay the parked copy over its own sizes.
        for size, st in self._slot_states.items():
            if size not in per_size:
                self._slot_states[size] = st.reset_rows([slot])
        for size, rows in per_size.items():
            state = self._ensure_slot_state(size)
            self._slot_states[size] = DigcState(entries={
                k: dataclasses.replace(
                    e.put_rows(rows.entries[k], [slot]), step=e.step
                )
                for k, e in state.entries.items()
            })
        self.park_hits += 1
        self._refresh_tokens([slot])
        return True

    def bucket_for(self, active: int) -> int:
        """Smallest bucket that fits ``active`` slots (the bucket
        policy); the exact count when bucketing is disabled."""
        if not 1 <= active <= self.slots:
            raise ValueError(f"active={active} outside 1..{self.slots}")
        if self.buckets is None:
            return active
        return next(b for b in self.buckets if b >= active)

    def _ensure_slot_state(self, size: Optional[int] = None):
        from repro.models.vig import init_vig_state

        size = self.image_sizes[0] if size is None else size
        if size not in self._slot_states:
            # Allocate from the same impl choice the bucket programs
            # resolve: a user-provided VigSchedule may carry per-stage
            # specs (e.g. cluster with stage-specific n_clusters) whose
            # entry shapes differ from a stage-0-only resolution. The
            # autotuned (blocked-only) schedules never change entry
            # shapes, so the canonical state stays bucket-independent.
            # Row buffers are sized by this N-bucket's stage plans
            # (grid=) — a 448 cell's cached-graph rows are N=12544.
            choice = self.schedule if self._user_schedule else self.spec
            self._slot_states[size] = init_vig_state(
                self.cfg, self.slots, choice, per_slot=True,
                mesh=self.mesh, mesh_axis=self.mesh_axis,
                grid=size // self.cfg.patch,
            )
        return self._slot_states[size]

    def _choice_for(self, bucket: int, size: Optional[int] = None):
        """Resolve the cell's DIGC impl through the degradation
        ladder: at fallback level 0 this is the tuned per-cell
        choice; each descended rung swaps in the next tier of
        ``core.builder.fallback_chain`` (simpler machinery, never less
        exact)."""
        if self.fallback_level == 0:
            return self._bucket_choice(bucket, size)
        from repro.core.builder import degraded_spec, fallback_chain

        chain = fallback_chain(self._ladder_base_impl())
        return degraded_spec(self.spec, chain[self.fallback_level - 1])

    def _build_program(self, bucket: int, size: Optional[int] = None,
                       masked: bool = False) -> Callable:
        """Compile one (B, N) cell's donated forward. Split out so
        tests can stub program construction and count compiles. Passes
        the ``program.build`` fault site (injected compile failures).
        ``masked=True`` builds the pad-node variant: a fourth (B, N)
        bool argument marks live nodes, BIG-norm-masked through DIGC
        (exact-size cells keep the 3-argument program, so their trace
        is byte-identical to the single-size engine's)."""
        from repro.models.vig import vig_forward

        size = self.image_sizes[0] if size is None else size
        choice = self._choice_for(bucket, size)
        impl = (choice.spec_for(0).impl if hasattr(choice, "spec_for")
                else choice.impl)
        self._fire("program.build", bucket=bucket, impl=impl)
        if masked:
            return jax.jit(
                lambda p, im, st, mv: vig_forward(
                    p, im, self.cfg, digc_impl=choice, state=st,
                    valid_mask=mv,
                ),
                donate_argnums=(2,),
            )
        return jax.jit(
            lambda p, im, st: vig_forward(
                p, im, self.cfg, digc_impl=choice, state=st
            ),
            donate_argnums=(2,),
        )

    def _program_for(self, bucket: int, size: Optional[int] = None,
                     masked: bool = False) -> Callable:
        """Cell program lookup with recovery: a failing build is
        retried (transient compile-service hiccups), and a
        persistently failing tier walks the degradation ladder until a
        rung builds — only an exhausted ladder re-raises."""
        key = self._program_key(bucket, size, masked)
        legacy = key == bucket  # single-size, unmasked: the 1-arg
        # _build_program call the stubbing tests override
        while key not in self._programs:
            try:
                if legacy:
                    prog = self._retry(
                        lambda: self._build_program(bucket),
                        f"bucket {bucket} program build",
                    )
                else:
                    prog = self._retry(
                        lambda: self._build_program(
                            bucket, size=size, masked=masked
                        ),
                        f"cell {key} program build",
                    )
            except Exception as e:  # noqa: BLE001 — ladder boundary
                info = (e.info if isinstance(e, FaultError) else FaultInfo(
                    kind="compile_failure", site="program.build",
                    tick=self._tick, detail=repr(e),
                ))
                if not self._degrade(dataclasses.replace(
                    info, kind="compile_degrade",
                    detail=f"{info.detail}; descending ladder",
                )):
                    raise
                continue
            self._programs[key] = prog
            self.compile_count += 1
            if self.on_compile is not None:
                self.on_compile(key)
        return self._programs[key]

    def _admit(self, tenant_key, used: set) -> Optional[int]:
        """Bind a new tenant to a slot: a free one, else LRU-evict an
        idle one (never a slot already serving this tick; the evictee's
        rows are parked host-side first). The bound slot's state rows
        are restored from the tenant's parked copy when one exists,
        else cold-reset. Returns None when every slot is busy this
        tick."""
        free = [s for s in range(self.slots) if self.slot_tenant[s] is None
                and s not in used]
        if free:
            slot = free[0]
        else:
            idle = [s for s in range(self.slots) if s not in used]
            if not idle:
                return None
            slot = min(idle, key=lambda s: self._slot_last_tick[s])
            evicted = self.slot_tenant[slot]
            if evicted is not None:
                del self._tenant_slot[evicted]
                self._park(evicted, slot)
        self.slot_tenant[slot] = tenant_key
        self._tenant_slot[tenant_key] = slot
        if self._unpark(tenant_key, slot):
            self.last_restores.append(slot)
        else:
            if self._slot_states:
                self._reset_rows_all([slot])
            self.last_resets.append(slot)
        return slot

    def step(self) -> int:
        """One engine tick: admit queued requests into slots, serve the
        active slots padded to a bucket, scatter state back. Returns
        the number of requests served.

        On the multi-resolution lattice a tick serves exactly ONE
        (size, pad-variant) cell — the head-of-queue's. Requests
        resolved to other cells stay queued (in order) for a later
        tick: a compiled program has one static (B, N) shape, and
        mixing cells in a tick would need a second program anyway."""
        if not self.queue:
            return 0
        if self.mode != "jit":
            raise RuntimeError(
                "the multi-tenant request path serves through the jitted "
                "functional-state forward; construct with mode='jit'"
            )
        cell, eligible = self._select_cell()
        if cell is None:
            # Scheduler deferral (slo_ms > 0): no cell is ripe — wait
            # for arrivals to fill a cell or for the recorded
            # ``_next_deadline`` (run() advances the clock to it). Not
            # a tick: _tick/last_* stay untouched.
            self.deferrals += 1
            self._prefetch_parked()
            return 0
        size, masked_cell = cell
        self._tick += 1
        self.last_resets = []
        self.last_restores = []
        self.last_quarantined = []
        used: set[int] = set()
        assigned: dict[int, int] = {}  # id(request) -> slot
        _tkey = self._tkey

        # Admission pass 1 — tenants that already own a slot reserve it
        # first, so a new tenant admitted later in the same tick can
        # only LRU-evict *idle* slots, never a warm tenant that is
        # itself active this tick (queue order must not decide whose
        # warm state survives). One lane per tenant per tick: state is
        # a serial carry, a tenant's second request waits for the next
        # tick so it warm-starts from the first's output.
        for req in eligible:
            if len(assigned) >= self.slots:
                break
            slot = self._tenant_slot.get(_tkey(req))
            if slot is not None and slot not in used:
                used.add(slot)
                assigned[id(req)] = slot
        # Admission pass 2 — new tenants, in arrival order, into free
        # slots first, else LRU-evicting an idle slot.
        for req in eligible:
            if len(assigned) >= self.slots:
                break
            if id(req) in assigned:
                continue
            tkey = _tkey(req)
            if self._tenant_slot.get(tkey) is not None:
                continue  # bound tenant already serving this tick
            slot = self._admit(tkey, used)
            if slot is None:
                continue
            used.add(slot)
            assigned[id(req)] = slot
        picked = [(assigned[id(r)], r) for r in eligible
                  if id(r) in assigned]
        self.queue = [r for r in self.queue if id(r) not in assigned]
        picked.sort(key=lambda sr: sr[0])

        state = self._ensure_slot_state(size)
        # Fault site: unsanctioned state mutation (bit corruption that
        # bypassed put_rows/reset_rows). The replaced state is adopted
        # WITHOUT refreshing the integrity tokens — detecting exactly
        # this is what the tokens are for.
        mutated = self._fire("state.rows", value=state)
        if mutated is not state:
            self._slot_states[size] = state = mutated

        # Guarded screening (DESIGN.md §11): each picked lane passes
        # the admission finiteness screen and the state-row checks
        # before it may reach a compiled program. A failing lane is
        # handled per the fault taxonomy — co-batched healthy tenants
        # are served exactly as if the faulty lane never existed.
        healthy: list[tuple[int, VigRequest]] = []
        imgs_list: list[np.ndarray] = []
        masks_list: list[np.ndarray] = []
        # One batched device->host pull for all picked lanes' state
        # checks — the sync, not the crc/isfinite, is the guard cost
        # (the serve/guarded_* bench rows price exactly this).
        finite = fps = None
        if self.guards and picked:
            slots_picked = [slot for slot, _ in picked]
            finite = state.rows_finite(slots_picked)
            fps = state.row_fingerprints(slots_picked)
        for slot, req in picked:
            img = np.asarray(req.image, np.float32)
            fired = self._fire("admit.image", value=img, tenant=req.tenant)
            if fired is not img:
                img = np.asarray(fired, np.float32)
            if self.guards and not np.isfinite(img).all():
                self._quarantine(slot, req, FaultInfo(
                    kind="nonfinite_input", site="admit.image",
                    tenant=req.tenant, tick=self._tick,
                    detail="non-finite values in submitted image",
                ))
                continue
            if self.guards:
                if not self._row_finite(slot, finite, size):
                    # Non-finite state rows: the tenant's warm carry is
                    # poisoned — fail this request, cold-reset the slot.
                    self._quarantine(slot, req, FaultInfo(
                        kind="nonfinite_state", site="state.rows",
                        tenant=req.tenant, tick=self._tick,
                        detail=f"non-finite state rows on slot {slot}",
                    ))
                    continue
                if not self._row_intact(slot, fps, size):
                    # Finite but token-mismatched rows (silent
                    # corruption): recover by serving this request
                    # COLD — reset, re-fingerprint, keep the lane.
                    state = state.reset_rows([slot])
                    self._slot_states[size] = state
                    self.state_resets += 1
                    self.fault_log.append(FaultInfo(
                        kind="state_corruption", site="state.rows",
                        tenant=req.tenant, tick=self._tick,
                        detail=(f"integrity token mismatch on slot "
                                f"{slot}; cold reset"),
                    ))
                    self.last_resets.append(slot)
                    self._refresh_tokens([slot], size)
            if masked_cell and img.shape[0] < size:
                # Zero-pad the ragged image up to its cell: the patch
                # embed is stride-patch (node-local), so live patches
                # see exactly their own pixels and pad patches are
                # BIG-norm-masked out of every top-k downstream.
                canvas = np.zeros((size, size, img.shape[-1]), np.float32)
                canvas[:img.shape[0], :img.shape[1]] = img
                img = canvas
            healthy.append((slot, req))
            imgs_list.append(img)
            if masked_cell:
                mask = self._req_mask(req)
                n = (size // self.cfg.patch) ** 2
                masks_list.append(np.ones(n, bool) if mask is None
                                  else np.asarray(mask, bool))

        if not healthy:
            self.last_lanes = []
            self.last_bucket = None
            self.last_cell = None
            self._prefetch_parked()
            return 0

        lanes = [slot for slot, _ in healthy]
        a = len(lanes)
        bucket = self.bucket_for(a)
        self.last_lanes = list(lanes)
        self.last_bucket = bucket
        self.last_cell = (size, bucket)
        # Padding lanes replicate lane 0 (image AND state row): their
        # compute mirrors a live lane — well-conditioned, and warm
        # whenever lane 0 is, so they never force the mixed warm/cold
        # path — and their outputs/state are simply dropped. The tick
        # width additionally rounds the bucket up to the next
        # mesh_batch_axis multiple (same replication) when the rows are
        # sharded — non-dividing buckets pad instead of failing.
        width = self._tick_width(bucket)
        rows = lanes + [lanes[0]] * (width - a)
        imgs = np.stack(imgs_list + [imgs_list[0]] * (width - a))
        state = self._slot_states[size]
        bucket_state = state.take_rows(rows)
        fwd = self._program_for(bucket, size, masked_cell)
        pkey = self._program_key(bucket, size, masked_cell)
        # The timed serve section: dispatch + device compute + the
        # host sync that materializes the logits. A per-engine
        # deadline budget (deadline_ms) turns stragglers into counted
        # misses; deadline_strikes consecutive misses descend the
        # degradation ladder.
        t0 = time.perf_counter()
        self._fire("tick.serve", bucket=bucket)
        if masked_cell:
            masks = np.stack(
                masks_list + [masks_list[0]] * (width - a)
            )
            logits, new_bucket_state = fwd(
                self.params, jnp.asarray(imgs), bucket_state,
                jnp.asarray(masks),
            )
        else:
            logits, new_bucket_state = fwd(
                self.params, jnp.asarray(imgs), bucket_state
            )
        # Scatter live lanes only: src rows >= a (padding) are dropped.
        self._slot_states[size] = state.put_rows(new_bucket_state, lanes)
        logits_np = np.asarray(logits)  # host sync closes the region
        self._graph_stats_update(state, self._slot_states[size], lanes)
        elapsed_ms = (time.perf_counter() - t0) * 1e3
        first_tick = pkey not in self._program_ticks
        self._program_ticks[pkey] = self._program_ticks.get(pkey, 0) + 1
        if self.deadline_ms is not None and not first_tick:
            # A bucket program's first served tick includes its jit
            # compile — never a deadline signal.
            if elapsed_ms > self.deadline_ms:
                self.deadline_misses += 1
                self._consecutive_misses += 1
                info = FaultInfo(
                    kind="deadline_miss", site="tick.serve",
                    tick=self._tick,
                    detail=(f"bucket {bucket} tick {elapsed_ms:.2f}ms > "
                            f"budget {self.deadline_ms}ms"),
                )
                self.fault_log.append(info)
                if self._consecutive_misses >= self.deadline_strikes:
                    self._degrade(dataclasses.replace(
                        info, kind="deadline_degrade",
                        detail=(f"{self._consecutive_misses} consecutive "
                                "misses; descending ladder"),
                    ))
            else:
                self._consecutive_misses = 0
        self._refresh_tokens(lanes, size)
        for i, (slot, req) in enumerate(healthy):
            req.logits = logits_np[i]
            req.done = True
            self._slot_last_tick[slot] = self._tick
            if req.tenant is None:
                # anonymous one-shot: free the slot immediately so it
                # never pins out live warm tenants under LRU eviction
                # (the next occupant is cold-reset on admission)
                self.slot_tenant[slot] = None
                self._tenant_slot.pop(("req", req.uid), None)
        self.requests_served += a
        self.bucket_ticks[bucket] = self.bucket_ticks.get(bucket, 0) + 1
        cell = (size, bucket)
        self.cell_ticks[cell] = self.cell_ticks.get(cell, 0) + 1
        # padding-waste accounting (stats()/retune_buckets): the
        # invariant the property tests pin is padded_lanes ==
        # sum over ticks of (width - live), exactly.
        self.live_lanes += a
        self.padded_lanes += width - a
        self.lane_hist[(size, a)] = self.lane_hist.get((size, a), 0) + 1
        self._prefetch_parked()
        return a

    def run(self) -> list[VigRequest]:
        """Drain the queue; returns the completed requests in
        submission order. (The engine keeps no completion log of its
        own — a step()-driven server owns its request objects, so
        nothing accumulates across ticks.)

        Under the admission scheduler (slo_ms > 0) a deferred tick
        advances time to the next recorded deadline — a ``VirtualClock``
        jumps (deterministic drains in tests/benches), the wall clock
        sleeps the remainder — so draining always terminates."""
        pending = list(self.queue)
        while self.queue:
            served = self.step()
            if not served and self.queue and self._next_deadline is not None:
                self._advance_to_deadline()
        return [r for r in pending if r.done]

    # -- observability --------------------------------------------------

    def state_steps(self) -> dict:
        """Per-batch-size view of the functional state's step counters
        (the direct fixed-batch path)."""
        return {b: c[1].steps() for b, c in self._compiled.items()}

    def slot_row_steps(self, size: Optional[int] = None) -> dict:
        """Per-slot request counters of the canonical multi-tenant
        state (empty before the first tick). ``size`` selects an
        N-bucket on the lattice; default is the primary size."""
        st = self._slot_states.get(
            self.image_sizes[0] if size is None else size
        )
        if st is None:
            return {}
        return st.row_steps()

    def stats(self) -> dict:
        out = {"requests_served": self.requests_served, "mode": self.mode,
               "digc_cache": self.cache.stats(),
               "digc_state": self.state_steps(),
               "buckets": self.buckets,
               "image_sizes": self.image_sizes,
               "bucket_ticks": dict(self.bucket_ticks),
               "cell_ticks": {f"{s}x{b}": n
                              for (s, b), n in self.cell_ticks.items()},
               "compiled_programs": self.compile_count,
               "slot_tenants": list(self.slot_tenant),
               "slot_row_steps": self.slot_row_steps(),
               "mesh": (None if self.mesh is None
                        else {k: int(v) for k, v in self.mesh.shape.items()}),
               "parked_tenants": list(self._parked),
               "park_hits": self.park_hits,
               "park_evictions": self.park_evictions,
               # admission scheduling + padding-waste accounting
               # (DESIGN.md §14) — live on the legacy slo_ms=0 path too
               "queue_depth": len(self.queue),
               "live_lanes": self.live_lanes,
               "padded_lanes": self.padded_lanes,
               "util": (self.live_lanes
                        / (self.live_lanes + self.padded_lanes)
                        if (self.live_lanes + self.padded_lanes) else 1.0),
               "lane_hist": {f"{s}x{live}": n
                             for (s, live), n in sorted(self.lane_hist.items())},
               "deferrals": self.deferrals,
               "slo_ms": (dict(self._slo_ms)
                          if isinstance(self._slo_ms, dict) else self._slo_ms),
               "prefetch_issued": self.prefetch_issued,
               "prefetch_hits": self.prefetch_hits,
               # fault tolerance (DESIGN.md §11)
               "guards": self.guards,
               "quarantines": self.quarantines,
               "state_resets": self.state_resets,
               "deadline_misses": self.deadline_misses,
               "fallback_level": self.fallback_level,
               "park_losses": self.park_losses,
               "retries": self.retries,
               "requests_failed": self.requests_failed,
               # stale-graph serving (DESIGN.md §12)
               "graph_reuses": self.graph_reuses,
               "graph_rebuilds": self.graph_rebuilds,
               "drift": {
                   "mean": (self._drift_sum / self._drift_n
                            if self._drift_n else 0.0),
                   "last": dict(self.last_drift),
               },
               "faults": [f.as_dict() for f in self.fault_log[-16:]]}
        if self.fallback_level > 0:
            from repro.core.builder import fallback_chain

            chain = fallback_chain(self._ladder_base_impl())
            out["fallback_impl"] = chain[self.fallback_level - 1]
        if self.schedule is not None:
            out["schedule"] = self.schedule.describe()
        if self.tuned is not None:
            out["tuned"] = [r.as_dict() for r in self.tuned]
        if self._bucket_schedules:
            out["bucket_schedules"] = {
                b: s.describe() for b, s in self._bucket_schedules.items()
            }
        return out
