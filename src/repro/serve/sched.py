"""Serving-scheduler support (DESIGN.md §14): the deterministic clock
and the arrival-trace tooling behind the SLO-bounded admission queue.

``VirtualClock`` replaces wall time in ``VigServeEngine`` (the
``clock=`` knob): time only moves when the harness advances it, so a
replayed trace dispatches identically run over run — deadlines become
exact comparisons instead of races, which is what makes the scheduler
property tests and the ``serve/sched_*`` bench rows reproducible.

``arrival_trace`` draws the seeded Poisson + bursty request stream the
ROADMAP acceptance bar names: a memoryless trickle of mixed-size
singletons punctuated by synchronized flash crowds — the workload
shape where exact-size programs burn their time on per-tick overhead
and bucketed programs burn theirs on padding, i.e. exactly the regime
the admission queue and the bucket-set optimizer are built for.
``benchmarks/bench_serve.py`` and ``examples/serve_trace.py`` share
this generator so the committed rows and the example replay the same
workload.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


class VirtualClock:
    """Manually-advanced monotonic clock for deterministic scheduling.

    Duck-compatible with both call styles the engine accepts: it is a
    plain ``clock()`` callable and it exposes ``now()``; ``run()``'s
    deferral path additionally uses ``advance_to`` to jump straight to
    the next admission deadline instead of sleeping."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a monotonic clock by {dt}")
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        # monotonic: advancing to the past is a no-op, never a rewind
        self._t = max(self._t, float(t))
        return self._t


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of a generated trace (times in ms since start)."""

    t_ms: float
    tenant: str
    tclass: str = "default"      # tenant class: the slo_ms dict key
    size: Optional[int] = None   # image size; None = engine native


def arrival_trace(
    *,
    seed: int = 0,
    tenants: int = 8,
    poisson_ms: float = 40.0,
    poisson_n: int = 48,
    burst_every_ms: float = 400.0,
    burst_n: int = 3,
    burst_size: int = 6,
    classes: Sequence[str] = ("default",),
    sizes: Optional[Sequence[int]] = None,
) -> list[Arrival]:
    """Seeded Poisson + bursty arrival stream (the ROADMAP acceptance
    trace): ``poisson_n`` memoryless arrivals (exponential gaps, mean
    ``poisson_ms``) with ``burst_n`` synchronized flash crowds layered
    on top — ``burst_size`` back-to-back arrivals every
    ``burst_every_ms``. Tenants cycle round-robin over ``tenants``
    identities; classes and sizes cycle over their sequences.
    Deterministic for a fixed seed; the returned list is time-sorted.
    """
    rng = np.random.default_rng(seed)
    out: list[Arrival] = []
    t = 0.0
    for i in range(poisson_n):
        t += float(rng.exponential(poisson_ms))
        out.append(Arrival(
            t_ms=t,
            tenant=f"t{i % tenants}",
            tclass=classes[i % len(classes)],
            size=None if sizes is None else int(sizes[i % len(sizes)]),
        ))
    for b in range(burst_n):
        t0 = (b + 1) * burst_every_ms
        for j in range(burst_size):
            i = poisson_n + b * burst_size + j
            out.append(Arrival(
                t_ms=t0 + j * 1e-2,  # back-to-back, order preserved
                tenant=f"t{i % tenants}",
                tclass=classes[i % len(classes)],
                size=None if sizes is None else int(sizes[i % len(sizes)]),
            ))
    out.sort(key=lambda a: (a.t_ms, a.tenant))
    return out


def replay(engine, arrivals, images, *, clock: VirtualClock,
           max_idle_ticks: int = 10_000) -> list[tuple[int, int, int]]:
    """Replay a generated trace through an engine under a
    ``VirtualClock``: advance the clock to each arrival, submit it,
    offer the engine a tick, then drain — jumping the clock to the
    engine's next admission deadline whenever a tick defers. Works for
    scheduling engines (slo_ms > 0) and legacy ones alike (a legacy
    engine never defers, so the clock jumps never trigger).

    ``images`` is either a single HWC array or a ``{tenant: array}``
    dict. Returns one ``(served, live, width)`` triple per dispatched
    tick for utilization reporting. The engine must have been
    constructed with this same ``clock``."""
    from repro.serve.engine import VigRequest

    ticks: list[tuple[int, int, int]] = []

    def _tick() -> int:
        served = engine.step()
        if served:
            ticks.append((served, len(engine.last_lanes),
                          engine._tick_width(engine.last_bucket)))
        return served

    for uid, arr in enumerate(arrivals):
        t_arr = arr.t_ms / 1e3
        # timer wakeups: serve every queued cell whose deadline ripens
        # before this arrival — a real scheduler loop wakes on its
        # deadline timer, not only on arrivals, and the SLO bound the
        # property tests pin depends on it.
        idle = 0
        while engine.queue and idle < max_idle_ticks:
            dl = engine.next_deadline()
            if dl is None or dl >= t_arr:
                break
            clock.advance_to(dl)
            idle = idle + 1 if _tick() == 0 else 0
        clock.advance_to(t_arr)
        img = images[arr.tenant] if isinstance(images, dict) else images
        engine.submit(VigRequest(uid=uid, image=img, tenant=arr.tenant,
                                 tclass=arr.tclass))
        _tick()
    idle = 0
    while engine.queue and idle < max_idle_ticks:
        if _tick() == 0:
            idle += 1
            dl = engine.next_deadline()
            if dl is not None:
                clock.advance_to(dl)
        else:
            idle = 0
    if engine.queue:
        raise RuntimeError(
            f"trace replay stalled with {len(engine.queue)} requests "
            f"queued after {max_idle_ticks} idle ticks")
    return ticks
