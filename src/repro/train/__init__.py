# Training substrate: AdamW, schedules, train step, grad accumulation.
