"""AdamW with fp32 master weights, cosine schedule, global-norm clip.

Optimizer state is sharded identically to the parameters (the ZeRO-3
property falls out of the FSDP param sharding rules: every state tensor
inherits the param's NamedSharding)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment  (fp32, param-sharded)
    nu: Any  # second moment (fp32, param-sharded)
    master: Any  # fp32 master copy of params


def lr_at(step, oc: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_frac + (1 - oc.min_lr_frac) * cos)


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros_like(p, jnp.float32)
    master = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, params),
        nu=jax.tree_util.tree_map(f32, params),
        master=master,
    )


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(grads, opt_state: OptState, oc: OptConfig, params_dtype=None):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        new_master = master - lr * (
            mu_hat / (jnp.sqrt(nu_hat) + oc.eps) + oc.weight_decay * master
        )
        return mu, nu, new_master

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state.mu)
    flat_nu = treedef.flatten_up_to(opt_state.nu)
    flat_ma = treedef.flatten_up_to(opt_state.master)
    out = [upd(g, m, n, w) for g, m, n, w in zip(flat_g, flat_mu, flat_nu, flat_ma)]
    mu = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    nu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    master = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    dt = params_dtype
    new_params = jax.tree_util.tree_map(
        lambda w, g: w.astype(g.dtype if dt is None else dt), master, grads
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu, master), metrics
