"""Train step: loss + grad + AdamW, with microbatch gradient
accumulation, bf16 params / fp32 master, and optional int8-compressed
gradient all-reduce (distributed/compression.py)."""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.transformer import loss_fn as lm_loss_fn
from repro.train.optimizer import OptConfig, OptState, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, oc: OptConfig,
                    loss_fn: Optional[Callable] = None,
                    accum_steps: int = 1,
                    param_dtype=jnp.bfloat16):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With accum_steps > 1, batch's leading dim is split into
    microbatches scanned sequentially (same memory as 1/accum of the
    batch)."""
    loss_fn = loss_fn or lm_loss_fn

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch):
        if accum_steps == 1:
            loss, metrics, grads = compute_grads(params, batch)
        else:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, _, grads = compute_grads(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads
                )
                return (acc, loss_acc + loss), None

            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = lax.scan(micro, (zero, jnp.float32(0.0)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, oc, params_dtype=param_dtype
        )
        out = {"loss": loss, **opt_metrics}
        for k, v in (metrics or {}).items():
            out[k] = v
        return new_params, new_opt, out

    return train_step


def init_train_state(params):
    return init_opt_state(params)
