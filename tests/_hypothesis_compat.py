"""Shared optional-hypothesis shim (requirements-dev.txt): property
tests skip cleanly when hypothesis is absent; everything else runs.

    from _hypothesis_compat import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:

    def given(**kwargs):
        del kwargs
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**kwargs):
        del kwargs
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in namespace
        integers = staticmethod(lambda *a, **k: None)
