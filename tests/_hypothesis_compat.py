"""Shared optional-hypothesis shim (requirements-dev.txt): property
tests skip cleanly when hypothesis is absent; everything else runs.

    from _hypothesis_compat import given, settings, st

When hypothesis *is* installed, ``conftest.py`` registers and loads
the fixed ``repro`` profile (deadline=None, derandomized) so CI and
local runs draw identical examples.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(**kwargs):
        del kwargs
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**kwargs):
        del kwargs
        return lambda fn: fn

    class _NullStrategy:
        """Stand-in strategy object: accepts any chained call."""

        def __getattr__(self, name):
            return lambda *a, **k: self

    class _StMeta(type):
        # class-level attribute access (st.integers, st.lists, ...)
        # resolves through the metaclass
        def __getattr__(cls, name):
            return lambda *a, **k: _NullStrategy()

    class st(metaclass=_StMeta):  # noqa: N801 - stand-in namespace
        """Any ``st.<strategy>(...)`` resolves to an inert stand-in, so
        decorated test modules still import when hypothesis is absent
        (the ``given`` shim skips them before the strategies are
        drawn)."""
