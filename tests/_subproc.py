"""Shared multi-device subprocess runner for the forced-host-device
tests (test_ring, test_distributed, test_dryrun_specs,
test_serve_sharded).

One definition of the subprocess environment, because its contents are
load-bearing in a way per-test copies kept getting wrong:

* ``JAX_PLATFORMS=cpu`` — without the pin jax probes for a TPU backend
  first, and on TPU-library-equipped hosts that probe retries metadata
  fetches for ~8 minutes per subprocess before falling back to CPU
  (these are CPU tests by construction);
* ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — must be set
  before jax initializes, which is the whole reason these tests run in
  a subprocess rather than the (1-device) main test process.
"""

import subprocess
import sys
import textwrap
from pathlib import Path
from typing import Optional

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_snippet(
    snippet: str,
    *,
    devices: Optional[int] = 8,
    timeout: int = 600,
    check: bool = True,
) -> subprocess.CompletedProcess:
    """Run a dedented python snippet in a pinned-env subprocess.

    ``devices=None`` omits XLA_FLAGS for snippets that set their own
    device count before importing jax. ``check=True`` asserts a zero
    exit status with stderr in the failure message.
    """
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu"}
    if devices is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
        )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if check:
        assert proc.returncode == 0, proc.stderr[-4000:]
    return proc
