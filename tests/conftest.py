# NOTE: do NOT set XLA_FLAGS / device-count here — unit and smoke tests
# must see the single real CPU device. Multi-device tests spawn
# subprocesses with their own flags (test_ring.py, test_dryrun.py).


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
