# NOTE: do NOT set XLA_FLAGS / device-count here — unit and smoke tests
# must see the single real CPU device. Multi-device tests spawn
# subprocesses with their own flags (test_ring.py, test_dryrun.py).

import os


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # Fixed hypothesis profile (CI fast job + local runs): no deadline —
    # jit compiles inside property bodies blow any wall-clock budget —
    # and derandomized so every run draws the same examples (the serve
    # property tests must be reproducible across CI shards). Override
    # with HYPOTHESIS_PROFILE=default for exploratory fuzzing.
    try:
        from hypothesis import settings
    except ImportError:
        return
    settings.register_profile("repro", deadline=None, derandomize=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro"))
