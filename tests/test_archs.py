"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions. Full configs are exercised only by the
dry-run (abstract, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.launch.api import get_api
from repro.models import transformer as tr
from repro.models.module import init_params
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step

B, S = 2, 16

DECODER_ARCHS = [a for a in ARCH_IDS if a != "whisper-tiny"]


def _setup(arch):
    cfg = get_smoke(arch)
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    return cfg, api, params


def _batch(cfg, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32
        )
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
    return batch


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg, api, params = _setup(arch)
    batch = _batch(cfg)
    logits, metrics = tr.forward(params, batch["tokens"], cfg,
                                 positions=batch.get("positions"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg, api, params = _setup(arch)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, oc, loss_fn=api.loss_fn)
    opt = init_train_state(params)
    batch = _batch(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-370m",
                                  "recurrentgemma-9b", "deepseek-v2-lite-16b"])
def test_decode_matches_forward_fp32(arch):
    cfg = get_smoke(arch).replace(dtype="float32")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = tr.decode_step(params, cache, tokens[:, t:t + 1],
                                   jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_prefill_then_decode_matches_forward():
    cfg = get_smoke("olmo-1b").replace(dtype="float32")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = tr.forward(params, tokens, cfg)
    # prefill first S-1, decode last token
    logits_p, cache = tr.prefill(params, tokens[:, :-1], cfg, max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, :-1]), rtol=2e-3, atol=2e-4
    )
    lg, _ = tr.decode_step(params, cache, tokens[:, -1:], jnp.int32(S - 1), cfg)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-4
    )


def test_whisper_full_stack():
    from repro.models import encdec as ed

    cfg = get_smoke("whisper-tiny")
    params = init_params(ed.encdec_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    frames = jnp.asarray(rng.standard_normal((B, 24, cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    memory = ed.encode(params, frames, cfg)
    assert memory.shape == (B, 24, cfg.d_model)
    logits = ed.decode_forward(params, tokens, memory, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    expect = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
        "mamba2-370m": (48, 1024, None, None, 0, 50_280),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151_936),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50_304),
        "qwen3-32b": (64, 5120, 64, 8, 25_600, 151_936),
        "granite-34b": (88, 6144, 48, 1, 24_576, 49_152),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51_865),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151_936),
        "deepseek-v2-lite-16b": (27, 2048, 16, None, 1408, 102_400),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29_568, 152_064),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        if h is not None:
            assert cfg.num_heads == h, arch
        if kv is not None:
            assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # family extensions
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.mla.kv_lora == 512 and ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared == 2
    assert get_config("mamba2-370m").ssm.d_state == 128
    assert get_config("qwen2-vl-72b").mrope_sections == (16, 24, 24)
    assert get_config("recurrentgemma-9b").hybrid.window == 2048
