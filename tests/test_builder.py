"""GraphBuilder registry + DigcSpec semantics and batched parity.

For every registered builder, (B, N, D) input must reproduce the
stacked per-image (N, D) outputs — exact for the exact tiers
(reference / blocked / pallas-interpret), neighbor-set recall for the
approximate strategies (cluster / axial) — including the dilation > 1
and pos_bias paths where the builder supports them. The ring builder is
covered in tests/test_ring.py (needs a multi-device subprocess).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    BIG,
    DigcSpec,
    available_impls,
    digc,
    get_builder,
    list_builders,
)

EXACT = ("reference", "blocked", "pallas")
APPROX = ("cluster", "axial")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _set_recall(a, b):
    a, b = np.asarray(a), np.asarray(b)
    a = a.reshape(-1, a.shape[-1])
    b = b.reshape(-1, b.shape[-1])
    hits = sum(len(set(a[i]) & set(b[i])) for i in range(a.shape[0]))
    return hits / a.size


# ---------------------------------------------------------------------------
# Registry semantics


def test_registry_has_all_six_builders():
    assert set(available_impls()) == {
        "reference", "blocked", "pallas", "ring", "cluster", "axial",
    }
    for b in list_builders():
        assert callable(b.build), b.name


def test_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown DIGC impl"):
        get_builder("fpga")


def test_unknown_knob_for_builder_raises():
    """A stray block_m on the reference path must raise, not be dropped."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 10, 4)
    with pytest.raises(ValueError, match="does not accept knob"):
        digc(x, k=3, impl="reference", block_m=16)
    with pytest.raises(ValueError, match="does not accept knob"):
        digc(x, k=3, impl="blocked", n_clusters=8)
    with pytest.raises(ValueError, match="does not accept knob"):
        digc(x, spec=DigcSpec(impl="pallas", k=3, n_probe=2))


def test_unknown_knob_name_raises():
    rng = np.random.default_rng(1)
    x = _rand(rng, 10, 4)
    with pytest.raises(ValueError, match="unknown DIGC knob"):
        digc(x, k=3, block_q=7)


def test_unsupported_capability_raises():
    rng = np.random.default_rng(2)
    x = _rand(rng, 16, 4)
    with pytest.raises(ValueError, match="causal"):
        digc(x, k=3, impl="cluster", causal=True)
    with pytest.raises(ValueError, match="pos_bias"):
        digc(x, k=3, impl="axial", pos_bias=jnp.zeros((16, 16)))


def test_spec_overrides_and_knobs():
    spec = DigcSpec(impl="blocked", k=4, block_m=32)
    assert spec.knobs() == {"block_m": 32}
    rng = np.random.default_rng(3)
    x = _rand(rng, 20, 6)
    i_spec = digc(x, spec=spec)
    i_override = digc(x, spec=spec, k=2)  # keyword overrides the spec
    assert i_spec.shape == (20, 4)
    assert i_override.shape == (20, 2)


def test_missing_k_raises():
    rng = np.random.default_rng(4)
    x = _rand(rng, 10, 4)
    with pytest.raises(TypeError, match="requires k"):
        digc(x)


# ---------------------------------------------------------------------------
# Batched parity: (B, N, D) == stacked per-image (N, D)


@pytest.mark.parametrize("impl", EXACT)
@pytest.mark.parametrize("k,dil", [(4, 1), (3, 2)])
def test_batched_parity_exact(impl, k, dil):
    rng = np.random.default_rng(k * 10 + dil)
    bsz, n, m, d = 3, 40, 64, 12
    x = _rand(rng, bsz, n, d)
    y = _rand(rng, bsz, m, d)
    spec = DigcSpec(impl=impl, k=k, dilation=dil)
    ib, db = digc(x, y, spec=spec, return_dists=True)
    assert ib.shape == (bsz, n, k) and db.shape == (bsz, n, k)
    for b in range(bsz):
        i1, d1 = digc(x[b], y[b], spec=spec, return_dists=True)
        np.testing.assert_array_equal(np.asarray(ib[b]), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(db[b]), np.asarray(d1),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("impl", EXACT)
def test_batched_parity_pos_bias(impl):
    rng = np.random.default_rng(7)
    bsz, n, m, d = 2, 24, 48, 8
    x = _rand(rng, bsz, n, d)
    y = _rand(rng, bsz, m, d)
    p = _rand(rng, bsz, n, m) * 0.3
    spec = DigcSpec(impl=impl, k=4)
    ib = digc(x, y, spec=spec, pos_bias=p)
    for b in range(bsz):
        i1 = digc(x[b], y[b], spec=spec, pos_bias=p[b])
        np.testing.assert_array_equal(np.asarray(ib[b]), np.asarray(i1))


def test_batched_shared_pos_bias_broadcasts():
    """A single (N, M) pos_bias applies to every image in the batch."""
    rng = np.random.default_rng(8)
    x = _rand(rng, 2, 20, 6)
    p = jnp.zeros((20, 20)).at[:, 0].set(-1e6)
    ib = digc(x, k=3, impl="blocked", pos_bias=p)
    assert bool(jnp.all(ib[:, :, 0] == 0))


@pytest.mark.parametrize("dil", [1, 2])
def test_batched_parity_cluster(dil):
    rng = np.random.default_rng(11)
    bsz, n, d, k = 3, 96, 16, 4
    x = _rand(rng, bsz, n, d)
    spec = DigcSpec(impl="cluster", k=k, dilation=dil,
                    n_clusters=6, n_probe=6, capacity_factor=8.0)
    ib = digc(x, spec=spec)
    assert ib.shape == (bsz, n, k)
    for b in range(bsz):
        i1 = digc(x[b], spec=spec)
        assert _set_recall(ib[b], i1) >= 0.98, b


@pytest.mark.parametrize("dil", [1, 2])
def test_batched_parity_axial(dil):
    rng = np.random.default_rng(12)
    bsz, h, w, d, k = 3, 8, 8, 10, 3
    x = _rand(rng, bsz, h * w, d)
    spec = DigcSpec(impl="axial", k=k, dilation=dil, grid_h=h, grid_w=w)
    ib = digc(x, spec=spec)
    assert ib.shape == (bsz, h * w, k)
    for b in range(bsz):
        i1 = digc(x[b], spec=spec)
        assert _set_recall(ib[b], i1) >= 0.99, b


def test_axial_infers_square_grid():
    rng = np.random.default_rng(13)
    x = _rand(rng, 49, 8)
    i_inferred = digc(x, k=3, impl="axial")
    i_explicit = digc(x, k=3, impl="axial", grid_h=7, grid_w=7)
    np.testing.assert_array_equal(np.asarray(i_inferred), np.asarray(i_explicit))


def test_axial_infers_partial_grid():
    """A non-square grid is recoverable from either given dimension."""
    rng = np.random.default_rng(18)
    x = _rand(rng, 32, 8)  # 4 x 8 grid
    i_full = digc(x, k=3, impl="axial", grid_h=4, grid_w=8)
    i_h = digc(x, k=3, impl="axial", grid_h=4)
    i_w = digc(x, k=3, impl="axial", grid_w=8)
    np.testing.assert_array_equal(np.asarray(i_full), np.asarray(i_h))
    np.testing.assert_array_equal(np.asarray(i_full), np.asarray(i_w))
    with pytest.raises(ValueError, match="does not match"):
        digc(x, k=3, impl="axial", grid_h=5)


def test_axial_pooled_conodes_falls_back_exact():
    """M != N (pooled co-node stage): axial resolves via the blocked tier."""
    rng = np.random.default_rng(14)
    x = _rand(rng, 2, 36, 8)
    y = _rand(rng, 2, 9, 8)
    i_ax = digc(x, y, k=3, impl="axial")
    i_ref = digc(x, y, k=3, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_ax), np.asarray(i_ref))


def test_axial_explicit_conodes_falls_back_exact():
    """Axial is a self-graph construction: any explicit y — even the
    very same array as x — resolves via the blocked tier, so eager and
    jitted calls agree (under jit x and y are always distinct tracers).
    The self-graph spelling is y=None."""
    rng = np.random.default_rng(19)
    for shape in ((36, 8), (2, 36, 8)):  # single and batched
        x = _rand(rng, *shape)
        y = _rand(rng, *shape)
        for cons in (y, x):
            i_ax = digc(x, cons, k=3, impl="axial")
            i_ref = digc(x, cons, k=3, impl="reference")
            np.testing.assert_array_equal(np.asarray(i_ax), np.asarray(i_ref))
        # eager/jit consistency for the explicit-y spelling
        f = jax.jit(lambda a, b: digc(a, b, k=3, impl="axial"))
        np.testing.assert_array_equal(
            np.asarray(f(x, x)), np.asarray(digc(x, x, k=3, impl="axial"))
        )
        # self-graph (y=None) engages the axial construction: differs
        # from exact KNN on random features
        i_self = digc(x, k=3, impl="axial")
        i_exact = digc(x, k=3, impl="reference")
        assert not np.array_equal(np.asarray(i_self), np.asarray(i_exact))


def test_vig_pyramid_explicit_axial_spec():
    """A user axial spec with stale grid knobs must not blow up on
    pyramid stages — the model re-derives the grid per stage."""
    from repro.core import DigcSpec
    from repro.models import vig
    from repro.models.module import init_params

    cfg = vig.VIG_VARIANTS["vig_ti_pyr"].replace(
        image_size=32, embed_dims=(16, 24, 32, 48), depths=(1, 1, 1, 1),
        num_classes=5, k=3,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    spec = DigcSpec(impl="axial", grid_h=56, grid_w=56)  # stale on purpose
    out = vig.vig_forward(params, imgs, cfg, digc_impl=spec)
    assert out.shape == (1, 5)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_spec_without_k_raises_but_inherits_in_model():
    rng = np.random.default_rng(20)
    x = _rand(rng, 12, 4)
    with pytest.raises(TypeError, match="k is unset"):
        digc(x, spec=DigcSpec(impl="blocked"))
    from repro.models.vig import VIG_VARIANTS, resolve_digc_spec

    cfg = VIG_VARIANTS["vig_ti_iso"].replace(k=5)
    assert resolve_digc_spec(cfg, DigcSpec(impl="pallas")).k == 5
    assert resolve_digc_spec(cfg, DigcSpec(impl="pallas", k=3)).k == 3
    assert resolve_digc_spec(cfg, None).k == 5


def test_pallas_batched_b1_b3_vs_reference():
    """Acceptance: the kernel's batch grid dim for B in {1, 3}."""
    rng = np.random.default_rng(15)
    for bsz in (1, 3):
        x = _rand(rng, bsz, 33, 17)  # awkward shapes exercise padding
        y = _rand(rng, bsz, 70, 17)
        i_ref = digc(x, y, k=5, impl="reference")
        i_pl = digc(x, y, k=5, impl="pallas", block_n=16, block_m=128)
        np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pl))


def test_batched_causal():
    rng = np.random.default_rng(16)
    x = _rand(rng, 2, 32, 8)
    for impl in EXACT:
        i, d = digc(x, k=4, causal=True, impl=impl, return_dists=True)
        valid = np.asarray(d) < BIG / 2
        rows = np.arange(32)[None, :, None]
        assert np.all(np.where(valid, np.asarray(i) <= rows, True)), impl
        assert np.array_equal(
            valid.sum(-1),
            np.broadcast_to(np.minimum(np.arange(32) + 1, 4), (2, 32)),
        ), impl


def test_builder_aggregate_hook():
    """Builders with a fused aggregation must match the generic one."""
    from repro.core.graph import mr_aggregate

    rng = np.random.default_rng(17)
    x = _rand(rng, 2, 40, 12)
    y = _rand(rng, 2, 60, 12)
    idx = jnp.asarray(rng.integers(0, 60, (2, 40, 5)), jnp.int32)
    pallas = get_builder("pallas")
    assert pallas.aggregate is not None
    np.testing.assert_allclose(
        np.asarray(pallas.aggregate(x, y, idx)),
        np.asarray(mr_aggregate(x, y, idx)),
        rtol=1e-5, atol=1e-5,
    )
    assert get_builder("blocked").aggregate is None
