"""DIGC correctness: reference vs blocked streaming, semantics, properties."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import BIG, digc, digc_blocked, digc_reference, pairwise_sq_dists


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def assert_same_valid(i_a, d_a, i_b, d_b):
    """Indices must agree wherever entries are valid; validity must agree."""
    va = np.asarray(d_a) < BIG / 2
    vb = np.asarray(d_b) < BIG / 2
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(
        np.where(va, np.asarray(i_a), -1), np.where(vb, np.asarray(i_b), -1)
    )
    np.testing.assert_allclose(
        np.where(va, np.asarray(d_a), 0.0),
        np.where(vb, np.asarray(d_b), 0.0),
        rtol=1e-5,
        atol=1e-4,
    )


@pytest.mark.parametrize("n,m,d", [(16, 16, 8), (64, 33, 17), (100, 128, 48), (7, 200, 3)])
@pytest.mark.parametrize("k,dil", [(1, 1), (4, 1), (3, 2)])
def test_blocked_matches_reference(n, m, d, k, dil):
    if k * dil > m:
        pytest.skip("kd > M")
    rng = np.random.default_rng(n * 1000 + m)
    x, y = _rand(rng, n, d), _rand(rng, m, d)
    i_r, d_r = digc_reference(x, y, k=k, dilation=dil, return_dists=True)
    i_b, d_b = digc_blocked(x, y, k=k, dilation=dil, block_m=32, return_dists=True)
    assert_same_valid(i_r, d_r, i_b, d_b)


@pytest.mark.parametrize("block_m", [8, 16, 64, 256, 1024])
def test_blocked_block_size_invariance(block_m):
    rng = np.random.default_rng(0)
    x, y = _rand(rng, 50, 12), _rand(rng, 70, 12)
    i_r = digc_reference(x, y, k=5)
    i_b = digc_blocked(x, y, k=5, block_m=block_m)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_b))


def test_pos_bias_changes_selection():
    rng = np.random.default_rng(1)
    x, y = _rand(rng, 20, 8), _rand(rng, 30, 8)
    p = jnp.zeros((20, 30)).at[:, 0].set(-1e6)  # co-node 0 irresistibly close
    i_b = digc_blocked(x, y, k=3, pos_bias=p, block_m=16)
    assert bool(jnp.all(i_b[:, 0] == 0))


def test_pos_bias_agreement():
    rng = np.random.default_rng(2)
    x, y = _rand(rng, 40, 8), _rand(rng, 50, 8)
    p = _rand(rng, 40, 50) * 0.3
    i_r, d_r = digc_reference(x, y, k=4, pos_bias=p, return_dists=True)
    i_b, d_b = digc_blocked(x, y, k=4, pos_bias=p, block_m=16, return_dists=True)
    assert_same_valid(i_r, d_r, i_b, d_b)


def test_causal_masks_future():
    rng = np.random.default_rng(3)
    x = _rand(rng, 32, 8)
    for impl in ("reference", "blocked"):
        i, d = digc(x, k=4, causal=True, impl=impl, return_dists=True)
        valid = np.asarray(d) < BIG / 2
        rows = np.arange(32)[:, None]
        assert np.all(np.where(valid, np.asarray(i) <= rows, True))
        # row r has min(r+1, k) valid entries
        assert np.array_equal(valid.sum(1), np.minimum(np.arange(32) + 1, 4))


def test_self_graph_nearest_is_self():
    rng = np.random.default_rng(4)
    x = _rand(rng, 30, 16)
    i = digc(x, k=3, impl="blocked")
    np.testing.assert_array_equal(np.asarray(i[:, 0]), np.arange(30))


def test_dilation_subsamples_sorted_list():
    rng = np.random.default_rng(5)
    x, y = _rand(rng, 25, 8), _rand(rng, 60, 8)
    i_full, d_full = digc_reference(x, y, k=8, dilation=1, return_dists=True)
    i_dil = digc_reference(x, y, k=4, dilation=2)
    np.testing.assert_array_equal(np.asarray(i_full[:, ::2][:, :4]), np.asarray(i_dil))


def test_kd_exceeds_m_raises():
    rng = np.random.default_rng(6)
    x, y = _rand(rng, 10, 4), _rand(rng, 5, 4)
    with pytest.raises(ValueError):
        digc_reference(x, y, k=3, dilation=2)
    with pytest.raises(ValueError):
        digc_blocked(x, y, k=6)


def test_distances_sorted_ascending():
    rng = np.random.default_rng(7)
    x, y = _rand(rng, 40, 8), _rand(rng, 90, 8)
    _, d = digc_blocked(x, y, k=10, return_dists=True, block_m=32)
    d = np.asarray(d)
    assert np.all(np.diff(d, axis=1) >= -1e-5)


def test_bf16_inputs():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.bfloat16)
    y = jnp.asarray(rng.standard_normal((48, 16)), jnp.bfloat16)
    i_r = digc_reference(x, y, k=4)
    i_b = digc_blocked(x, y, k=4, block_m=16)
    # fp32 compute inside: identical results
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_b))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 40),
    m=st.integers(2, 60),
    d=st.integers(1, 24),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_blocked_equals_reference(n, m, d, k, seed):
    if k > m:
        k = m
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, n, d), _rand(rng, m, d)
    i_r, d_r = digc_reference(x, y, k=k, return_dists=True)
    i_b, d_b = digc_blocked(x, y, k=k, block_m=16, return_dists=True)
    assert_same_valid(i_r, d_r, i_b, d_b)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 8))
def test_property_neighbors_are_true_knn(seed, k):
    """The returned set must equal the brute-force numpy KNN set."""
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, 20, 6), _rand(rng, 30, 6)
    idx = np.asarray(digc_blocked(x, y, k=k, block_m=8))
    d = np.asarray(pairwise_sq_dists(x, y))
    brute = np.argsort(d, axis=1, kind="stable")[:, :k]
    # compare as sets per row with distance multiset (ties tolerated)
    for r in range(20):
        np.testing.assert_allclose(
            np.sort(d[r, idx[r]]), np.sort(d[r, brute[r]]), rtol=1e-5, atol=1e-5
        )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_permutation_equivariance(seed):
    """Permuting co-nodes permutes indices: idx' = perm^{-1} applied."""
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, 15, 5), _rand(rng, 25, 5)
    perm = rng.permutation(25)
    y_p = y[perm]
    i0, d0 = digc_blocked(x, y, k=3, return_dists=True, block_m=8)
    i1, d1 = digc_blocked(x, y_p, k=3, return_dists=True, block_m=8)
    # distances invariant under co-node permutation
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5, atol=1e-5)
    # mapped indices point at identical feature rows
    np.testing.assert_allclose(
        np.asarray(y)[np.asarray(i0)], np.asarray(y_p)[np.asarray(i1)], rtol=1e-6
    )


def test_jit_blocked():
    rng = np.random.default_rng(9)
    x, y = _rand(rng, 32, 8), _rand(rng, 64, 8)
    f = jax.jit(lambda a, b: digc_blocked(a, b, k=4))
    np.testing.assert_array_equal(
        np.asarray(f(x, y)), np.asarray(digc_reference(x, y, k=4))
    )
