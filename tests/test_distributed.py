"""Multi-device tests (subprocess with 8 host devices): MoE expert
parallelism vs dense reference, pipeline parallelism vs sequential,
int8 ring all-reduce vs psum, FSDP sharding rules.

Runs in the fast tier-1 job: with JAX_PLATFORMS=cpu pinned in the
subprocess env the whole suite is seconds, not minutes (the old slow
marker predated the pin, when device discovery alone took ~30s)."""

from _subproc import run_snippet


def _run(snippet: str, devices: int = 8) -> str:
    return run_snippet(snippet, devices=devices, timeout=900).stdout


def test_moe_expert_parallel_matches_dense():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_smoke
        from repro.models.moe import moe_spec, moe_apply, _dense_moe
        from repro.models.module import init_params, use_mesh
        from repro.launch.mesh import make_mesh

        cfg = get_smoke("qwen3-moe-235b-a22b").replace(dtype="float32")
        # capacity high enough that nothing drops -> exact equality
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

        ref, mref = _dense_moe(params, x, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            out, m = jax.jit(lambda p, x: moe_apply(p, x, cfg, mesh=mesh))(params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        assert float(m["moe_drop_frac"]) == 0.0
        print("MOE_EP_OK", err)
        """
    )
    assert "MOE_EP_OK" in out


def test_moe_capacity_drops_tokens():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_smoke
        from repro.models.moe import moe_spec, moe_apply
        from repro.models.module import init_params, use_mesh
        from repro.launch.mesh import make_mesh

        cfg = get_smoke("qwen3-moe-235b-a22b").replace(dtype="float32")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
        params = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            out, m = jax.jit(lambda p, x: moe_apply(p, x, cfg, mesh=mesh))(params, x)
        drop = float(m["moe_drop_frac"])
        assert 0.0 < drop < 1.0, drop
        assert bool(jnp.all(jnp.isfinite(out)))
        print("MOE_DROP_OK", drop)
        """
    )
    assert "MOE_DROP_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax import lax
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh

        L, D, B = 8, 16, 12
        rng = jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (L, D, D)) * 0.2
        b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(2), (B, D))

        def layer_fn(lp, h):
            wi, bi = lp
            return jnp.tanh(h @ wi + bi)

        def seq(x):
            def f(c, lp):
                return layer_fn(lp, c), None
            out, _ = lax.scan(f, x, (w, b))
            return out

        ref = seq(x)
        mesh = make_mesh((4,), ("stage",))
        out = pipeline_apply(layer_fn, (w, b), x, mesh=mesh, num_microbatches=3)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
        """
    )
    assert "PIPELINE_OK" in out


def test_int8_ring_allreduce_close_to_psum():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.compression import compressed_allreduce_tree
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("pod",))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal(17), jnp.float32)}
        summed = compressed_allreduce_tree(g, mesh, axis_name="pod")
        # every device holds identical g -> sum = 8 * g
        for k in g:
            ref = 8 * np.asarray(g[k])
            got = np.asarray(summed[k])
            rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
            assert rel < 0.02, (k, rel)  # int8 quantization noise bound
        print("COMPRESS_OK")
        """
    )
    assert "COMPRESS_OK" in out


def test_fsdp_param_sharding_rules():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.models.module import make_shardings, abstract_params
        from repro.models import transformer as tr

        cfg = get_smoke("qwen3-32b")
        spec = tr.param_spec(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        sh = make_shardings(spec, mesh)
        # embedding (vocab, embed): vocab over model, embed over data
        emb = sh["embed"]["tokens"].spec
        assert tuple(emb) == ("model", "data"), emb
        # attn wq stacked (L, d, H, dh): embed over data, heads over model
        wq = sh["layers"]["mix"]["wq"].spec
        assert tuple(wq) == (None, "data", "model", None), wq
        # kv heads (2) not divisible by model=4 -> dropped
        wk = sh["layers"]["mix"]["wk"].spec
        assert tuple(wk) == (None, "data", None, None), wk
        print("SHARDING_OK")
        """
    )
    assert "SHARDING_OK" in out


def test_dryrun_smoke_cell():
    """End-to-end dry-run machinery on a small mesh + smoke config."""
    out = _run(
        """
        import jax
        import repro.configs as C
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import make_cell
        from repro.launch import roofline as rl
        from repro.models.module import use_mesh

        C.SHAPES["t"] = (64, 8, "train")
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cell = make_cell("olmo-1b", "t", mesh, cfg=get_smoke("olmo-1b"))
        with use_mesh(mesh):
            lowered = jax.jit(cell["fn"], in_shardings=cell["in_shardings"]).lower(*cell["args"])
            compiled = lowered.compile()
        roof = rl.analyze(compiled)
        assert roof.flops > 0 and roof.hbm_bytes > 0
        assert roof.collective_bytes > 0  # FSDP must produce collectives
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print("DRYRUN_OK", roof.bound)
        """
    )
    assert "DRYRUN_OK" in out
