"""Structural dry-run coverage: input_specs for all 40 (arch x shape)
cells build correct abstract args + shardings on the production meshes
(spec construction only — compiles happen in launch/dryrun.py).
Fast-tier: the pinned-CPU subprocess finishes in ~2s."""

from _subproc import run_snippet


def test_all_cells_build_specs_on_production_meshes():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config
        from repro.launch.mesh import make_production_mesh
        from repro.launch.specs import make_cell

        built = skipped = 0
        for multi_pod in (False, True):
            mesh = make_production_mesh(multi_pod=multi_pod)
            assert mesh.devices.size == (512 if multi_pod else 256)
            for arch in ARCH_IDS:
                cfg = get_config(arch)
                for shape in SHAPES:
                    ok, why = cell_supported(cfg, shape)
                    if not ok:
                        skipped += 1
                        continue
                    cell = make_cell(arch, shape, mesh)
                    args, sh = cell["args"], cell["in_shardings"]
                    # structures must match and every leaf needs a sharding
                    la = jax.tree_util.tree_structure(args)
                    ls = jax.tree_util.tree_structure(sh)
                    assert la == ls, (arch, shape, la, ls)
                    for leaf, s in zip(jax.tree_util.tree_leaves(args),
                                       jax.tree_util.tree_leaves(sh)):
                        assert hasattr(leaf, "shape"), (arch, shape)
                        assert s.mesh.devices.size == mesh.devices.size
                        # sharding must divide the array shape
                        _ = s.shard_shape(leaf.shape)
                    built += 1
        assert built == 64 and skipped == 16, (built, skipped)
        print("SPECS_OK", built, skipped)
        """
    # devices=None: the snippet sets its own 512-device flag before
    # importing jax
    proc = run_snippet(code, devices=None, timeout=900)
    assert "SPECS_OK 64 16" in proc.stdout
