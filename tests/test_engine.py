"""Streaming-engine correctness: merge strategies, two-level tiling,
packed keys, and the DigcCache.

The exact merges ("select", "topk") must match the reference oracle
bit-for-bit on indices; the packed merge is tie-tolerant (distances
truncated by ``idx_bits`` mantissa bits) and is validated semantically:
the distances *implied by its chosen indices* must match the oracle's
distances within the truncation tolerance. Property tests run under the
shared hypothesis shim (skip cleanly when hypothesis is absent)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import BIG, DigcSpec, digc, digc_reference, pairwise_sq_dists
from repro.core.digc import merge_topk
from repro.core.engine import (
    DigcCache,
    merge_packed_xla,
    select_topkd,
    stream_topk,
)
from repro.core.packedkey import idx_bits_for, pack_keys, unpack_keys


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def assert_same_valid(i_a, d_a, i_b, d_b, rtol=1e-5, atol=1e-4):
    va = np.asarray(d_a) < BIG / 2
    vb = np.asarray(d_b) < BIG / 2
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(
        np.where(va, np.asarray(i_a), -1), np.where(vb, np.asarray(i_b), -1)
    )
    np.testing.assert_allclose(
        np.where(va, np.asarray(d_a), 0.0), np.where(vb, np.asarray(d_b), 0.0),
        rtol=rtol, atol=atol,
    )


# ---------------------------------------------------------------------------
# select_topkd: the grouped LSM


@pytest.mark.parametrize("group_w", [32, 64, 48])
@pytest.mark.parametrize("w,kd", [(64, 4), (200, 9), (1000, 16), (7, 7)])
def test_select_topkd_matches_lax_topk(w, kd, group_w):
    rng = np.random.default_rng(w * 31 + kd)
    d = _rand(rng, 2, 37, w) * 10
    vals, cols = select_topkd(d, kd, group_w=group_w)
    neg, ref_cols = jax.lax.top_k(-d, kd)
    np.testing.assert_array_equal(np.asarray(cols), np.asarray(ref_cols))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(-neg))


@pytest.mark.parametrize("group_w", [32, 64])
def test_select_topkd_ties_lowest_column(group_w):
    d = jnp.asarray([[3.0, 1.0, 1.0, 2.0, 1.0]])
    vals, cols = select_topkd(d, 4, group_w=group_w)
    np.testing.assert_array_equal(np.asarray(cols[0]), [1, 2, 4, 3])


def test_select_topkd_w64_ties_across_mask_words():
    """Equal values on both sides of the 32-lane word boundary of one
    64-lane group: extraction order must stay lowest-column-first and
    the second mask word must retire lanes 32..63 correctly."""
    row = np.full(64, 50.0, np.float32)
    row[[2, 34, 40]] = 1.0  # tie triple spanning both words
    row[[5, 63]] = 2.0
    vals, cols = select_topkd(jnp.asarray(row[None]), 5, group_w=64)
    np.testing.assert_array_equal(np.asarray(cols[0]), [2, 34, 40, 5, 63])
    np.testing.assert_array_equal(
        np.asarray(vals[0]), [1.0, 1.0, 1.0, 2.0, 2.0]
    )


def test_engine_group_w_knob_exact_end_to_end():
    """blocked merge="select" with group_w=64 == reference, through the
    registry (DigcSpec knob) and under query tiling."""
    rng = np.random.default_rng(77)
    x, y = _rand(rng, 2, 50, 12), _rand(rng, 2, 150, 12)
    i_r = digc(x, y, k=5, impl="reference")
    i_w = digc(x, y, k=5, impl="blocked", merge="select", group_w=64,
               block_n=16, block_m=96)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_w))
    with pytest.raises(ValueError, match="group_w"):
        digc(x, y, k=5, impl="blocked", group_w=128)


def test_select_topkd_short_rows_pad_big():
    """Rows with fewer candidates than kd pad with BIG lanes."""
    d = jnp.asarray([[5.0, 4.0]])
    vals, _ = select_topkd(d, 4)
    v = np.asarray(vals[0])
    assert list(v[:2]) == [4.0, 5.0]
    assert np.all(v[2:] >= BIG / 2)


# ---------------------------------------------------------------------------
# Packed keys: pack/unpack + XLA packed merge vs merge_topk


@pytest.mark.parametrize("m", [8, 196, 3136, 1 << 20])
def test_pack_unpack_roundtrip_order(m):
    rng = np.random.default_rng(m % 97)
    bits = idx_bits_for(m)
    d = jnp.asarray(rng.standard_normal(256).astype(np.float32)) * 50
    idx = jnp.asarray(rng.integers(0, m, 256), jnp.int32)
    keys = pack_keys(d, idx, bits)
    d2, i2 = unpack_keys(keys, bits)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    # truncation error bounded by 2^-(23 - idx_bits) relative
    np.testing.assert_allclose(
        np.asarray(d2), np.asarray(d), rtol=2.0 ** -(23 - bits) * 1.01,
        atol=1e-30,
    )
    # packed integer order == distance order where distances differ
    order_keys = np.argsort(np.asarray(keys), kind="stable")
    d_sorted = np.asarray(d)[order_keys]
    assert np.all(np.diff(d_sorted) >= -np.abs(d_sorted[1:]) * 2.0 ** -(23 - bits) * 2)


def test_idx_bits_cap():
    with pytest.raises(ValueError, match="at most"):
        idx_bits_for((1 << 20) + 1)


@settings(max_examples=30, deadline=None)
@given(
    kd=st.integers(1, 8),
    bw=st.integers(1, 40),
    m=st.integers(41, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_packed_merge_matches_merge_topk(kd, bw, m, seed):
    """Packed-key merge == merge_topk bit-for-bit on idx (and within fp
    tolerance on dist) whenever distances survive truncation exactly —
    here: distinct small integers, exactly representable in the top
    23 - idx_bits mantissa bits."""
    rng = np.random.default_rng(seed)
    bits = idx_bits_for(m)
    n = 5
    width = kd + bw
    # distinct integer distances < 2^10: exact under <= 13 dropped bits
    vals = rng.permutation(1 << 10)[: n * width].astype(np.float32)
    cand_d = jnp.asarray(vals.reshape(n, width))
    cand_i = jnp.asarray(rng.integers(0, m, (n, width)), jnp.int32)
    run_d, blk_d = cand_d[:, :kd], cand_d[:, kd:]
    run_i, blk_i = cand_i[:, :kd], cand_i[:, kd:]
    # merge_topk expects a sorted running list (engine invariant)
    order = jnp.argsort(run_d, axis=1)
    run_d = jnp.take_along_axis(run_d, order, axis=1)
    run_i = jnp.take_along_axis(run_i, order, axis=1)

    ref_d, ref_i = merge_topk(run_d, run_i, blk_d, blk_i, kd)
    keys = merge_packed_xla(
        pack_keys(run_d, run_i, bits), pack_keys(blk_d, blk_i, bits), kd
    )
    got_d, got_i = unpack_keys(keys, bits)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(ref_d), rtol=2.0 ** -(23 - bits) * 1.01
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 40),
    m=st.integers(4, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_select_merge_equals_reference(n, m, seed):
    """Engine select merge == reference, bit-for-bit idx, random floats."""
    rng = np.random.default_rng(seed)
    k = min(4, m)
    x, y = _rand(rng, n, 7), _rand(rng, m, 7)
    i_r, d_r = digc_reference(x, y, k=k, return_dists=True)
    i_s, d_s = digc(x, y, k=k, impl="blocked", merge="select", block_m=16,
                    return_dists=True)
    assert_same_valid(i_r, d_r, i_s, d_s)


# ---------------------------------------------------------------------------
# Full engine paths: merge strategies x tiling, ragged edges


@pytest.mark.parametrize("merge", ["select", "topk"])
@pytest.mark.parametrize("block_n,block_m", [(None, 16), (16, 32), (13, 17)])
def test_engine_exact_merges_match_reference(merge, block_n, block_m):
    rng = np.random.default_rng(hash((merge, block_n, block_m)) % 2**31)
    x, y = _rand(rng, 2, 50, 12), _rand(rng, 2, 70, 12)
    i_r, d_r = digc(x, y, k=5, impl="reference", return_dists=True)
    i_e, d_e = digc(x, y, k=5, impl="blocked", merge=merge,
                    block_n=block_n, block_m=block_m, return_dists=True)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_e))
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_e),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("merge", ["select", "topk", "packed"])
def test_engine_query_tiled_causal_ragged(merge):
    """causal masking with N % block_n != 0: global row offsets must
    stay correct across query tiles."""
    rng = np.random.default_rng(21)
    x = _rand(rng, 2, 37, 8)  # 37 % 16 != 0
    i_r, d_r = digc(x, k=4, causal=True, impl="reference", return_dists=True)
    i_e, d_e = digc(x, k=4, causal=True, impl="blocked", merge=merge,
                    block_n=16, block_m=16, return_dists=True)
    va = np.asarray(d_r) < BIG / 2
    vb = np.asarray(d_e) < BIG / 2
    np.testing.assert_array_equal(va, vb)
    if merge == "packed":  # tie-tolerant: check implied distances
        np.testing.assert_allclose(
            np.where(vb, np.asarray(d_e), 0.0), np.where(va, np.asarray(d_r), 0.0),
            rtol=1e-3, atol=1e-3,
        )
    else:
        np.testing.assert_array_equal(
            np.where(va, np.asarray(i_r), -1), np.where(vb, np.asarray(i_e), -1)
        )


@pytest.mark.parametrize("merge", ["select", "topk"])
def test_engine_query_tiled_pos_bias_ragged(merge):
    rng = np.random.default_rng(22)
    x, y = _rand(rng, 2, 37, 8), _rand(rng, 2, 53, 8)
    p = _rand(rng, 2, 37, 53) * 0.3
    i_r = digc(x, y, k=4, impl="reference", pos_bias=p)
    i_e = digc(x, y, k=4, impl="blocked", merge=merge, pos_bias=p,
               block_n=16, block_m=16)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_e))


def test_engine_packed_full_path_tie_tolerant():
    """blocked merge="packed" vs reference: the distances implied by the
    chosen indices must match the oracle's within truncation tolerance
    (indices may differ only across truncation-ties)."""
    rng = np.random.default_rng(23)
    x, y = _rand(rng, 2, 40, 8), _rand(rng, 2, 70, 8)
    i_p, d_p = digc(x, y, k=5, impl="blocked", merge="packed", block_m=32,
                    return_dists=True)
    i_r, d_r = digc(x, y, k=5, impl="reference", return_dists=True)
    d_full = np.asarray(pairwise_sq_dists(x, y))
    implied = np.take_along_axis(d_full, np.asarray(i_p), axis=-1)
    bits = idx_bits_for(96)  # padded co-node count
    np.testing.assert_allclose(
        implied, np.asarray(d_r), rtol=2.0 ** -(23 - bits) * 4, atol=1e-3
    )


def test_engine_fuse_norms_and_bf16_tie_tolerant():
    rng = np.random.default_rng(24)
    x, y = _rand(rng, 2, 40, 16), _rand(rng, 2, 64, 16)
    i_r, d_r = digc(x, y, k=5, impl="reference", return_dists=True)
    d_full = np.asarray(pairwise_sq_dists(x, y))
    i_f, d_f = digc(x, y, k=5, impl="blocked", fuse_norms=True, block_m=32,
                    return_dists=True)
    implied = np.take_along_axis(d_full, np.asarray(i_f), axis=-1)
    np.testing.assert_allclose(implied, np.asarray(d_r), rtol=1e-5, atol=1e-4)
    i_b, _ = digc(x, y, k=5, impl="blocked", mxu_bf16=True, block_m=32,
                  return_dists=True)
    implied = np.take_along_axis(d_full, np.asarray(i_b), axis=-1)
    # bf16 contraction: ~8-bit mantissa on the cross term
    np.testing.assert_allclose(implied, np.asarray(d_r), rtol=0.1, atol=0.3)


def test_engine_dilation_through_spec():
    rng = np.random.default_rng(25)
    x = _rand(rng, 30, 8)
    spec = DigcSpec(impl="blocked", k=3, dilation=2, merge="select",
                    block_n=8, block_m=8)
    i_e = digc(x, spec=spec)
    i_r = digc(x, k=3, dilation=2, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_e))


def test_stream_topk_self_graph_shares_norms():
    """y=None (self-graph) must equal passing x explicitly as y."""
    rng = np.random.default_rng(26)
    x = _rand(rng, 2, 33, 8)
    d_a, i_a = stream_topk(x, None, kd=4, block_m=16)
    d_b, i_b = stream_topk(x, x, kd=4, block_m=16)
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))


def test_engine_unknown_merge_raises():
    rng = np.random.default_rng(27)
    x = _rand(rng, 10, 4)
    with pytest.raises(ValueError, match="unknown merge"):
        digc(x, k=3, impl="blocked", merge="bogus")


# ---------------------------------------------------------------------------
# DigcCache


def test_cache_norms_roundtrip_and_stats():
    rng = np.random.default_rng(28)
    y = _rand(rng, 2, 20, 6)
    cache = DigcCache()
    sq1 = cache.norms("gallery-v1", y)
    sq2 = cache.norms("gallery-v1", y)
    assert cache.stats()["hits"] == 1
    np.testing.assert_array_equal(np.asarray(sq1), np.asarray(sq2))
    np.testing.assert_allclose(
        np.asarray(sq1), np.asarray(jnp.sum(y * y, -1)), rtol=1e-6
    )


def test_cache_bypassed_under_jit():
    """Tracing must never read or write the cache (stale constants)."""
    cache = DigcCache()

    @jax.jit
    def f(y):
        return cache.norms("k", y)

    rng = np.random.default_rng(29)
    y1, y2 = _rand(rng, 4, 3), _rand(rng, 4, 3)
    np.testing.assert_allclose(
        np.asarray(f(y1)), np.asarray(jnp.sum(y1 * y1, -1)), rtol=1e-6
    )
    # second call with different data: a cached constant would be wrong
    np.testing.assert_allclose(
        np.asarray(f(y2)), np.asarray(jnp.sum(y2 * y2, -1)), rtol=1e-6
    )
    assert cache.stats()["entries"] == 0


def test_cache_cluster_warm_start_recall():
    """Warm-started cluster construction stays at cold-start recall."""
    from repro.core.strategies import recall_vs_exact

    rng = np.random.default_rng(30)
    x = _rand(rng, 2, 128, 16)
    cache = DigcCache()
    spec = DigcSpec(impl="cluster", k=4, n_clusters=8, n_probe=8,
                    capacity_factor=8.0)
    i_cold = digc(x, spec=spec, cache=cache, cache_key="layer0")
    assert cache.stats()["entries"] == 1
    i_warm = digc(x, spec=spec, cache=cache, cache_key="layer0")
    assert cache.stats()["hits"] >= 1
    # full probe + ample capacity: both must be exact
    assert recall_vs_exact(x, x, i_cold, 4) == 1.0
    assert recall_vs_exact(x, x, i_warm, 4) == 1.0


def test_cache_eviction_bounded():
    cache = DigcCache(max_entries=4)
    for i in range(10):
        cache.put("sq_y", f"k{i}", jnp.zeros((3,)))
    assert cache.stats()["entries"] <= 4


def test_vig_serve_engine_persists_state():
    """VigServeEngine (jit mode, the default): the cluster tier serves
    through the compiled forward with functional DigcState carried
    across requests — no eager fallback, no DigcCache involvement."""
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigServeEngine

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3,
        digc_impl="cluster",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    eng = VigServeEngine(cfg, params, autotune=False)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    out = eng.infer(imgs)
    assert out.shape == (2, 3) and bool(jnp.all(jnp.isfinite(out)))
    eng.infer(imgs)
    s = eng.stats()
    assert s["requests_served"] == 4 and s["mode"] == "jit"
    # 2 blocks x 2 requests threaded the stage-0 state entry 4 times
    # (layer 2 warm-starts from layer 1, request 2 from request 1) ...
    assert s["digc_state"][2]["stage0"] == 4
    # ... and the host-side cache never engaged (fully jitted).
    assert s["digc_cache"]["hits"] == 0 and s["digc_cache"]["entries"] == 0


def test_vig_serve_engine_eager_shim_matches_jit():
    """The legacy eager DigcCache shim (mode="eager") stays available
    and parity-equal: same logits as the jitted functional-state path
    for the cluster tier (deterministic seed), and its DigcCache still
    engages across layers/requests."""
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigServeEngine

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3,
        digc_impl="cluster",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    jit_eng = VigServeEngine(cfg, params, autotune=False)
    eager_eng = VigServeEngine(cfg, params, autotune=False, mode="eager")
    out_jit = jit_eng.infer(imgs)
    out_eager = eager_eng.infer(imgs)
    # First request: both sides cold-start the same k-means (same seed,
    # same Lloyd schedule) — the shim and the pytree path must agree.
    np.testing.assert_allclose(
        np.asarray(out_jit), np.asarray(out_eager), rtol=1e-4, atol=1e-4
    )
    assert eager_eng.stats()["digc_cache"]["hits"] >= 1


def test_vig_serve_engine_autotunes_blocked(tmp_path):
    """warmup() now tunes a per-stage VigSchedule (host-keyed cache)."""
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigServeEngine

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(1,), num_classes=3, k=3,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    eng = VigServeEngine(cfg, params, batch=2,
                         tuner_path=tmp_path / "tune.json")
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    out = eng.infer(imgs)
    assert bool(jnp.all(jnp.isfinite(out)))
    st = eng.stats()
    assert [r["source"] for r in st["tuned"]] == ["measured"]
    assert len(st["schedule"]) == 1
    assert eng.schedule.spec_for(0).merge in ("select", "topk")


def test_vig_serve_engine_accepts_pretuned_schedule():
    """A VigSchedule passed as digc_impl must be used per stage (not
    collapsed to stage 0) and must never be clobbered by warmup."""
    from repro.core.tuner import VigSchedule
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigServeEngine

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(1,), num_classes=3, k=3,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    sched = VigSchedule(stages=(
        DigcSpec(impl="blocked", k=3, block_m=32, merge="topk"),
    ))
    eng = VigServeEngine(cfg, params, digc_impl=sched, batch=2)
    assert eng.schedule is sched
    assert eng.warmup() is None  # pre-tuned: nothing to measure
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    out = eng.infer(imgs)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert eng.schedule is sched  # infer() did not re-tune over it


def test_vig_serve_engine_eager_blocked_uses_tuned_schedule(tmp_path):
    """mode="eager" must serve the blocked tier through the same tuned
    schedule as jit mode (the modes differ only in state threading),
    so warmup's measurement is never wasted."""
    from repro.models import vig
    from repro.models.module import init_params
    from repro.serve.engine import VigServeEngine

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(1,), num_classes=3, k=3,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    eng = VigServeEngine(cfg, params, batch=2, mode="eager",
                         tuner_path=tmp_path / "tune.json")
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    eng.infer(imgs)
    assert eng.schedule is not None
    assert eng._jit_fwd[0] is eng.schedule  # serving through the schedule


def test_vig_forward_with_cache_matches_without():
    """The cache must not change blocked-tier results (exact path)."""
    from repro.models import vig
    from repro.models.module import init_params

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    cache = DigcCache()
    out_nc = vig.vig_forward(params, imgs, cfg)
    out_c = vig.vig_forward(params, imgs, cfg, cache=cache)
    np.testing.assert_allclose(
        np.asarray(out_nc), np.asarray(out_c), rtol=1e-5, atol=1e-5
    )
