"""Fault injection, quarantine, recovery, and the degradation ladder
(DESIGN.md §11).

The centerpiece is the **fault matrix**: one shared fault-free trace
(tenants A/B/C co-batched in a single bucket for four ticks) replayed
against engines with one injector armed at tenant B. For every row the
matrix asserts the full contract:

* the injector actually fired (``FaultPlan.counts()``),
* the engine detected it and reacted per the recovery state machine
  (quarantine + typed ``VigRequest.fault`` / cold-reset recovery /
  retry), with the counters in ``stats()`` to prove it,
* every co-batched *healthy* tenant's logits are **bit-identical** on
  CPU to the fault-free replay — a quarantined lane must vanish
  without a trace for its neighbors,
* the affected tenant's post-recovery requests match a cold B=1
  replay — recovery means *cold*, not garbage.

The single-bucket set ``(4,)`` keeps the compiled batch shape constant
whether or not a lane is quarantined, so per-row compute independence
makes the bitwise comparison meaningful.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import digc
from repro.core.builder import (
    DEGRADATION_LADDER,
    degraded_spec,
    fallback_chain,
    resolve_spec,
)
from repro.core.faults import SITES, FaultError, FaultInfo, FaultPlan
from repro.core.state import DigcState
from repro.models import vig
from repro.serve.engine import VigRequest, VigServeEngine

from test_serve_multitenant import (
    _StubProgramEngine,
    _image,
    _replay_tenant,
    _tiny_vig,
)

TENANTS = ("A", "B", "C")
TICKS = 4


def _trace_images(seed=0):
    rng = np.random.default_rng(seed)
    return {(tick, t): _image(rng)
            for tick in range(1, TICKS + 1) for t in TENANTS}


IMAGES = _trace_images()


def _run_trace(eng, images=IMAGES, ticks=TICKS, tenants=TENANTS):
    """Submit one request per (tick, tenant) and step once per tick;
    returns the request objects keyed by (tick, tenant)."""
    reqs = {}
    uid = 0
    for tick in range(1, ticks + 1):
        for t in tenants:
            r = VigRequest(uid=uid, image=images[(tick, t)], tenant=t)
            reqs[(tick, t)] = r
            eng.submit(r)
            uid += 1
        eng.step()
    return reqs


@pytest.fixture(scope="module")
def cluster_model():
    return _tiny_vig("cluster")


@pytest.fixture(scope="module")
def clean_trace(cluster_model):
    """The fault-free reference run every matrix row compares against."""
    cfg, params = cluster_model
    eng = VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                         buckets=(4,))
    reqs = _run_trace(eng)
    return eng, reqs


def _faulty_engine(cluster_model, plan, **kw):
    cfg, params = cluster_model
    return VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                          buckets=(4,), fault_plan=plan, **kw)


def _assert_healthy_bitwise(reqs, clean_reqs, *, skip=()):
    """Every (tick, tenant) outside ``skip`` matches the fault-free
    replay bit-for-bit."""
    for key, req in reqs.items():
        if key in skip:
            continue
        assert req.done and req.fault is None, (key, req.fault)
        np.testing.assert_array_equal(
            req.logits, clean_reqs[key].logits,
            err_msg=f"healthy lane {key} diverged from fault-free replay",
        )


def _assert_cold_replay(cfg, params, reqs, tenant, ticks):
    """The affected tenant's post-recovery requests equal a cold B=1
    replay (recovery restarts the warm carry, it does not corrupt it).
    Engine-vs-replay crosses program shapes, so tolerances follow the
    parity suite (bitwise is reserved for same-program comparisons)."""
    chain = [reqs[(tick, tenant)] for tick in ticks]
    replayed, _ = _replay_tenant(cfg, params, "cluster", chain)
    for tick, want in zip(ticks, replayed):
        np.testing.assert_allclose(
            reqs[(tick, tenant)].logits, want, rtol=1e-5, atol=1e-5,
            err_msg=f"tenant {tenant} tick {tick} is not a cold replay",
        )


# ---------------------------------------------------------------------------
# The fault matrix


def test_matrix_nonfinite_input_quarantines_tenant(cluster_model,
                                                   clean_trace):
    cfg, params = cluster_model
    _, clean_reqs = clean_trace
    plan = FaultPlan(seed=1).inject_nonfinite_input("B", tick=2)
    eng = _faulty_engine(cluster_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"nonfinite_input": 1}
    bad = reqs[(2, "B")]
    assert bad.done and bad.logits is None
    assert bad.fault is not None and bad.fault.kind == "nonfinite_input"
    assert bad.fault.site == "admit.image" and bad.fault.tenant == "B"
    st = eng.stats()
    assert st["quarantines"] == 1 and st["requests_failed"] == 1
    assert st["state_resets"] >= 1
    # Co-batched tenants never see the fault; B's ticks 1 and 3-4 are a
    # warm tick then a cold restart.
    _assert_healthy_bitwise(reqs, clean_reqs,
                            skip={(2, "B"), (3, "B"), (4, "B")})
    _assert_cold_replay(cfg, params, reqs, "B", ticks=(3, 4))


def test_matrix_state_nan_quarantines_tenant(cluster_model, clean_trace):
    cfg, params = cluster_model
    _, clean_reqs = clean_trace
    # Arrival order A,B,C binds slots 0,1,2 — row 1 is tenant B.
    plan = FaultPlan(seed=2).inject_state_corruption(
        field="centroids", row=1, tick=2, mode="nan",
    )
    eng = _faulty_engine(cluster_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"state_corruption": 1}
    bad = reqs[(2, "B")]
    assert bad.done and bad.logits is None
    assert bad.fault is not None and bad.fault.kind == "nonfinite_state"
    st = eng.stats()
    assert st["quarantines"] == 1 and st["state_resets"] >= 1
    _assert_healthy_bitwise(reqs, clean_reqs,
                            skip={(2, "B"), (3, "B"), (4, "B")})
    _assert_cold_replay(cfg, params, reqs, "B", ticks=(3, 4))


def test_matrix_state_bitflip_recovers_cold(cluster_model, clean_trace):
    """A flipped bit yields *finite* wrong values — only the integrity
    fingerprint can see it. Detection cold-resets the row and still
    serves the request (recovery, not quarantine)."""
    cfg, params = cluster_model
    _, clean_reqs = clean_trace
    plan = FaultPlan(seed=3).inject_state_corruption(
        field="centroids", row=1, tick=2, mode="bitflip",
    )
    eng = _faulty_engine(cluster_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"state_corruption": 1}
    st = eng.stats()
    assert st["quarantines"] == 0 and st["requests_failed"] == 0
    assert st["state_resets"] >= 1
    assert any(f["kind"] == "state_corruption" for f in st["faults"])
    # Every request served; B restarts cold AT tick 2.
    for req in reqs.values():
        assert req.done and req.logits is not None and req.fault is None
    _assert_healthy_bitwise(reqs, clean_reqs,
                            skip={(2, "B"), (3, "B"), (4, "B")})
    _assert_cold_replay(cfg, params, reqs, "B", ticks=(2, 3, 4))


def test_matrix_transient_build_failure_retries_to_identical(
        cluster_model, clean_trace):
    """One injected compile failure is absorbed by the retry loop: no
    degradation, and the whole trace — including the first tick that
    triggered the build — is bit-identical to fault-free."""
    _, clean_reqs = clean_trace
    plan = FaultPlan(seed=4).inject_build_failure(times=1)
    eng = _faulty_engine(cluster_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"compile_failure": 1}
    st = eng.stats()
    assert st["retries"] >= 1
    assert st["fallback_level"] == 0
    assert st["quarantines"] == 0
    _assert_healthy_bitwise(reqs, clean_reqs)


def test_matrix_persistent_build_failure_walks_ladder(cluster_model):
    """Every cluster-tier build fails: after the retry budget the
    engine descends the ladder (cluster -> blocked) and keeps
    serving."""
    plan = FaultPlan(seed=5).inject_build_failure(impl="cluster",
                                                 times=None)
    eng = _faulty_engine(cluster_model, plan)
    reqs = _run_trace(eng)

    st = eng.stats()
    assert st["fallback_level"] == 1
    assert st["fallback_impl"] == "blocked"
    assert st["retries"] >= eng.retry_attempts
    assert any(f["kind"] == "compile_degrade" for f in st["faults"])
    for req in reqs.values():
        assert req.done and req.fault is None
        assert np.isfinite(req.logits).all()


def test_exhausted_ladder_reraises(cluster_model):
    """When every rung fails to build, the engine stops absorbing: the
    last build error propagates (a served-blind engine is worse than a
    crashed one)."""
    plan = FaultPlan(seed=6).inject_build_failure(times=None)
    eng = _faulty_engine(cluster_model, plan, retry_attempts=1,
                         retry_backoff=0.0)
    eng.submit(VigRequest(uid=0, image=IMAGES[(1, "A")], tenant="A"))
    with pytest.raises(FaultError):
        eng.step()
    assert eng.stats()["fallback_level"] == len(fallback_chain("cluster"))


# ---------------------------------------------------------------------------
# Deadline budget / slow ticks (stubbed programs: no compiles)


def _stub_fault_engine(plan, **kw):
    cfg, params = _tiny_vig("cluster")
    return _StubProgramEngine(cfg, params, digc_impl="cluster",
                              autotune=False, buckets=(2,),
                              fault_plan=plan, **kw)


def test_deadline_strikes_descend_ladder():
    plan = FaultPlan(seed=7).inject_slow_tick(seconds=0.05, times=3)
    eng = _stub_fault_engine(plan, deadline_ms=5.0, deadline_strikes=2)
    for tick in range(1, 4):
        eng.submit(VigRequest(uid=tick, image=IMAGES[(1, "A")], tenant="A"))
        assert eng.step() == 1
    st = eng.stats()
    # Tick 1 is the bucket program's first (compile-bearing) tick —
    # never a deadline signal; ticks 2 and 3 miss and degrade.
    assert st["deadline_misses"] == 2
    assert st["fallback_level"] == 1
    assert any(f["kind"] == "deadline_degrade" for f in st["faults"])
    assert plan.counts()["slow_tick"] == 3


def test_fast_ticks_never_miss_deadline():
    eng = _stub_fault_engine(None, deadline_ms=250.0)
    for tick in range(1, 4):
        eng.submit(VigRequest(uid=tick, image=IMAGES[(1, "A")], tenant="A"))
        eng.step()
    st = eng.stats()
    assert st["deadline_misses"] == 0 and st["fallback_level"] == 0


# ---------------------------------------------------------------------------
# Parking faults (satellite: eviction/parking under injected loss)


def _parking_scenario(cluster_model, plan):
    """slots=2: A,B bind; C evicts A (parked); A returns and restores
    — unless the plan says its parked rows are gone."""
    cfg, params = cluster_model
    eng = VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                         buckets=(2,), fault_plan=plan)
    rng = np.random.default_rng(11)
    imgs = {k: _image(rng) for k in ("A1", "B1", "C2", "A3")}
    r = {}
    for uid, (key, tenant) in enumerate(
            [("A1", "A"), ("B1", "B")]):
        r[key] = VigRequest(uid=uid, image=imgs[key], tenant=tenant)
        eng.submit(r[key])
    eng.step()
    r["C2"] = VigRequest(uid=2, image=imgs["C2"], tenant="C")
    eng.submit(r["C2"])
    eng.step()
    assert "A" in eng.stats()["parked_tenants"]
    r["A3"] = VigRequest(uid=3, image=imgs["A3"], tenant="A")
    eng.submit(r["A3"])
    eng.step()
    return cfg, params, eng, r


def test_injected_parking_loss_readmits_cold(cluster_model):
    plan = FaultPlan(seed=8).inject_parking_loss("A")
    cfg, params, eng, r = _parking_scenario(cluster_model, plan)

    assert plan.counts() == {"parking_loss": 1}
    st = eng.stats()
    assert st["park_losses"] == 1
    assert st["park_hits"] == 0
    assert st["state_resets"] >= 1
    assert any(f["kind"] == "parking_loss" and f["tenant"] == "A"
               for f in st["faults"])
    # The dropped-parked tenant re-admitted COLD: its slot shows in
    # last_resets (not last_restores) and its logits are a cold replay.
    slot = eng._tenant_slot["A"]
    assert slot in eng.last_resets and slot not in eng.last_restores
    want, _ = _replay_tenant(cfg, params, "cluster", [r["A3"]])
    np.testing.assert_allclose(r["A3"].logits, want[0], rtol=1e-5,
                               atol=1e-5)
    assert r["A3"].fault is None  # loss is recovery, not request failure


def test_transient_park_restore_error_retries_warm(cluster_model):
    plan = FaultPlan(seed=9).inject_park_restore_error("A", times=1)
    cfg, params, eng, r = _parking_scenario(cluster_model, plan)

    assert plan.counts() == {"parking_transient": 1}
    st = eng.stats()
    assert st["retries"] >= 1
    assert st["park_losses"] == 0
    assert st["park_hits"] == 1  # the retry restored the rows warm
    # Warm restore: A3 continues from A1's state, not from cold.
    _, warm_state = _replay_tenant(cfg, params, "cluster", [r["A1"]])
    want, _ = _replay_tenant(cfg, params, "cluster", [r["A3"]],
                             state=warm_state)
    np.testing.assert_allclose(r["A3"].logits, want[0], rtol=1e-5,
                               atol=1e-5)


def test_capacity_park_eviction_accounting(cluster_model):
    """park_capacity=1 drops the oldest parked copy (park_evictions);
    the dropped tenant re-admits cold without any injected fault."""
    cfg, params = cluster_model
    eng = VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                         buckets=(1,), park_capacity=1)
    rng = np.random.default_rng(12)
    for uid, tenant in enumerate(["A", "B", "C"]):
        eng.submit(VigRequest(uid=uid, image=_image(rng), tenant=tenant))
        eng.step()  # each admission evicts + parks the previous tenant
    st = eng.stats()
    assert st["park_evictions"] >= 1  # A's copy dropped when B parked
    assert "A" not in st["parked_tenants"]
    req = VigRequest(uid=9, image=_image(rng), tenant="A")
    eng.submit(req)
    eng.step()
    slot = eng._tenant_slot["A"]
    assert slot in eng.last_resets  # no parked copy left: cold re-admit
    assert eng.stats()["park_losses"] == 0  # capacity drop, not a fault


# ---------------------------------------------------------------------------
# submit() validation (satellite: typed errors naming the field)


def _valid_engine():
    cfg, params = _tiny_vig("reference")
    return VigServeEngine(cfg, params, digc_impl="reference",
                          autotune=False, buckets=(2,))


def test_submit_rejects_wrong_ndim():
    eng = _valid_engine()
    with pytest.raises(ValueError, match=r"VigRequest\.image.*ndim"):
        eng.submit(VigRequest(uid=1, image=np.zeros((16, 16), np.float32)))
    assert not eng.queue


def test_submit_rejects_wrong_shape():
    eng = _valid_engine()
    with pytest.raises(ValueError, match=r"VigRequest\.image.*shape"):
        eng.submit(VigRequest(uid=2,
                              image=np.zeros((8, 8, 3), np.float32)))


def test_submit_rejects_non_float_dtype():
    eng = _valid_engine()
    with pytest.raises(ValueError, match=r"VigRequest\.image.*dtype"):
        eng.submit(VigRequest(uid=3,
                              image=np.zeros((16, 16, 3), np.int32)))


def test_submit_error_names_the_uid():
    eng = _valid_engine()
    with pytest.raises(ValueError, match="uid=41"):
        eng.submit(VigRequest(uid=41, image=np.zeros((1,), np.float32)))


def test_submit_accepts_valid_request():
    eng = _valid_engine()
    eng.submit(VigRequest(uid=4, image=np.zeros((16, 16, 3), np.float32)))
    assert len(eng.queue) == 1


# ---------------------------------------------------------------------------
# FaultPlan mechanics


def test_plan_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan()._add("no.such.site", lambda v, c: v, {}, 1)


def test_plan_times_bounds_firing():
    plan = FaultPlan(seed=0).inject_nonfinite_input(times=2)
    img = np.zeros((4, 4), np.float32)
    for _ in range(5):
        plan.fire("admit.image", value=img, tenant="T")
    assert plan.counts() == {"nonfinite_input": 2}


def test_plan_criteria_scope_tenant_and_tick():
    plan = FaultPlan(seed=0).inject_nonfinite_input("B", tick=3, times=None)
    img = np.zeros((2, 2), np.float32)
    out = plan.fire("admit.image", value=img, tenant="A", tick=3)
    assert np.isfinite(out).all()  # wrong tenant
    out = plan.fire("admit.image", value=img, tenant="B", tick=2)
    assert np.isfinite(out).all()  # wrong tick
    out = plan.fire("admit.image", value=img, tenant="B", tick=3)
    assert not np.isfinite(out).all()
    assert plan.counts() == {"nonfinite_input": 1}


def test_plan_is_deterministic_across_instances():
    img = np.zeros((8, 8), np.float32)
    outs = []
    for _ in range(2):
        plan = FaultPlan(seed=17).inject_nonfinite_input(count=4)
        outs.append(plan.fire("admit.image", value=img, tenant="T"))
    np.testing.assert_array_equal(np.isnan(outs[0]), np.isnan(outs[1]))
    assert np.isnan(outs[0]).sum() > 0


def test_sites_registry_is_closed():
    assert set(SITES) == {
        "admit.image", "state.rows", "program.build", "park.restore",
        "tick.serve", "digc.x",
    }


def test_fault_info_as_dict_stringifies_tenant():
    info = FaultInfo(kind="k", site="admit.image", tenant=("t", 1), tick=2)
    d = info.as_dict()
    assert d["tenant"] == str(("t", 1)) and d["tick"] == 2


# ---------------------------------------------------------------------------
# digc.x — kernel-level injection


def test_digc_x_site_corrupts_eager_features():
    x = np.random.default_rng(0).standard_normal((2, 16, 8)).astype(
        np.float32)
    clean = np.asarray(digc(x, k=3, impl="reference"))
    plan = FaultPlan(seed=20).inject_nonfinite_input(site="digc.x")
    faulty = np.asarray(digc(x, k=3, impl="reference", fault_plan=plan))
    assert plan.counts() == {"nonfinite_input": 1}
    assert not np.array_equal(clean, faulty)


def test_digc_without_plan_is_unchanged():
    x = np.random.default_rng(1).standard_normal((16, 8)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(digc(x, k=3, impl="reference")),
        np.asarray(digc(x, k=3, impl="reference", fault_plan=None)),
    )


# ---------------------------------------------------------------------------
# Degradation ladder (core.builder)


def test_fallback_chain_orderings():
    assert DEGRADATION_LADDER == ("pallas", "blocked", "reference")
    assert fallback_chain("pallas") == ("blocked", "reference")
    assert fallback_chain("blocked") == ("reference",)
    assert fallback_chain("reference") == ()
    # approximate tiers degrade into the exact chain
    for impl in ("cluster", "axial", "ring"):
        assert fallback_chain(impl) == ("blocked", "reference")


def test_degraded_spec_preserves_graph_semantics():
    spec = resolve_spec(None, impl="cluster", k=5, dilation=2)
    down = degraded_spec(spec, "blocked")
    assert down.impl == "blocked"
    assert (down.k, down.dilation, down.causal) == (5, 2, spec.causal)


# ---------------------------------------------------------------------------
# State integrity primitives (core.state)


def test_row_fingerprint_sees_single_row_changes():
    cfg, _ = _tiny_vig("cluster")
    state = vig.init_vig_state(cfg, 4, "cluster", per_slot=True)
    before = state.row_fingerprints([0, 1, 2, 3])
    plan = FaultPlan(seed=21).inject_state_corruption(
        field="centroids", row=2, mode="bitflip")
    corrupted = plan.fire("state.rows", value=state)
    after = corrupted.row_fingerprints([0, 1, 2, 3])
    for key in before:
        changed = [r for r in range(4) if before[key][r] != after[key][r]]
        assert changed in ([], [2]), (key, changed)
    assert any(before[key][2] != after[key][2] for key in before)


def test_rows_finite_flags_nan_rows():
    cfg, _ = _tiny_vig("cluster")
    state = vig.init_vig_state(cfg, 4, "cluster", per_slot=True)
    assert all(state.rows_finite([0, 1, 2, 3]).values())
    plan = FaultPlan(seed=22).inject_state_corruption(
        field="centroids", row=3, mode="nan")
    corrupted = plan.fire("state.rows", value=state)
    finite = corrupted.rows_finite([0, 1, 2, 3])
    assert finite == {0: True, 1: True, 2: True, 3: False}


def test_guards_off_restores_unguarded_path():
    """guards=False must keep the PR-6 behavior: no fingerprinting, no
    screening — an injected NaN image sails into the (stub) program."""
    plan = FaultPlan(seed=23).inject_nonfinite_input("A")
    eng = _stub_fault_engine(plan, guards=False)
    req = VigRequest(uid=0, image=IMAGES[(1, "A")], tenant="A")
    eng.submit(req)
    assert eng.step() == 1
    assert req.done and req.logits is not None and req.fault is None
    assert plan.counts() == {"nonfinite_input": 1}
    st = eng.stats()
    assert st["quarantines"] == 0 and st["state_resets"] == 0
    assert eng._row_tokens == {}


# ---------------------------------------------------------------------------
# Stale-graph serving under faults (DESIGN.md §12): the cached graph
# and its gate metadata are state rows like any other — integrity
# tokens must see their corruption, recovery must cold-reset them, and
# a quarantined lane's cache must never survive into the slot's next
# occupant or its own post-reset stream.


def _reuse_spec():
    from repro.core.builder import DigcSpec

    return DigcSpec(impl="cluster", k=3, n_clusters=4, n_probe=4,
                    capacity_factor=8.0, reuse="tick", drift_tau=0.05,
                    max_stale=16)


@pytest.fixture(scope="module")
def reuse_model():
    cfg, params = _tiny_vig("cluster")
    return cfg, params, _reuse_spec()


@pytest.fixture(scope="module")
def reuse_clean_trace(reuse_model):
    cfg, params, spec = reuse_model
    eng = VigServeEngine(cfg, params, digc_impl=spec, autotune=False,
                         buckets=(4,))
    reqs = _run_trace(eng)
    return eng, reqs


def _reuse_engine(reuse_model, plan, **kw):
    cfg, params, spec = reuse_model
    return VigServeEngine(cfg, params, digc_impl=spec, autotune=False,
                          buckets=(4,), fault_plan=plan, **kw)


def _assert_reuse_cold_replay(reuse_model, reqs, tenant, ticks):
    cfg, params, spec = reuse_model
    chain = [reqs[(tick, tenant)] for tick in ticks]
    replayed, _ = _replay_tenant(cfg, params, spec, chain)
    for tick, want in zip(ticks, replayed):
        np.testing.assert_allclose(
            reqs[(tick, tenant)].logits, want, rtol=1e-5, atol=1e-5,
            err_msg=f"tenant {tenant} tick {tick} is not a cold replay",
        )


def test_cached_graph_bitflip_trips_integrity_and_recovers(
        reuse_model, reuse_clean_trace):
    """A flipped bit in the *cached graph* is finite garbage — only the
    crc32 row token can see it. Detection cold-resets the row (cache
    included) and still serves the request."""
    _, clean_reqs = reuse_clean_trace
    plan = FaultPlan(seed=31).inject_state_corruption(
        field="graph_idx", row=1, tick=2, mode="bitflip",
    )
    eng = _reuse_engine(reuse_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"state_corruption": 1}
    st = eng.stats()
    assert st["quarantines"] == 0 and st["requests_failed"] == 0
    assert st["state_resets"] >= 1
    assert any(f["kind"] == "state_corruption" for f in st["faults"])
    for req in reqs.values():
        assert req.done and req.logits is not None and req.fault is None
    _assert_healthy_bitwise(reqs, clean_reqs,
                            skip={(2, "B"), (3, "B"), (4, "B")})
    _assert_reuse_cold_replay(reuse_model, reqs, "B", ticks=(2, 3, 4))


def test_cached_snapshot_nan_quarantines_and_resets(
        reuse_model, reuse_clean_trace):
    """A non-finite drift snapshot would poison every later gate
    decision: the finiteness screen quarantines the lane before it
    serves."""
    _, clean_reqs = reuse_clean_trace
    plan = FaultPlan(seed=32).inject_state_corruption(
        field="graph_snap", row=1, tick=2, mode="nan",
    )
    eng = _reuse_engine(reuse_model, plan)
    reqs = _run_trace(eng)

    assert plan.counts() == {"state_corruption": 1}
    bad = reqs[(2, "B")]
    assert bad.done and bad.logits is None
    assert bad.fault is not None and bad.fault.kind == "nonfinite_state"
    st = eng.stats()
    assert st["quarantines"] == 1 and st["state_resets"] >= 1
    _assert_healthy_bitwise(reqs, clean_reqs,
                            skip={(2, "B"), (3, "B"), (4, "B")})
    _assert_reuse_cold_replay(reuse_model, reqs, "B", ticks=(3, 4))


def test_quarantined_lane_never_leaks_stale_graph(reuse_model):
    """After a quarantine, the lane's cached graph must be *zeroed* —
    the next occupant of the slot (here: the same tenant, re-admitted
    cold) gates against an empty cache, never the pre-fault graph."""
    cfg, params, spec = reuse_model
    plan = FaultPlan(seed=33).inject_state_corruption(
        field="graph_snap", row=1, tick=2, mode="nan",
    )
    eng = _reuse_engine(reuse_model, plan)

    reqs = {}
    uid = 0
    for tick in range(1, 3):
        for t in TENANTS:
            r = VigRequest(uid=uid, image=IMAGES[(tick, t)], tenant=t)
            reqs[(tick, t)] = r
            eng.submit(r)
            uid += 1
        eng.step()
    assert eng.stats()["quarantines"] == 1
    slot = eng._tenant_slot[("tenant", "B")] \
        if ("tenant", "B") in getattr(eng, "_tenant_slot", {}) \
        else eng.slot_tenant.index("B")
    entry = next(e for e in eng._slot_state.entries.values()
                 if e.graph_idx is not None)
    # the reset wiped the cache row: no stale neighbors, age 0
    assert np.all(np.asarray(entry.graph_idx)[slot] == 0)
    assert np.asarray(entry.graph_age)[slot] == 0
    assert np.asarray(entry.graph_snap)[slot] == 0.0

    # the slot's next stream (B re-served post-reset) is a cold replay:
    # nothing of the pre-fault graph reaches it
    r3 = VigRequest(uid=uid, image=IMAGES[(3, "B")], tenant="B")
    eng.submit(r3)
    eng.step()
    replayed, _ = _replay_tenant(cfg, params, spec, [r3])
    np.testing.assert_allclose(r3.logits, replayed[0],
                               rtol=1e-5, atol=1e-5)
