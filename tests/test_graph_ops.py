"""Graph ops: gather / MRConv aggregation / edge list / degree / pos bias."""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    degree_histogram,
    digc_blocked,
    edge_list,
    grid_pos_bias,
    knn_gather,
    mean_aggregate,
    mr_aggregate,
    sum_aggregate,
)


def test_knn_gather_shapes_and_values():
    y = jnp.arange(12.0).reshape(6, 2)
    idx = jnp.asarray([[0, 5], [2, 2]], jnp.int32)
    g = knn_gather(y, idx)
    assert g.shape == (2, 2, 2)
    np.testing.assert_array_equal(np.asarray(g[0, 1]), np.asarray(y[5]))


def test_mr_aggregate_matches_numpy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((10, 4)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((15, 4)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 15, size=(10, 3)), jnp.int32)
    out = np.asarray(mr_aggregate(x, y, idx))
    ref = (np.asarray(y)[np.asarray(idx)] - np.asarray(x)[:, None]).max(1)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_aggregators_consistency():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 8, size=(8, 1)), jnp.int32)
    # With one neighbor: max == sum == mean == y_j - x_i
    m = np.asarray(mr_aggregate(x, x, idx))
    s = np.asarray(sum_aggregate(x, x, idx))
    a = np.asarray(mean_aggregate(x, x, idx))
    np.testing.assert_allclose(m, s, rtol=1e-6)
    np.testing.assert_allclose(m, a, rtol=1e-6)


def test_edge_list_and_degree():
    idx = jnp.asarray([[1, 2], [0, 2], [0, 1]], jnp.int32)
    e = edge_list(idx)
    assert e.shape == (2, 6)
    deg = degree_histogram(idx, 3)
    np.testing.assert_array_equal(np.asarray(deg), [2, 2, 2])


def test_grid_pos_bias_prefers_nearby_patches():
    p = grid_pos_bias(4, 4, scale=10.0)
    assert p.shape == (16, 16)
    assert float(p[0, 0]) == 0.0
    assert float(p[0, 15]) > float(p[0, 1])
    # with a strong spatial prior, DIGC picks spatial neighbors
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((16, 8)) * 0.01, jnp.float32)
    idx = digc_blocked(x, x, k=2, pos_bias=grid_pos_bias(4, 4, scale=1e6))
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.arange(16))
