"""Sorted two-level merge (LSM+GMM) inside the fused Pallas kernel.

The bitonic merge is the kernel's default exact path; this suite pins
its parity against the pure-jnp oracle across every kernel feature
(pos_bias, causal, ragged co-node tails, dilation, packed keys, bf16
MXU), its bit-equality with the legacy kd-pass merge, and the wrapper
contract errors for invalid merge/bucketing combinations.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import BIG
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.digc_topk import KERNEL_MERGES, digc_topk_pallas


def _rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _assert_exact(d_ref, i_ref, d_k, i_k):
    valid = np.asarray(d_ref) < BIG / 2
    np.testing.assert_array_equal(valid, np.asarray(d_k) < BIG / 2)
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(i_ref), -1),
        np.where(valid, np.asarray(i_k), -1))
    np.testing.assert_allclose(
        np.where(valid, np.asarray(d_ref), 0.0),
        np.where(valid, np.asarray(d_k), 0.0), rtol=1e-5, atol=1e-4)


def test_kernel_merges_registry():
    assert KERNEL_MERGES == ("bitonic", "legacy")


@pytest.mark.parametrize("n,m,kd", [(16, 128, 4), (32, 300, 9), (8, 128, 16)])
def test_bitonic_parity_basic(n, m, kd):
    rng = np.random.default_rng(n + m)
    x, y = _rand(rng, n, 24), _rand(rng, m, 24)
    d_ref, i_ref = kref.digc_reference(x, y, kd=kd)
    i_k, d_k = ops.digc_topk(x, y, k=kd, block_n=16, block_m=128,
                             kernel_merge="bitonic", return_dists=True)
    _assert_exact(d_ref, i_ref, d_k, i_k)


def test_bitonic_parity_pos_bias():
    rng = np.random.default_rng(7)
    x, y = _rand(rng, 24, 16), _rand(rng, 200, 16)
    p = _rand(rng, 24, 200)
    d_ref, i_ref = kref.digc_reference(x, y, p, kd=6)
    i_k, d_k = ops.digc_topk(x, y, k=6, pos_bias=p, block_n=8, block_m=128,
                             kernel_merge="bitonic", return_dists=True)
    _assert_exact(d_ref, i_ref, d_k, i_k)


def test_bitonic_parity_causal():
    rng = np.random.default_rng(8)
    x = _rand(rng, 96, 12)
    i_k, d_k = ops.digc_topk(x, x, k=5, causal=True, block_n=32,
                             block_m=32, kernel_merge="bitonic",
                             return_dists=True)
    d_full = np.asarray(kref.pairwise_sq_dists(x, x))
    for i in range(96):
        allowed = d_full[i, : i + 1]
        order = np.argsort(allowed, kind="stable")[:5]
        got = np.asarray(i_k)[i]
        valid = np.asarray(d_k)[i] < BIG / 2
        assert valid.sum() == min(5, i + 1)
        np.testing.assert_array_equal(got[valid], order[: valid.sum()])


def test_bitonic_parity_ragged_tail():
    """M not a multiple of block_m: padded columns masked inside the
    kernel, never emitted."""
    rng = np.random.default_rng(9)
    x, y = _rand(rng, 20, 8), _rand(rng, 130, 8)
    d_ref, i_ref = kref.digc_reference(x, y, kd=7)
    i_k, d_k = ops.digc_topk(x, y, k=7, block_n=16, block_m=128,
                             kernel_merge="bitonic", return_dists=True)
    _assert_exact(d_ref, i_ref, d_k, i_k)
    assert np.asarray(i_k).max() < 130


def test_bitonic_parity_dilation():
    rng = np.random.default_rng(10)
    x, y = _rand(rng, 16, 8), _rand(rng, 256, 8)
    d_ref, i_ref = kref.digc_reference(x, y, kd=8)
    i_k = ops.digc_topk(x, y, k=4, dilation=2, block_n=16, block_m=128,
                        kernel_merge="bitonic")
    np.testing.assert_array_equal(np.asarray(i_k),
                                  np.asarray(i_ref)[:, ::2])


def test_bitonic_matches_legacy_exactly():
    """Both exact merges implement the same selection (incl. the
    lowest-index tie rule): identical indices, identical distances."""
    rng = np.random.default_rng(11)
    # integer-valued features => many exact distance ties
    x = jnp.asarray(rng.integers(0, 3, (32, 8)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, (256, 8)), jnp.float32)
    outs = {}
    for km in KERNEL_MERGES:
        d_k, i_k = digc_topk_pallas(x, y, kd=9, block_n=16, block_m=128,
                                    kernel_merge=km)
        outs[km] = (np.asarray(d_k), np.asarray(i_k))
    np.testing.assert_array_equal(outs["bitonic"][1], outs["legacy"][1])
    np.testing.assert_array_equal(outs["bitonic"][0], outs["legacy"][0])


def test_bitonic_packed_recall():
    rng = np.random.default_rng(12)
    x, y = _rand(rng, 64, 32), _rand(rng, 512, 32)
    _, i_ref = kref.digc_reference(x, y, kd=8)
    i_k = ops.digc_topk(x, y, k=8, block_n=32, block_m=128,
                        kernel_merge="bitonic", packed=True)
    hits = sum(
        len(set(np.asarray(i_k)[r]) & set(np.asarray(i_ref)[r]))
        for r in range(64))
    assert hits / (64 * 8) >= 0.99


def test_bitonic_bf16_recall():
    rng = np.random.default_rng(13)
    x, y = _rand(rng, 48, 64), _rand(rng, 384, 64)
    _, i_ref = kref.digc_reference(x, y, kd=6)
    i_k = ops.digc_topk(x, y, k=6, block_n=16, block_m=128,
                        kernel_merge="bitonic", mxu_bf16=True)
    hits = sum(
        len(set(np.asarray(i_k)[r]) & set(np.asarray(i_ref)[r]))
        for r in range(48))
    assert hits / (48 * 6) >= 0.95


def test_bitonic_batched():
    rng = np.random.default_rng(14)
    x, y = _rand(rng, 3, 24, 8), _rand(rng, 3, 140, 8)
    i_k, d_k = ops.digc_topk(x, y, k=5, block_n=8, block_m=128,
                             kernel_merge="bitonic", return_dists=True)
    for b in range(3):
        d_ref, i_ref = kref.digc_reference(x[b], y[b], kd=5)
        _assert_exact(d_ref, i_ref, d_k[b], i_k[b])


# -- wrapper contract -------------------------------------------------------


def _xy(rng=None, n=16, m=128, d=8):
    rng = rng or np.random.default_rng(0)
    return _rand(rng, n, d), _rand(rng, m, d)


def test_unknown_kernel_merge_rejected():
    x, y = _xy()
    with pytest.raises(ValueError, match="unknown kernel_merge"):
        digc_topk_pallas(x, y, kd=4, kernel_merge="heap")


def test_bucket_rounds_requires_legacy():
    x, y = _xy()
    with pytest.raises(ValueError, match="legacy"):
        digc_topk_pallas(x, y, kd=4, packed=True, bucket_rounds=2,
                         kernel_merge="bitonic")


def test_bucket_rounds_requires_packed():
    x, y = _xy()
    with pytest.raises(ValueError, match="packed"):
        digc_topk_pallas(x, y, kd=4, bucket_rounds=2)


def test_bucket_rounds_block_m_contract():
    x, y = _xy(m=128)
    # block_m % kd != 0
    with pytest.raises(ValueError, match="block_m"):
        digc_topk_pallas(x, y, kd=5, packed=True, bucket_rounds=1,
                         block_m=128)
    # block_m // kd < 2 buckets
    with pytest.raises(ValueError, match="block_m"):
        digc_topk_pallas(x, y, kd=64, packed=True, bucket_rounds=1,
                         block_n=16, block_m=64)


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(min_value=1, max_value=40),
    m=st.integers(min_value=4, max_value=200),
    kd=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_bitonic_exact_topk(n, m, kd, seed):
    if not HAVE_HYPOTHESIS:  # pragma: no cover - shim path
        pytest.skip("hypothesis not installed")
    if kd > m:
        kd = m
    rng = np.random.default_rng(seed)
    # few distinct values => dense ties exercise the tie rule
    x = jnp.asarray(rng.integers(0, 4, (n, 6)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, (m, 6)), jnp.float32)
    d_ref, i_ref = kref.digc_reference(x, y, kd=kd)
    i_k, d_k = ops.digc_topk(x, y, k=kd, block_n=16, block_m=128,
                             kernel_merge="bitonic", return_dists=True)
    _assert_exact(d_ref, i_ref, d_k, i_k)
    assert (np.diff(np.asarray(d_k), axis=-1) >= 0).all()
