"""Optimized kernel variants (§Perf iterations): packed keys, bf16 MXU,
bucketed pre-reduction — correctness/recall guarantees vs the oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.digc_topk import _pack_keys, _unpack_keys


def _recall(i_ref, i_k):
    a, b = np.asarray(i_ref), np.asarray(i_k)
    return np.mean([len(set(a[i]) & set(b[i])) / a.shape[1]
                    for i in range(a.shape[0])])


def test_pack_unpack_roundtrip_order():
    rng = np.random.default_rng(0)
    d = jnp.asarray(np.sort(rng.standard_normal(256) * 100), jnp.float32)
    idx = jnp.arange(256, dtype=jnp.int32)
    keys = _pack_keys(d, idx, idx_bits=8)
    # packed keys preserve the (ascending) distance order
    assert bool(jnp.all(jnp.diff(keys) > 0))
    d2, i2 = _unpack_keys(keys, idx_bits=8)
    np.testing.assert_array_equal(np.asarray(i2), np.arange(256))
    # truncation error bounded by the dropped mantissa bits
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d), rtol=2e-2)


def test_pack_handles_negatives_and_zero():
    d = jnp.asarray([-1e5, -2.0, -1.0, -1e-8, 0.0, 1e-8, 1.0, 2.0, 1e5],
                    jnp.float32)
    idx = jnp.arange(9, dtype=jnp.int32)
    keys = _pack_keys(d, idx, idx_bits=4)
    assert bool(jnp.all(jnp.diff(keys) >= 0))


@pytest.mark.parametrize("n,m,kd", [(64, 256, 8), (196, 196, 16), (100, 300, 9)])
def test_packed_mode_near_exact(n, m, kd):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((n, 48)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((m, 48)), jnp.float32)
    _, i_ref = kref.digc_reference(x, y, kd=kd)
    i_pk = ops.digc_topk(x, y, k=kd, block_n=32, block_m=128, packed=True)
    assert _recall(i_ref, i_pk) >= 0.99


def test_bf16_mxu_high_recall():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((196, 192)), jnp.float32)
    _, i_ref = kref.digc_reference(x, x, kd=16)
    i_bf = ops.digc_topk(x, x, k=16, block_n=32, block_m=128, mxu_bf16=True)
    assert _recall(i_ref, i_bf) >= 0.98


# r=1 recall floor is workload-dependent: with few tiles more of the
# global top-kd lands in one tile and bucket collisions bite (measured
# 0.81 @ 2 tiles, 0.95 @ 64 tiles). r>=2 is robust.
@pytest.mark.parametrize("rounds,floor", [(1, 0.78), (2, 0.97), (3, 0.99)])
def test_bucketed_recall_floor(rounds, floor):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    _, i_ref = kref.digc_reference(x, x, kd=16)
    i_b = ops.digc_topk(x, x, k=16, block_n=64, block_m=256, packed=True,
                        bucket_rounds=rounds)
    assert _recall(i_ref, i_b) >= floor


def test_bucketed_self_neighbor_survives():
    """The nearest neighbor (self, distance 0) must never be dropped."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    idx = ops.digc_topk(x, x, k=8, block_n=64, block_m=128, packed=True,
                        bucket_rounds=1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.arange(256))


def test_packed_dilation_consistent():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    i_full = ops.digc_topk(x, x, k=16, block_n=32, block_m=128, packed=True)
    i_dil = ops.digc_topk(x, x, k=8, dilation=2, block_n=32, block_m=128,
                          packed=True)
    np.testing.assert_array_equal(np.asarray(i_full[:, ::2][:, :8]),
                                  np.asarray(i_dil))
