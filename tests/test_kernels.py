"""Pallas DIGC kernel: shape/dtype sweeps vs the pure-jnp oracle (ref.py)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BIG
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.digc_topk import digc_topk_pallas


def _rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def assert_same_valid(i_a, d_a, i_b, d_b):
    va = np.asarray(d_a) < BIG / 2
    vb = np.asarray(d_b) < BIG / 2
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(
        np.where(va, np.asarray(i_a), -1), np.where(vb, np.asarray(i_b), -1)
    )
    np.testing.assert_allclose(
        np.where(va, np.asarray(d_a), 0.0),
        np.where(vb, np.asarray(d_b), 0.0),
        rtol=1e-5,
        atol=1e-4,
    )


@pytest.mark.parametrize(
    "n,m,d",
    [
        (8, 128, 8),
        (16, 128, 32),
        (32, 256, 64),
        (64, 384, 128),
        (100, 130, 48),  # padding on both axes
        (33, 257, 17),  # awkward everything
        (128, 128, 192),  # ViG-Ti feature dim
    ],
)
@pytest.mark.parametrize("kd", [1, 4, 9])
def test_kernel_shape_sweep(n, m, d, kd):
    rng = np.random.default_rng(n * 7 + m)
    x, y = _rand(rng, n, d), _rand(rng, m, d)
    d_ref, i_ref = kref.digc_reference(x, y, kd=kd)
    i_k, d_k = ops.digc_topk(
        x, y, k=kd, block_n=32, block_m=128, return_dists=True
    )
    assert_same_valid(i_ref, d_ref, i_k, d_k)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_kernel_dtype_sweep(dtype):
    rng = np.random.default_rng(11)
    x, y = _rand(rng, 32, 24, dtype=dtype), _rand(rng, 160, 24, dtype=dtype)
    d_ref, i_ref = kref.digc_reference(x, y, kd=5)
    i_k, d_k = ops.digc_topk(x, y, k=5, block_n=16, block_m=128, return_dists=True)
    # kernel computes in fp32 after upcast — identical selection
    assert_same_valid(i_ref, d_ref, i_k, d_k)


@pytest.mark.parametrize("block_n,block_m", [(8, 128), (16, 256), (64, 128), (128, 512)])
def test_kernel_block_shape_invariance(block_n, block_m):
    rng = np.random.default_rng(12)
    x, y = _rand(rng, 96, 32), _rand(rng, 300, 32)
    d_ref, i_ref = kref.digc_reference(x, y, kd=7)
    i_k, d_k = ops.digc_topk(
        x, y, k=7, block_n=block_n, block_m=block_m, return_dists=True
    )
    assert_same_valid(i_ref, d_ref, i_k, d_k)


def test_kernel_pos_bias():
    rng = np.random.default_rng(13)
    x, y = _rand(rng, 48, 16), _rand(rng, 200, 16)
    p = _rand(rng, 48, 200) * 0.5
    d_ref, i_ref = kref.digc_reference(x, y, p, kd=6)
    i_k, d_k = ops.digc_topk(
        x, y, k=6, pos_bias=p, block_n=16, block_m=128, return_dists=True
    )
    assert_same_valid(i_ref, d_ref, i_k, d_k)


def test_kernel_causal():
    rng = np.random.default_rng(14)
    x = _rand(rng, 64, 16)
    i_k, d_k = ops.digc_topk(
        x, x, k=4, causal=True, block_n=16, block_m=128, return_dists=True
    )
    valid = np.asarray(d_k) < BIG / 2
    rows = np.arange(64)[:, None]
    assert np.all(np.where(valid, np.asarray(i_k) <= rows, True))
    assert np.array_equal(valid.sum(1), np.minimum(np.arange(64) + 1, 4))


def test_kernel_dilation():
    rng = np.random.default_rng(15)
    x, y = _rand(rng, 40, 16), _rand(rng, 256, 16)
    d_full, i_full = kref.digc_reference(x, y, kd=8)
    i_k = ops.digc_topk(x, y, k=4, dilation=2, block_n=8, block_m=128)
    np.testing.assert_array_equal(np.asarray(i_full[:, ::2][:, :4]), np.asarray(i_k))


def test_kernel_vig_tiny_shape():
    """The paper's reference config: N=M=196, D=192, k=8, d=2."""
    rng = np.random.default_rng(16)
    x = _rand(rng, 196, 192)
    d_ref, i_ref = kref.digc_reference(x, x, kd=16)
    i_k, d_k = ops.digc_topk(
        x, x, k=8, dilation=2, block_n=32, block_m=128, return_dists=True
    )
    np.testing.assert_array_equal(np.asarray(i_ref[:, ::2]), np.asarray(i_k))


def test_pallas_call_unpadded_direct():
    """digc_topk_pallas direct path (no wrapper) on aligned shapes."""
    rng = np.random.default_rng(17)
    x, y = _rand(rng, 64, 32), _rand(rng, 256, 32)
    d_ref, i_ref = kref.digc_reference(x, y, kd=4)
    d_k, i_k = digc_topk_pallas(x, y, kd=4, block_n=32, block_m=128)
    assert_same_valid(i_ref, d_ref, i_k, d_k)
