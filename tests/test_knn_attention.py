"""KNN-sparse attention (DIGC-backed) vs dense attention."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.knn_attention import (
    knn_attention,
    knn_attention_decode,
    knn_attention_mha,
)


def _full_causal(q, k, v):
    s = q.shape[0]
    logits = jnp.einsum("shd,thd->hst", q, k) / np.sqrt(q.shape[-1])
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None], logits, -jnp.inf)
    return jnp.einsum("hst,thd->shd", jax.nn.softmax(logits, -1), v)


def test_knn_equals_full_when_k_is_t():
    rng = np.random.default_rng(0)
    s, h, dh = 24, 2, 8
    q, k, v = (jnp.asarray(rng.standard_normal((s, h, dh)), jnp.float32) for _ in range(3))
    out = knn_attention_mha(q, k, v, num_neighbors=s, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_full_causal(q, k, v)), atol=1e-5)


def test_knn_subset_rows_match_when_neighbors_cover_history():
    """Early rows (position < num_neighbors) see their full history."""
    rng = np.random.default_rng(1)
    s, h, dh, nn = 32, 1, 8, 8
    q, k, v = (jnp.asarray(rng.standard_normal((s, h, dh)), jnp.float32) for _ in range(3))
    out = knn_attention_mha(q, k, v, num_neighbors=nn, causal=True)
    full = _full_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(out[:nn]), np.asarray(full[:nn]), atol=1e-5)


def test_decode_matches_prefill_last_row():
    rng = np.random.default_rng(2)
    s, h, dh = 20, 2, 8
    q, k, v = (jnp.asarray(rng.standard_normal((s, h, dh)), jnp.float32) for _ in range(3))
    full = _full_causal(q, k, v)
    out = knn_attention_decode(q[s - 1], k, v, jnp.int32(s), num_neighbors=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[s - 1]), atol=1e-5)


def test_decode_respects_cache_len():
    rng = np.random.default_rng(3)
    t, h, dh = 16, 1, 4
    q = jnp.asarray(rng.standard_normal((h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    out_short = knn_attention_decode(q, k, v, jnp.int32(4), num_neighbors=t)
    # zeroing out the cache beyond len must not change the result
    k2 = k.at[4:].set(1e3)
    v2 = v.at[4:].set(-1e3)
    out_short2 = knn_attention_decode(q, k2, v2, jnp.int32(4), num_neighbors=t)
    np.testing.assert_allclose(np.asarray(out_short), np.asarray(out_short2), atol=1e-5)


def test_single_head_output_finite_and_shaped():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    out = knn_attention(q, k, v, num_neighbors=4, causal=True)
    assert out.shape == (16, 8)
    assert bool(jnp.all(jnp.isfinite(out)))
