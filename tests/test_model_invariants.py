"""Model-level invariants across families: causality, determinism,
batch-element independence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as tr
from repro.models.module import init_params

DECODER_ARCHS = [a for a in ARCH_IDS if a != "whisper-tiny"]
B, S = 2, 12


def _params(cfg):
    return init_params(tr.param_spec(cfg), jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_causality(arch):
    """Perturbing tokens at positions > t must not change logits at t."""
    cfg = get_smoke(arch).replace(dtype="float32")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    t_cut = S // 2
    toks2 = toks.copy()
    toks2[:, t_cut + 1 :] = rng.integers(0, cfg.vocab_size, (B, S - t_cut - 1))
    l1, _ = tr.forward(params, jnp.asarray(toks, jnp.int32), cfg)
    l2, _ = tr.forward(params, jnp.asarray(toks2, jnp.int32), cfg)
    np.testing.assert_allclose(
        np.asarray(l1[:, : t_cut + 1]), np.asarray(l2[:, : t_cut + 1]),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-370m", "recurrentgemma-9b"])
def test_determinism(arch):
    cfg = get_smoke(arch)
    params = _params(cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    l1, _ = tr.forward(params, toks, cfg)
    l2, _ = tr.forward(params, toks, cfg)
    np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v2-lite-16b"])
def test_batch_independence(arch):
    """Row 0's logits must not depend on row 1's tokens (no batch mixing
    through MoE dispatch or attention)."""
    cfg = get_smoke(arch).replace(dtype="float32")
    params = _params(cfg)
    rng = np.random.default_rng(2)
    a = rng.integers(0, cfg.vocab_size, (2, S))
    b = a.copy()
    b[1] = rng.integers(0, cfg.vocab_size, S)
    la, _ = tr.forward(params, jnp.asarray(a, jnp.int32), cfg)
    lb, _ = tr.forward(params, jnp.asarray(b, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[0]),
                               rtol=1e-4, atol=1e-4)
