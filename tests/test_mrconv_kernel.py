"""MRConv Pallas kernel: shape/dtype sweeps + properties vs the
pure-jnp oracle (core.graph.mr_aggregate)."""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.graph import mr_aggregate
from repro.kernels import ops


def _case(rng, n, m, d, k, dtype=jnp.float32):
    x = jnp.asarray(rng.standard_normal((n, d)), dtype)
    y = jnp.asarray(rng.standard_normal((m, d)), dtype)
    idx = jnp.asarray(rng.integers(0, m, (n, k)), jnp.int32)
    return x, y, idx


@pytest.mark.parametrize("n,m,d,k", [
    (8, 128, 8, 1), (64, 256, 32, 4), (100, 300, 48, 9),
    (196, 196, 192, 16), (33, 513, 7, 5),
])
def test_mrconv_shape_sweep(n, m, d, k):
    rng = np.random.default_rng(n + m)
    x, y, idx = _case(rng, n, m, d, k)
    ref = mr_aggregate(x, y, idx)
    out = ops.mrconv(x, y, idx, block_n=32, block_m=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mrconv_dtype_sweep(dtype):
    rng = np.random.default_rng(7)
    x, y, idx = _case(rng, 48, 160, 24, 4, dtype)
    ref = mr_aggregate(x.astype(jnp.float32), y.astype(jnp.float32), idx)
    out = ops.mrconv(x, y, idx, block_n=16, block_m=128)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("block_n,block_m", [(8, 128), (64, 256), (128, 512)])
def test_mrconv_block_invariance(block_n, block_m):
    rng = np.random.default_rng(8)
    x, y, idx = _case(rng, 96, 600, 32, 6)
    ref = mr_aggregate(x, y, idx)
    out = ops.mrconv(x, y, idx, block_n=block_n, block_m=block_m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), m=st.integers(2, 80), d=st.integers(1, 24),
       k=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_mrconv_property(n, m, d, k, seed):
    rng = np.random.default_rng(seed)
    x, y, idx = _case(rng, n, m, d, k)
    ref = mr_aggregate(x, y, idx)
    out = ops.mrconv(x, y, idx, block_n=16, block_m=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_mrconv_duplicate_neighbors():
    """Duplicated neighbor ids must not change the max."""
    rng = np.random.default_rng(9)
    x, y, _ = _case(rng, 16, 32, 8, 1)
    idx1 = jnp.asarray(rng.integers(0, 32, (16, 1)), jnp.int32)
    idx3 = jnp.concatenate([idx1, idx1, idx1], axis=1)
    out1 = ops.mrconv(x, y, idx1, block_n=16, block_m=128)
    out3 = ops.mrconv(x, y, idx3, block_n=16, block_m=128)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out3), rtol=1e-6)
