"""Bitonic sort/merge/top-k networks over packed (dist, idx) keys.

These networks are the kernel's LSM+GMM stages *and* the engine's
packed merge, so they are tested directly against numpy oracles:
sortedness, multiset preservation, exact union-lowest-L merges, and
the lowest-index tie rule the rest of the stack relies on.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.packedkey import (
    IDX_FILL,
    INT_BIG,
    bitonic_merge_sorted,
    bitonic_sort,
    bitonic_topk,
    dist_idx_less,
    idx_bits_for,
    key_less,
    merge_sorted,
    next_pow2,
    pack_keys,
    sort_keys,
    topk_keys,
    unpack_keys,
)


def _rand_keys(rng, *shape, m=256):
    """Random packed keys with plenty of duplicate distances."""
    bits = idx_bits_for(m)
    d = rng.integers(0, 8, shape).astype(np.float32)  # few distinct dists
    idx = rng.integers(0, m, shape).astype(np.int32)
    return pack_keys(jnp.asarray(d), jnp.asarray(idx), bits), bits


def test_next_pow2():
    assert [next_pow2(v) for v in (0, 1, 2, 3, 4, 5, 8, 9, 17)] == [
        1, 1, 2, 4, 4, 8, 8, 16, 32]


def test_sort_keys_sorts_and_preserves_multiset():
    rng = np.random.default_rng(0)
    keys, _ = _rand_keys(rng, 3, 5, 64)
    out = np.asarray(sort_keys(keys))
    assert (np.diff(out, axis=-1) >= 0).all()
    np.testing.assert_array_equal(np.sort(np.asarray(keys), axis=-1), out)


def test_sort_keys_rejects_non_pow2():
    with pytest.raises(ValueError, match="power-of-two"):
        sort_keys(jnp.zeros((3,), jnp.int32))


def test_merge_sorted_is_lowest_l_of_union():
    rng = np.random.default_rng(1)
    a, _ = _rand_keys(rng, 4, 16)
    b, _ = _rand_keys(rng, 4, 16)
    a = jnp.sort(a, axis=-1)
    b = jnp.sort(b, axis=-1)
    out = np.asarray(merge_sorted(a, b))
    union = np.concatenate([np.asarray(a), np.asarray(b)], axis=-1)
    expect = np.sort(union, axis=-1)[..., :16]
    np.testing.assert_array_equal(out, expect)


def test_topk_keys_matches_numpy_partial_sort():
    rng = np.random.default_rng(2)
    for width in (1, 3, 8, 19, 32, 57, 100):
        keys, _ = _rand_keys(rng, 2, width)
        out = np.asarray(topk_keys(keys, 8))
        full = np.sort(
            np.concatenate(
                [np.asarray(keys),
                 np.full((2, max(0, 8 - width)), INT_BIG, np.int32)],
                axis=-1),
            axis=-1)
        np.testing.assert_array_equal(out, full[..., :8])


def test_packed_ties_resolve_to_lowest_index():
    """All-equal distances: the sorted keys enumerate indices ascending
    (the lax.top_k tie rule, encoded in the packed integer order)."""
    bits = idx_bits_for(64)
    idx = jnp.asarray([7, 3, 5, 1, 6, 0, 2, 4], jnp.int32)
    keys = pack_keys(jnp.full((8,), 2.5, jnp.float32), idx, bits)
    _, got_idx = unpack_keys(sort_keys(keys), bits)
    np.testing.assert_array_equal(np.asarray(got_idx), np.arange(8))
    # and topk over a wider tied field picks the lowest indices
    idx_w = jnp.asarray(np.random.default_rng(3).permutation(40), jnp.int32)
    keys_w = pack_keys(jnp.full((40,), 1.0, jnp.float32), idx_w, bits)
    _, top_idx = unpack_keys(topk_keys(keys_w, 4), bits)
    np.testing.assert_array_equal(np.asarray(top_idx), np.arange(4))


def test_two_array_sort_ties_lowest_index():
    """The exact (unpacked) comparator path keeps the same tie rule."""
    d = jnp.asarray([1.0, 1.0, 0.5, 1.0], jnp.float32)
    i = jnp.asarray([9, 2, 11, 5], jnp.int32)
    sd, si = bitonic_sort((d, i), dist_idx_less)
    np.testing.assert_array_equal(np.asarray(si), [11, 2, 5, 9])
    np.testing.assert_allclose(np.asarray(sd), [0.5, 1.0, 1.0, 1.0])


def test_two_array_topk_fill_loses_ties():
    """IDX_FILL padding lanes lose every distance tie, so a real lane
    with distance == BIG-sentinel still beats padding."""
    d = jnp.asarray([3.0, 1.0, 2.0], jnp.float32)
    i = jnp.asarray([0, 1, 2], jnp.int32)
    td, ti = bitonic_topk((d, i), 4, dist_idx_less,
                          (np.float32(3.0), IDX_FILL))
    assert np.asarray(ti).tolist() == [1, 2, 0, IDX_FILL]
    np.testing.assert_allclose(np.asarray(td), [1.0, 2.0, 3.0, 3.0])


def test_two_array_merge_tracks_payload():
    """bitonic_merge_sorted moves the idx payload in lockstep with the
    dist key: merged (dist, idx) pairs stay true pairs."""
    rng = np.random.default_rng(4)
    da = np.sort(rng.standard_normal((2, 8)).astype(np.float32), axis=-1)
    db = np.sort(rng.standard_normal((2, 8)).astype(np.float32), axis=-1)
    ia = np.arange(0, 8, dtype=np.int32) * 2 + np.zeros((2, 1), np.int32)
    ib = np.arange(0, 8, dtype=np.int32) * 2 + 1
    ib = np.broadcast_to(ib, (2, 8)).astype(np.int32)
    md, mi = bitonic_merge_sorted(
        (jnp.asarray(da), jnp.asarray(ia)),
        (jnp.asarray(db), jnp.asarray(ib)), dist_idx_less)
    md, mi = np.asarray(md), np.asarray(mi)
    assert (np.diff(md, axis=-1) >= 0).all()
    # every output pair exists in the input pair set, per row
    for r in range(2):
        pairs_in = {(float(d), int(i)) for d, i in
                    list(zip(da[r], ia[r])) + list(zip(db[r], ib[r]))}
        for d, i in zip(md[r], mi[r]):
            assert (float(d), int(i)) in pairs_in
    # and they are the 8 smallest distances of the union
    np.testing.assert_allclose(
        md, np.sort(np.concatenate([da, db], axis=-1), axis=-1)[:, :8])


def test_networks_handle_batched_leading_dims():
    rng = np.random.default_rng(5)
    keys, _ = _rand_keys(rng, 2, 3, 4, 16)
    out = np.asarray(topk_keys(keys, 8))
    assert out.shape == (2, 3, 4, 8)
    expect = np.sort(np.asarray(keys), axis=-1)[..., :8]
    np.testing.assert_array_equal(out, expect)


def test_sort_keys_unique_distances_roundtrip():
    """Unique distances: sort_keys orders exactly like argsort on the
    float distances, and unpack recovers the permutation."""
    rng = np.random.default_rng(6)
    bits = idx_bits_for(128)
    d = rng.permutation(32).astype(np.float32)
    idx = jnp.arange(32, dtype=jnp.int32)
    keys = pack_keys(jnp.asarray(d), idx, bits)
    _, si = unpack_keys(sort_keys(keys), bits)
    np.testing.assert_array_equal(np.asarray(si), np.argsort(d, kind="stable"))


def test_comparators():
    a = (jnp.asarray([1.0, 2.0]), jnp.asarray([3, 1]))
    b = (jnp.asarray([2.0, 2.0]), jnp.asarray([0, 2]))
    np.testing.assert_array_equal(np.asarray(dist_idx_less(a, b)),
                                  [True, True])
    np.testing.assert_array_equal(
        np.asarray(key_less((jnp.asarray([3, 5]),), (jnp.asarray([4, 5]),))),
        [True, False])


@settings(deadline=None)
@given(
    dists=st.lists(st.integers(min_value=0, max_value=6),
                   min_size=1, max_size=70),
    kd=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_topk_keys_matches_sort(dists, kd, seed):
    if not HAVE_HYPOTHESIS:  # pragma: no cover - shim path
        pytest.skip("hypothesis not installed")
    rng = np.random.default_rng(seed)
    m = 256
    bits = idx_bits_for(m)
    d = np.asarray(dists, np.float32)
    idx = rng.integers(0, m, len(dists)).astype(np.int32)
    keys = pack_keys(jnp.asarray(d), jnp.asarray(idx), bits)
    k_pad = next_pow2(kd)
    out = np.asarray(topk_keys(keys, k_pad))
    ref = np.sort(np.concatenate(
        [np.asarray(keys),
         np.full(max(0, k_pad - len(dists)), INT_BIG, np.int32)]))
    np.testing.assert_array_equal(out, ref[:k_pad])
