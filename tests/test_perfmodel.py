"""The paper's Table I cycle model must reproduce exactly."""

from repro.core import (
    FPGAConfig,
    digc_hbm_bytes,
    fpga_cycles,
    fpga_latency_ms,
    tpu_digc_estimate,
    vig_resolution_to_nodes,
)


def test_table1_vig_tiny():
    # ViG-Tiny: N=M=196, D=192, k=8 with the paper's static parallelism.
    cyc = fpga_cycles(196, 196, 192, 8)
    assert cyc == {"DCM": 4704, "LSM": 3920, "GMM": 4704, "NSM": 224}


def test_latency_positive_and_scales():
    t1 = fpga_latency_ms(196, 196, 192, 8)
    t2 = fpga_latency_ms(4 * 196, 4 * 196, 192, 8)
    assert 0 < t1 < t2


def test_streaming_traffic_beats_naive():
    n = m = vig_resolution_to_nodes(1024)  # 4096 nodes
    s = digc_hbm_bytes(n, m, 192, 16, block_n=512, streaming=True)
    naive = digc_hbm_bytes(n, m, 192, 16, block_n=512, streaming=False)
    assert naive / s > 5  # the paper's memory-traffic claim
    # bigger node blocks amortize co-node re-reads (fewer Y sweeps)
    s_small = digc_hbm_bytes(n, m, 192, 16, block_n=64, streaming=True)
    assert s_small > s

def test_resolution_to_nodes():
    assert vig_resolution_to_nodes(224, 16) == 196
    assert vig_resolution_to_nodes(2048, 16) == 128 * 128
    assert vig_resolution_to_nodes(2048, 16, reduction=2) == 64 * 64


def test_tpu_estimate_fields():
    est = tpu_digc_estimate(4096, 4096, 192, 9, 1)
    assert est["flops"] == 2 * 4096 * 4096 * 192
    assert est["bound"] in ("compute", "memory", "merge")
    assert est["traffic_saving"] > 1
    assert est["latency_s"] > 0
