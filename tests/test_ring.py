"""Ring-DIGC (distributed GMM): exactness vs single-device reference.

Runs in a subprocess so the 8-device XLA host-platform flag never leaks
into the main test process (which must see 1 device).
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(snippet: str) -> str:
    code = textwrap.dedent(snippet)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


@pytest.mark.slow
def test_ring_digc_exact():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(2)
        for (N, M, D, k, dil) in [(64, 64, 16, 4, 1), (120, 100, 32, 4, 2), (16, 24, 8, 2, 1)]:
            x = jnp.asarray(rng.randn(N, D), jnp.float32)
            y = jnp.asarray(rng.randn(M, D), jnp.float32)
            ir, dr = digc(x, y, k=k, dilation=dil, impl="reference", return_dists=True)
            with mesh:
                ig, dg = ring_digc(x, y, k=k, dilation=dil, mesh=mesh, return_dists=True)
            assert bool(jnp.all(ir == ig)), (N, M)
            assert bool(jnp.allclose(dr, dg, rtol=1e-5, atol=1e-4)), (N, M)
        print("RING_OK")
        """
    )
    assert "RING_OK" in out


@pytest.mark.slow
def test_ring_digc_self_graph():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(80, 24), jnp.float32)
        ir = digc(x, k=5, impl="reference")
        with mesh:
            ig = ring_digc(x, k=5, mesh=mesh)
        assert bool(jnp.all(ir == ig))
        print("RING_SELF_OK")
        """
    )
    assert "RING_SELF_OK" in out


@pytest.mark.slow
def test_ring_digc_batched_registry():
    """(B, N, D) through the registry == stacked per-image reference."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec, digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(4)
        x = jnp.asarray(rng.randn(2, 64, 16), jnp.float32)
        ir = digc(x, k=4, impl="reference")
        spec = DigcSpec(impl="ring", k=4, mesh=mesh)
        with mesh:
            ig = digc(x, spec=spec)
        assert ig.shape == (2, 64, 4), ig.shape
        assert bool(jnp.all(ir == ig))
        print("RING_BATCHED_OK")
        """
    )
    assert "RING_BATCHED_OK" in out
