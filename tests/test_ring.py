"""Ring-DIGC (distributed GMM): exactness vs single-device reference,
and the functional-state contract (DESIGN.md §10).

The 8-device tests run in a subprocess so the XLA host-platform flag
never leaks into the main test process (which must see 1 device); the
4-device parity tests below do the same but at tiny shapes, so they
run fast enough for the tier-1 job. The fast tests ride a degenerate
1-device mesh in the main process.
"""

import numpy as np

from _subproc import run_snippet


# ---------------------------------------------------------------------------
# Fast (1-device mesh, main process): batched parity + state contract


def test_ring_batched_parity_and_state_contract():
    """Batched ring == reference on a 1-device mesh, and the ring
    builder is a ``supports_state`` tier: a frozen-gallery entry
    (explicit co-nodes, matching sq_y shape) advances its counters and
    carries the co-node norms — the sharded analogue of the blocked
    tier's gallery hook."""
    import jax
    import jax.numpy as jnp

    from repro.core import DigcSpec, digc
    from repro.core.builder import get_builder
    from repro.core.state import DigcState, state_entry

    assert get_builder("ring").supports_state
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 48, 12), jnp.float32)
    y = jnp.asarray(rng.randn(2, 40, 12), jnp.float32)
    i_ref = digc(x, y, k=4, impl="reference")
    spec = DigcSpec(impl="ring", k=4, mesh=mesh)
    with mesh:
        i_ring = digc(x, y, spec=spec)
        st = DigcState.init({"ring0": state_entry(sq_y_shape=(2, 40),
                                                  rows=2)})
        i_cold, st1 = digc(x, y, spec=spec, state=st, state_key="ring0")
        i_warm, st2 = digc(x, y, spec=spec, state=st1, state_key="ring0")
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_ring))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_cold))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_warm))
    assert st1.steps() == {"ring0": 1} and st2.steps() == {"ring0": 2}
    assert st1.row_steps() == {"ring0": [1, 1]}
    # shared 2D gallery next to batched nodes (the frozen-gallery
    # spelling) broadcasts, as it did before the batched rewrite
    from repro.core.ring import ring_digc

    with mesh:
        i_shared = ring_digc(x, y[0], k=4, mesh=mesh)
    i_shared_ref = digc(x, jnp.broadcast_to(y[0][None], y.shape),
                        k=4, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_shared),
                                  np.asarray(i_shared_ref))
    # the cold pass wrote the true gallery norms into the entry
    np.testing.assert_allclose(
        np.asarray(st1.entries["ring0"].sq_y),
        np.asarray(jnp.sum(y.astype(jnp.float32) ** 2, -1)),
        rtol=1e-6,
    )


def test_ring_state_entry_planned():
    """PR-4 pinned this as a strict xfail ("core/ring.py is outside the
    functional-state path"); the ROADMAP sharded-serving item landed, so
    it is now the live contract: ``digc(impl="ring", state=...)``
    advances a DigcState entry the same way the blocked tier carries
    its frozen-gallery norms (self-graph calls advance counters only —
    their co-nodes drift every call, so norms are never carried)."""
    import jax
    import jax.numpy as jnp

    from repro.core import DigcSpec, digc
    from repro.core.builder import get_builder
    from repro.core.state import DigcState, state_entry

    assert get_builder("ring").supports_state
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.RandomState(6).randn(32, 8), jnp.float32)
    st = DigcState.init({"r": state_entry(sq_y_shape=(1, 32))})
    with mesh:
        _, new_st = digc(x, spec=DigcSpec(impl="ring", k=3, mesh=mesh),
                         state=st, state_key="r")
    assert new_st.steps() == {"r": 1}
    # self-graph: norms not carried (the gallery is this call's x)
    np.testing.assert_array_equal(
        np.asarray(new_st.entries["r"].sq_y), 0.0)


def test_ring_warm_gate_engages_stale_norms():
    """Proof the warm path actually *reads* the carried norms (not a
    silent recompute): poisoning one co-node's carried norm on a warm
    entry pushes that co-node out of every neighbor list."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import DigcSpec, digc
    from repro.core.state import DigcState, state_entry

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(1, 24, 8), jnp.float32)
    y = jnp.asarray(rng.randn(1, 16, 8), jnp.float32)
    spec = DigcSpec(impl="ring", k=4, mesh=mesh)
    st = DigcState.init({"r": state_entry(sq_y_shape=(1, 16), rows=1)})
    i_ref, st1 = digc(x, y, spec=spec, state=st, state_key="r")
    victim = int(np.asarray(i_ref)[0, 0, 0])
    poisoned = dataclasses.replace(
        st1.entries["r"],
        sq_y=st1.entries["r"].sq_y.at[:, victim].add(1e9),
    )
    i_pois, _ = digc(x, y, spec=spec, state=st1.set("r", poisoned),
                     state_key="r")
    assert victim not in np.asarray(i_pois)
    # and a *cold* row ignores the poison entirely (per-row gate)
    cold = dataclasses.replace(
        poisoned, row_step=jnp.zeros((1,), jnp.int32))
    i_cold, _ = digc(x, y, spec=spec, state=st1.set("r", cold),
                     state_key="r")
    np.testing.assert_array_equal(np.asarray(i_cold), np.asarray(i_ref))


def test_ring_mesh_shape_in_workload_key():
    """Sharded workloads key separately in the tune cache: the mesh
    shape rides ``DigcSpec.mesh_shape()`` into ``workload_key`` and
    unsharded keys are unchanged (the committed cache stays valid)."""
    import jax

    from repro.core import DigcSpec, workload_key

    mesh = jax.make_mesh((1,), ("data",))
    spec = DigcSpec(impl="ring", k=4, mesh=mesh)
    assert spec.mesh_shape() == (1,)
    assert DigcSpec(impl="blocked", k=4).mesh_shape() is None
    base = workload_key(1, 64, 64, 16, 4)
    assert workload_key(1, 64, 64, 16, 4, mesh_shape=(4,)) == base + ":mesh4"
    assert workload_key(1, 64, 64, 16, 4, mesh_shape=None) == base


# ---------------------------------------------------------------------------
# Multi-device subprocess tests


def _run(snippet: str, *, devices: int = 8, timeout: int = 600) -> str:
    return run_snippet(snippet, devices=devices, timeout=timeout).stdout


# -- fast 4-device parity (tiny shapes, tier-1) -----------------------------


def test_ring_4dev_parity_warm_cold_and_sharded_state():
    """One subprocess, 4 forced host devices, tiny shapes (fast on
    CPU): ring-sharded construction == single-device blocked bitwise;
    a frozen-gallery entry placed with a PartitionSpec stays 4-way
    sharded through a warm round-trip; warm == cold bitwise; a 2D
    (rows x ring) mesh shards the batch rows data-parallel."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import DigcSpec, digc
        from repro.core.state import DigcState, state_entry
        assert jax.device_count() == 4
        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(2, 48, 12), jnp.float32)
        y = jnp.asarray(rng.randn(2, 40, 12), jnp.float32)
        i_blk = digc(x, y, k=4, impl="blocked")
        spec = DigcSpec(impl="ring", k=4, mesh=mesh)
        # stateless sharded == single-device blocked, bitwise
        assert bool(jnp.all(digc(x, y, spec=spec) == i_blk))
        # sharded frozen-gallery entry: cold -> warm, bitwise stable
        e = state_entry(sq_y_shape=(2, 40), rows=2, mesh=mesh)
        assert len(e.sq_y.addressable_shards) == 4
        # ragged co-node count: replicated fallback (placement is a
        # performance choice, never a semantic one)
        ragged = state_entry(sq_y_shape=(1, 7), mesh=mesh)
        assert ragged.sq_y.sharding.spec == P()
        st = DigcState.init({"r": e})
        i_cold, st1 = digc(x, y, spec=spec, state=st, state_key="r")
        assert len(st1.entries["r"].sq_y.addressable_shards) == 4
        i_warm, st2 = digc(x, y, spec=spec, state=st1, state_key="r")
        assert bool(jnp.all(i_cold == i_blk))
        assert bool(jnp.all(i_warm == i_blk))
        assert st2.steps() == {"r": 2}
        # mixed warm/cold rows (multi-tenant batch) still exact
        import dataclasses
        mixed = dataclasses.replace(
            st1.entries["r"], row_step=jnp.asarray([1, 0], jnp.int32))
        i_mix, _ = digc(x, y, spec=spec, state=st1.set("r", mixed),
                        state_key="r")
        assert bool(jnp.all(i_mix == i_blk))
        # 2D mesh: data-parallel batch rows x ring-sharded co-nodes
        mesh2 = jax.make_mesh((2, 2), ("rows", "ring"))
        spec2 = DigcSpec(impl="ring", k=4, mesh=mesh2, axis_name="ring",
                         batch_axis="rows")
        assert bool(jnp.all(digc(x, y, spec=spec2) == i_blk))
        print("RING_4DEV_OK")
        """,
        devices=4,
    )
    assert "RING_4DEV_OK" in out


# -- 8-device exhaustive (fast tier since the CPU-platform pin) -------------


def test_ring_digc_exact():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(2)
        for (N, M, D, k, dil) in [(64, 64, 16, 4, 1), (120, 100, 32, 4, 2), (16, 24, 8, 2, 1)]:
            x = jnp.asarray(rng.randn(N, D), jnp.float32)
            y = jnp.asarray(rng.randn(M, D), jnp.float32)
            ir, dr = digc(x, y, k=k, dilation=dil, impl="reference", return_dists=True)
            with mesh:
                ig, dg = ring_digc(x, y, k=k, dilation=dil, mesh=mesh, return_dists=True)
            assert bool(jnp.all(ir == ig)), (N, M)
            assert bool(jnp.allclose(dr, dg, rtol=1e-5, atol=1e-4)), (N, M)
        print("RING_OK")
        """
    )
    assert "RING_OK" in out


def test_ring_digc_self_graph():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(80, 24), jnp.float32)
        ir = digc(x, k=5, impl="reference")
        with mesh:
            ig = ring_digc(x, k=5, mesh=mesh)
        assert bool(jnp.all(ir == ig))
        print("RING_SELF_OK")
        """
    )
    assert "RING_SELF_OK" in out


def test_ring_digc_batched_registry():
    """(B, N, D) through the registry == stacked per-image reference —
    one shard_map program for the whole batch (the per-image unroll is
    gone), state passing through jit with donation."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec, digc
        from repro.core.state import DigcState, state_entry
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(4)
        x = jnp.asarray(rng.randn(2, 64, 16), jnp.float32)
        ir = digc(x, k=4, impl="reference")
        spec = DigcSpec(impl="ring", k=4, mesh=mesh)
        with mesh:
            ig = digc(x, spec=spec)
        assert ig.shape == (2, 64, 4), ig.shape
        assert bool(jnp.all(ir == ig))
        # donated jit round-trip of a sharded frozen-gallery entry
        y = jnp.asarray(rng.randn(2, 64, 16), jnp.float32)
        iry = digc(x, y, k=4, impl="reference")
        st = DigcState.init({"r": state_entry(sq_y_shape=(2, 64), rows=2,
                                              mesh=mesh)})
        f = jax.jit(lambda a, b, s: digc(a, b, spec=spec, state=s,
                                         state_key="r"),
                    donate_argnums=(2,))
        i1, st1 = f(x, y, st)
        i2, st2 = f(x, y, st1)
        assert bool(jnp.all(i1 == iry)) and bool(jnp.all(i2 == iry))
        assert st2.steps() == {"r": 2}
        print("RING_BATCHED_OK")
        """
    )
    assert "RING_BATCHED_OK" in out
