"""Ring-DIGC (distributed GMM): exactness vs single-device reference.

The multi-device tests run in a subprocess so the 8-device XLA
host-platform flag never leaks into the main test process (which must
see 1 device); the fast tests below ride a degenerate 1-device mesh in
the main process.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


# ---------------------------------------------------------------------------
# Fast (1-device mesh, main process): batched parity + state contract


def test_ring_batched_parity_state_passthrough():
    """Batched ring == reference on a 1-device mesh, and — documenting
    the current contract — the ring builder sits **outside** the
    functional-state path: ``digc(state=)`` passes the state through
    untouched (no counters advance, no co-node shard norms are carried
    across hops). The ROADMAP sharded-serving item adds a ring state
    entry; ``test_ring_state_entry_planned`` flips when it lands."""
    import jax
    import jax.numpy as jnp

    from repro.core import DigcSpec, digc
    from repro.core.builder import get_builder
    from repro.core.state import DigcState, state_entry

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 48, 12), jnp.float32)
    i_ref = digc(x, k=4, impl="reference")
    spec = DigcSpec(impl="ring", k=4, mesh=mesh)
    with mesh:
        i_ring = digc(x, spec=spec)
        st = DigcState.init({"ring0": state_entry(sq_y_shape=(2, 48),
                                                  rows=2)})
        i_st, new_st = digc(x, spec=spec, state=st, state_key="ring0")
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_ring))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_st))
    # passthrough: not supports_state => entry untouched, counters cold
    assert not get_builder("ring").supports_state
    assert new_st.steps() == {"ring0": 0}
    assert new_st.row_steps() == {"ring0": [0, 0]}
    np.testing.assert_array_equal(
        np.asarray(new_st.entries["ring0"].sq_y), 0.0)


@pytest.mark.xfail(
    strict=True,
    reason="core/ring.py is outside the functional-state path: no "
    "co-node shard-norm state entry yet (ROADMAP: sharded serving — "
    "a ring builder state entry would let DigcState ride shard_map "
    "for pod-level serving). This test flips to XPASS, and must be "
    "rewritten into a real parity test, when that item lands.",
)
def test_ring_state_entry_planned():
    """The planned contract: the ring builder advances a DigcState
    entry (carrying per-shard co-node norms across requests) the same
    way the blocked tier carries its frozen-gallery norms."""
    import jax
    import jax.numpy as jnp

    from repro.core import DigcSpec, digc
    from repro.core.builder import get_builder
    from repro.core.state import DigcState, state_entry

    assert get_builder("ring").supports_state
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.RandomState(6).randn(32, 8), jnp.float32)
    st = DigcState.init({"r": state_entry(sq_y_shape=(1, 32))})
    with mesh:
        _, new_st = digc(x, spec=DigcSpec(impl="ring", k=3, mesh=mesh),
                         state=st, state_key="r")
    assert new_st.steps() == {"r": 1}


# ---------------------------------------------------------------------------
# Multi-device subprocess tests (slow)


def _run(snippet: str) -> str:
    code = textwrap.dedent(snippet)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


@pytest.mark.slow
def test_ring_digc_exact():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(2)
        for (N, M, D, k, dil) in [(64, 64, 16, 4, 1), (120, 100, 32, 4, 2), (16, 24, 8, 2, 1)]:
            x = jnp.asarray(rng.randn(N, D), jnp.float32)
            y = jnp.asarray(rng.randn(M, D), jnp.float32)
            ir, dr = digc(x, y, k=k, dilation=dil, impl="reference", return_dists=True)
            with mesh:
                ig, dg = ring_digc(x, y, k=k, dilation=dil, mesh=mesh, return_dists=True)
            assert bool(jnp.all(ir == ig)), (N, M)
            assert bool(jnp.allclose(dr, dg, rtol=1e-5, atol=1e-4)), (N, M)
        print("RING_OK")
        """
    )
    assert "RING_OK" in out


@pytest.mark.slow
def test_ring_digc_self_graph():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import digc
        from repro.core.ring import ring_digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(80, 24), jnp.float32)
        ir = digc(x, k=5, impl="reference")
        with mesh:
            ig = ring_digc(x, k=5, mesh=mesh)
        assert bool(jnp.all(ir == ig))
        print("RING_SELF_OK")
        """
    )
    assert "RING_SELF_OK" in out


@pytest.mark.slow
def test_ring_digc_batched_registry():
    """(B, N, D) through the registry == stacked per-image reference."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec, digc
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(4)
        x = jnp.asarray(rng.randn(2, 64, 16), jnp.float32)
        ir = digc(x, k=4, impl="reference")
        spec = DigcSpec(impl="ring", k=4, mesh=mesh)
        with mesh:
            ig = digc(x, spec=spec)
        assert ig.shape == (2, 64, 4), ig.shape
        assert bool(jnp.all(ir == ig))
        print("RING_BATCHED_OK")
        """
    )
    assert "RING_BATCHED_OK" in out
