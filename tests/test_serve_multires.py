"""Multi-resolution serving: ragged N as a bucket dimension (§13).

The acceptance contract for the (B, N) lattice:

* **Parity**: a mixed-size ragged trace through one ``VigServeEngine``
  (``image_sizes=``) must match, per request, the same-resolution B=1
  replay of its own (tenant, size) stream — warm state follows the
  tenant per N-bucket, across bucket changes AND across
  eviction/parking (the parked copy carries every N-bucket's rows).
* **Bit-identity**: with B=1 cells, every served row is bit-identical
  (CPU) to the jitted B=1 same-resolution replay; a padded (masked)
  request is bit-identical to the B=1 replay of the same padded
  forward, and pad nodes provably never enter a live row's top-k
  (DIGC-level bitwise check).
* **Program bound**: at most |buckets| x |image_sizes| compiled
  programs for a whole mixed trace (``on_compile`` sees (size, bucket)
  cells).
* **Typed config/submit errors**: odd-grid pyramids fail at engine
  construction naming the stage and grid; off-lattice submissions fail
  at the submitter naming the field.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DigcSpec, digc
from repro.models import vig
from repro.models.module import init_params
from repro.models.vig import VigGridError
from repro.serve.engine import VigRequest, VigServeEngine
from _subproc import run_snippet


def _tiny_vig(impl):
    """16x16 / patch 4 -> native N=16 grid; single stage, r=1."""
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3, digc_impl=impl,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _image(rng, s=16):
    return rng.standard_normal((s, s, 3)).astype(np.float32)


def _replay_stream(cfg, params, impl, reqs, size):
    """Jitted B=1 stateful replay of one (tenant, size) stream — the
    same program shape a B=1 cell serves, so comparisons against B=1
    cells are bitwise and against padded buckets are allclose."""
    state = vig.init_vig_state(cfg, 1, impl, per_slot=True,
                               grid=size // cfg.patch)
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl=impl,
                                         state=s)
    )
    outs = []
    for r in reqs:
        logits, state = fwd(params, jnp.asarray(r.image)[None], state)
        outs.append(np.asarray(logits)[0])
    return outs


# ---------------------------------------------------------------------------
# Parity: one engine, mixed 16/24/32 trace == per-(tenant, size) replay


def test_mixed_trace_matches_same_resolution_replay():
    """Tenants x sizes interleave on a 2-slot engine (so eviction +
    multi-bucket parking fire): every request matches its own
    (tenant, size) B=1 replay — the cluster tier's centroid carry makes
    any cold-vs-warm or cross-bucket state leak visible — and the
    program count stays <= |buckets| x |image_sizes|."""
    cfg, params = _tiny_vig("cluster")
    compiled = []
    eng = VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                         buckets=(1, 2), image_sizes=(16, 24, 32),
                         on_compile=compiled.append)
    rng = np.random.default_rng(11)
    waves = [
        [("A", 16)], [("B", 24), ("C", 16)], [("A", 16), ("B", 24)],
        [("C", 32)], [("A", 24)], [("A", 16), ("C", 16)], [("B", 24)],
    ]
    streams: dict[tuple, list[VigRequest]] = {}
    uid = 0
    for wave in waves:
        for t, s in wave:
            req = VigRequest(uid=uid, image=_image(rng, s), tenant=t)
            streams.setdefault((t, s), []).append(req)
            eng.submit(req)
            uid += 1
        # a wave may span several cells -> several ticks
        while eng.queue:
            eng.step()
            assert eng.last_cell is not None
            size, bucket = eng.last_cell
            assert bucket == eng.bucket_for(len(eng.last_lanes))
    for (t, s), reqs in streams.items():
        refs = _replay_stream(cfg, params, "cluster", reqs, s)
        for req, ref in zip(reqs, refs):
            assert req.done and req.fault is None
            np.testing.assert_allclose(req.logits, ref, rtol=1e-5,
                                       atol=1e-5)
    assert eng.compile_count <= len(eng.buckets) * len(eng.image_sizes)
    assert eng.compile_count == len(set(compiled))
    assert all(s in eng.image_sizes and b in eng.buckets
               for s, b in compiled)
    # the trace crossed slots: at least one eviction parked rows for
    # MULTIPLE N-buckets (the {size: rows} layout)
    assert eng.park_hits + len(eng._parked) >= 1


def test_eviction_parks_and_restores_every_n_bucket():
    """A tenant warm at two resolutions, LRU-evicted, must come back
    warm at BOTH: the parked copy is keyed by N-bucket."""
    cfg, params = _tiny_vig("cluster")
    eng = VigServeEngine(cfg, params, digc_impl="cluster", autotune=False,
                         buckets=(1,), image_sizes=(16, 24))
    rng = np.random.default_rng(3)
    for uid, (t, s) in enumerate([("A", 16), ("A", 24)]):
        eng.submit(VigRequest(uid=uid, image=_image(rng, s), tenant=t))
        eng.run()
    a_slot = eng._tenant_slot["A"]
    assert eng.slot_row_steps(16)["stage0"][a_slot] == 2
    assert eng.slot_row_steps(24)["stage0"][a_slot] == 2
    # evict A by filling the slot ring with fresh tenants
    for uid, t in enumerate(["B", "C"], start=10):
        eng.submit(VigRequest(uid=uid, image=_image(rng), tenant=t))
        eng.run()
    assert "A" in eng._parked
    assert set(eng._parked["A"]) == {16, 24}  # every N-bucket parked
    # re-admit: A's row counters continue from the parked copy at both
    # sizes (a cold admit would restart the count from zero)
    eng.submit(VigRequest(uid=20, image=_image(rng, 16), tenant="A"))
    eng.run()
    assert eng.park_hits == 1
    a_slot = eng._tenant_slot["A"]
    assert eng.slot_row_steps(16)["stage0"][a_slot] == 4
    assert eng.slot_row_steps(24)["stage0"][a_slot] == 2


# ---------------------------------------------------------------------------
# Bit-identity (CPU): B=1 cells vs the jitted B=1 replay


def test_b1_cells_bitwise_identical_to_replay():
    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         buckets=(1,), image_sizes=(16, 24))
    rng = np.random.default_rng(5)
    streams: dict[tuple, list[VigRequest]] = {}
    for uid, (t, s) in enumerate(
        [("A", 16), ("B", 24), ("A", 16), ("B", 24), ("A", 24)]
    ):
        req = VigRequest(uid=uid, image=_image(rng, s), tenant=t)
        streams.setdefault((t, s), []).append(req)
        eng.submit(req)
    eng.run()
    for (t, s), reqs in streams.items():
        refs = _replay_stream(cfg, params, "blocked", reqs, s)
        for req, ref in zip(reqs, refs):
            np.testing.assert_array_equal(req.logits, ref)


def test_padded_request_bitwise_vs_masked_replay():
    """A ragged 20px request served through the 24px cell's masked
    program is bit-identical to the B=1 replay of the same padded
    forward (same canvas, same mask) — the pad-isolation contract at
    the engine boundary."""
    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         buckets=(1,), image_sizes=(24,))
    rng = np.random.default_rng(9)
    img = _image(rng, 20)
    req = VigRequest(uid=0, image=img, tenant="P")
    eng.submit(req)
    assert req._serve_size == 24
    mask = np.asarray(req._serve_mask)
    assert mask.sum() == (20 // 4) ** 2 and mask.size == (24 // 4) ** 2
    eng.run()
    assert req.done and req.fault is None
    canvas = np.zeros((24, 24, 3), np.float32)
    canvas[:20, :20] = img
    state = vig.init_vig_state(cfg, 1, "blocked", per_slot=True, grid=6)
    fwd = jax.jit(
        lambda p, im, s, mv: vig.vig_forward(
            p, im, cfg, digc_impl="blocked", state=s, valid_mask=mv)
    )
    ref, _ = fwd(params, jnp.asarray(canvas)[None], state,
                 jnp.asarray(mask)[None])
    np.testing.assert_array_equal(req.logits, np.asarray(ref)[0])


@pytest.mark.parametrize("impl", ["reference", "blocked"])
def test_pad_nodes_never_enter_live_topk(impl):
    """DIGC-level bitwise pad isolation: appending garbage pad nodes
    under an m_valid mask leaves every live row's top-k — indices AND
    the selection itself — exactly the live-only build's."""
    rng = np.random.default_rng(1)
    n0, n_pad, d = 20, 12, 8
    x_live = jnp.asarray(rng.standard_normal((2, n0, d)), jnp.float32)
    pads = jnp.asarray(100.0 * rng.standard_normal((2, n_pad, d)),
                       jnp.float32)
    x_pad = jnp.concatenate([x_live, pads], axis=1)
    mask = np.zeros(n0 + n_pad, bool)
    mask[:n0] = True
    spec = DigcSpec(impl=impl, k=4)
    idx_live = np.asarray(digc(x_live, spec=spec))
    idx_pad = np.asarray(digc(x_pad, spec=spec,
                              m_valid=jnp.asarray(mask)))
    np.testing.assert_array_equal(idx_pad[:, :n0], idx_live)
    assert (idx_pad[:, :n0] < n0).all()  # no pad index ever selected


def test_pad_mask_rejected_by_incapable_tier():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 16, 8)), jnp.float32)
    with pytest.raises(ValueError, match="pad-node masking"):
        digc(x, spec=DigcSpec(impl="cluster", k=3),
             m_valid=jnp.ones(16, bool))


# ---------------------------------------------------------------------------
# Typed errors: odd grids at construction, off-lattice submits


def test_odd_grid_pyramid_raises_at_engine_construction():
    """A size whose grid goes odd before a downsample (or indivisible
    by a pooling ratio) must fail when the engine is built — a typed
    VigGridError naming the stage and grid, not a mid-tick reshape
    crash."""
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16, 16), depths=(1, 1),
        num_classes=3, k=3, digc_impl="blocked",
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    with pytest.raises(VigGridError, match=r"stage0: grid 5.*downsample"):
        VigServeEngine(cfg, params, autotune=False,
                       image_sizes=(16, 20))
    pooled = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        reduce_ratios=(4,), num_classes=3, k=3, digc_impl="blocked",
    )
    pooled_params = init_params(vig.vig_param_spec(pooled),
                                jax.random.PRNGKey(0))
    with pytest.raises(VigGridError, match=r"stage0: grid 6.*reduce"):
        VigServeEngine(pooled, pooled_params, autotune=False,
                       image_sizes=(24,))


def test_submit_typed_errors_on_the_lattice():
    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         image_sizes=(16, 24))
    with pytest.raises(ValueError, match="non-square"):
        eng.submit(VigRequest(uid=0,
                              image=np.zeros((16, 24, 3), np.float32)))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(VigRequest(uid=1,
                              image=np.zeros((32, 32, 3), np.float32)))
    with pytest.raises(ValueError, match="divisible"):
        eng.submit(VigRequest(uid=2,
                              image=np.zeros((18, 18, 3), np.float32)))
    # a pooled pyramid cannot take pad nodes: typed refusal at submit
    pooled = cfg.replace(reduce_ratios=(2,))
    pooled_params = init_params(vig.vig_param_spec(pooled),
                                jax.random.PRNGKey(0))
    eng2 = VigServeEngine(pooled, pooled_params, autotune=False,
                          image_sizes=(16, 32))
    with pytest.raises(ValueError, match="pad nodes"):
        eng2.submit(VigRequest(uid=3,
                               image=np.zeros((24, 24, 3), np.float32)))
    # without image_sizes= the legacy exact-shape contract holds
    legacy = VigServeEngine(cfg, params, digc_impl="blocked",
                            autotune=False)
    with pytest.raises(ValueError, match="shape"):
        legacy.submit(VigRequest(uid=4,
                                 image=np.zeros((8, 8, 3), np.float32)))


# ---------------------------------------------------------------------------
# Mesh divisibility: ticks pad to the batch axis instead of refusing


def test_mesh_tick_padding_serves_nondividing_bucket():
    """buckets=(3,) on a 2-device batch axis used to be refused at
    construction; now the tick pads to width 4 (replicating lane 0)
    and every row still matches its B=1 replay. Buckets smaller than
    the axis stay a typed construction error."""
    out = run_snippet(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec
        from repro.models import vig
        from repro.models.module import init_params
        from repro.serve.engine import VigRequest, VigServeEngine

        assert jax.device_count() == 4
        mesh = jax.make_mesh((2, 2), ("ring", "data"))
        cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
            image_size=16, patch=4, embed_dims=(16,), depths=(2,),
            num_classes=3, k=3, digc_impl="ring")
        params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))

        try:
            VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                           mesh=mesh, mesh_axis="ring",
                           mesh_batch_axis="data", buckets=(1, 3))
            raise SystemExit("small bucket accepted")
        except ValueError as e:
            assert "smaller than" in str(e), e

        eng = VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                             mesh=mesh, mesh_axis="ring",
                             mesh_batch_axis="data", buckets=(3,))
        assert eng._tick_width(3) == 4
        rng = np.random.default_rng(7)
        reqs = [VigRequest(uid=i,
                           image=rng.standard_normal((16, 16, 3))
                           .astype(np.float32), tenant=t)
                for i, t in enumerate("ABC")]
        for r in reqs:
            eng.submit(r)
        assert eng.step() == 3
        assert eng.last_bucket == 3

        spec = DigcSpec(impl="ring", mesh=mesh, axis_name="ring")
        fwd = jax.jit(lambda p, im, s: vig.vig_forward(
            p, im, cfg, digc_impl=spec, state=s))
        for r in reqs:
            st = vig.init_vig_state(cfg, 1, spec, per_slot=True,
                                    mesh=mesh, mesh_axis="ring")
            ref, _ = fwd(params, jnp.asarray(r.image)[None], st)
            np.testing.assert_allclose(r.logits, np.asarray(ref)[0],
                                       rtol=1e-5, atol=1e-5)
        print("MESH-PAD-OK")
        """,
        devices=4,
    ).stdout
    assert "MESH-PAD-OK" in out


# ---------------------------------------------------------------------------
# tune_reuse across N-buckets: per-N grouping + tau scaling


def test_tune_reuse_mixed_n_groups_and_scales_tau():
    from repro.core.tuner import scale_tau, tune_reuse

    assert scale_tau(0.0, 400, 100) == 0.0  # tau=0 stays exact
    assert scale_tau(0.1, 400, 100) == pytest.approx(0.2)
    assert scale_tau(0.1, 400, 400) == pytest.approx(0.1)

    rng = np.random.default_rng(4)
    h16 = rng.standard_normal((1, 16, 8)).astype(np.float32)
    h36 = rng.standard_normal((1, 36, 8)).astype(np.float32)
    # static streams at two N under ONE layer key: per-(key, N) grouping
    # must give each its own cache stream (interleaved N would otherwise
    # cross-compare snapshots and never reuse)
    ticks = [[("stage0", h16, None), ("stage0", h36, None)]
             for _ in range(4)]
    spec = DigcSpec(impl="blocked", k=3)
    tuned, results = tune_reuse(ticks, spec=spec, policy="layer",
                                taus=(0.05,), max_stale=8)
    assert tuned.reuse == "layer"
    static = [r for r in results if r.drift_tau == 0.05][0]
    assert static.reuse_frac > 0.5  # both streams reuse after warmup
    assert static.n is None  # mixed-N trace: no single node count
    # tau=0 bit-identity per bucket: nothing reuses, spec unchanged
    tuned0, results0 = tune_reuse(ticks, spec=spec, policy="layer",
                                  taus=(0.0,))
    assert results0[0].reuse_frac == 0.0
    assert tuned0.reuse is None or results0[0].admitted
    # single-N trace records its node count
    _, r16 = tune_reuse([[("stage0", h16, None)]] * 3, spec=spec,
                        policy="layer", taus=(0.05,))
    assert r16[0].n == 16
