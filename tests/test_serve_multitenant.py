"""Multi-tenant bucketed serving (DESIGN.md §9).

Three layers of proof that the slot/bucket/state lifecycle is sound:

* **Parity**: a ragged request trace through the bucketed
  ``VigServeEngine`` must match, per request, an unbatched B=1
  ``vig_forward`` replay of the same tenant's requests — for every
  tier, including after slot eviction + refill. Any cross-tenant state
  leak, padding-lane clobber, or per-row warm-gate bug breaks this.
* **Properties** (hypothesis, stubbed programs so no compiles): for
  arbitrary arrival sequences, (a) the chosen bucket is the smallest
  that fits the active slots, (b) padding lanes never mutate live
  ``DigcState`` rows, (c) compiled-program count stays ≤ the bucket-set
  size (asserted through the compile-counter hook).
* **LM engine regression**: ``ServeEngine``'s decode/prefill cache
  writes carry an explicit per-slot commit mask — mixed-length slots
  must decode exactly as if each were served alone, in ONE jitted call
  per tick (``decode_step`` takes the per-slot position vector; the
  call count is pinned).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.state import DigcState
from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigRequest, VigServeEngine

TIERS = ("reference", "blocked", "pallas", "cluster", "axial")


def _tiny_vig(impl):
    """16x16 / patch 4 -> N=16 grid; cluster runs full-probe (exact)."""
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3, digc_impl=impl,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _image(rng):
    return rng.standard_normal((16, 16, 3)).astype(np.float32)


def _replay_tenant(cfg, params, impl, reqs, *, state=None):
    """Unbatched B=1 stateful replay of one tenant's request stream.

    Returns (per-request logits, final state). ``state=None`` starts
    cold, matching a freshly admitted slot."""
    if state is None:
        state = vig.init_vig_state(cfg, 1, impl, per_slot=True)
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl=impl, state=s)
    )
    outs = []
    for r in reqs:
        logits, state = fwd(params, jnp.asarray(r.image)[None], state)
        outs.append(np.asarray(logits)[0])
    return outs, state


# ---------------------------------------------------------------------------
# Parity: bucketed multi-tenant trace == per-tenant unbatched replay


@pytest.mark.parametrize("impl", TIERS)
def test_bucketed_ragged_trace_matches_unbatched_replay(impl):
    """Tenants A/B/C interleave raggedly (tick sizes 1-3, buckets
    {1,2,4}); every request's logits must match the tenant's own B=1
    replay — warm state follows the tenant across bucket changes and
    never crosses tenants or padding lanes."""
    cfg, params = _tiny_vig(impl)
    eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                         buckets=(1, 2, 4))
    rng = np.random.default_rng(7)
    waves = [["A"], ["B", "C"], ["A", "B"], ["C"], ["A", "B", "C"]]
    per_tenant: dict[str, list[VigRequest]] = {}
    uid = 0
    for wave in waves:
        for t in wave:
            req = VigRequest(uid=uid, image=_image(rng), tenant=t)
            per_tenant.setdefault(t, []).append(req)
            eng.submit(req)
            uid += 1
        served = eng.step()
        assert served == len(wave)
        # bucket policy: smallest bucket that fits the wave
        assert eng.last_bucket == eng.bucket_for(len(wave))
    for t, reqs in per_tenant.items():
        refs, _ = _replay_tenant(cfg, params, impl, reqs)
        for req, ref in zip(reqs, refs):
            assert req.done
            np.testing.assert_allclose(req.logits, ref, rtol=1e-5, atol=1e-5)
    # at most |bucket set| compiled programs for the whole ragged trace
    assert eng.compile_count <= 3
    assert set(eng.stats()["bucket_ticks"]) <= {1, 2, 4}


def test_bucketed_full_width_trace_1_to_8():
    """The acceptance trace shape: tick sizes 1-8 interleaved on the
    default bucket set {1,2,4,8}. Every request matches the stateless
    unbatched forward (exact tier), with at most 4 compiled programs."""
    impl = "blocked"
    cfg, params = _tiny_vig(impl)
    eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False)
    assert eng.buckets == (1, 2, 4, 8) and eng.slots == 8
    rng = np.random.default_rng(23)
    uid = 0
    all_reqs = []
    for w, size in enumerate((1, 3, 8, 2, 5, 4, 7, 6)):
        wave = [VigRequest(uid=uid + i, image=_image(rng),
                           tenant=(w + i) % 8) for i in range(size)]
        uid += size
        all_reqs.extend(wave)
        for r in wave:
            eng.submit(r)
        assert eng.step() == size
        assert eng.last_bucket == eng.bucket_for(size)
    base = jax.jit(lambda p, im: vig.vig_forward(p, im, cfg,
                                                 digc_impl=impl))
    for r in all_reqs:
        ref = np.asarray(base(params, jnp.asarray(r.image)[None]))[0]
        np.testing.assert_allclose(r.logits, ref, rtol=1e-5, atol=1e-5)
    assert eng.compile_count <= 4
    assert set(eng.stats()["bucket_ticks"]) <= {1, 2, 4, 8}


def test_bucketed_eviction_refill_no_state_bleed():
    """Slot churn on the stateful tier: 3 tenants on 2 slots. The
    evicted slot's new tenant must serve **cold** (no warm start from
    the previous occupant's centroids), the surviving tenant must stay
    warm, and the returning tenant re-admits **warm** — its rows were
    parked host-side on eviction (LRU state parking, DESIGN.md §10)
    and restored on re-admit."""
    impl = "cluster"
    cfg, params = _tiny_vig(impl)
    eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                         buckets=(1, 2))
    rng = np.random.default_rng(11)
    mk = lambda t: VigRequest(uid=rng.integers(1 << 30), image=_image(rng),
                              tenant=t)

    # warm A and B over two ticks
    a1, b1 = mk("A"), mk("B")
    eng.submit(a1), eng.submit(b1)
    eng.step()
    a2, b2 = mk("A"), mk("B")
    eng.submit(a2), eng.submit(b2)
    eng.step()
    refs_a, _ = _replay_tenant(cfg, params, impl, [a1, a2])
    np.testing.assert_allclose(a2.logits, refs_a[1], rtol=1e-5, atol=1e-5)
    assert set(eng.slot_tenant) == {"A", "B"}

    # C arrives alone: evicts (and parks) the LRU slot, must serve cold
    c1 = mk("C")
    eng.submit(c1)
    eng.step()
    assert eng.last_resets  # a slot was reassigned (cold reset)
    ref_c, _ = _replay_tenant(cfg, params, impl, [c1])
    np.testing.assert_allclose(c1.logits, ref_c[0], rtol=1e-5, atol=1e-5)
    evicted = "A" if "A" not in eng.slot_tenant else "B"
    survivor = "B" if evicted == "A" else "A"
    assert evicted in eng._parked  # the evictee's rows were parked

    # the survivor's warm row must be untouched by C's admission tick
    s3 = mk(survivor)
    eng.submit(s3)
    eng.step()
    history = {"A": [a1, a2], "B": [b1, b2]}[survivor] + [s3]
    refs_s, _ = _replay_tenant(cfg, params, impl, history)
    np.testing.assert_allclose(s3.logits, refs_s[-1], rtol=1e-5, atol=1e-5)

    # the evicted tenant returns: restored WARM from its parked rows —
    # it must match the replay of its FULL history, not a cold start
    e4 = mk(evicted)
    eng.submit(e4)
    eng.step()
    assert eng.park_hits == 1 and eng.last_restores
    full = {"A": [a1, a2], "B": [b1, b2]}[evicted] + [e4]
    refs_e, _ = _replay_tenant(cfg, params, impl, full)
    np.testing.assert_allclose(e4.logits, refs_e[-1], rtol=1e-5, atol=1e-5)


def test_eviction_readmit_cold_when_parking_disabled():
    """park_capacity=0 restores the PR-4 contract: an evicted tenant's
    state is gone and it re-admits cold."""
    impl = "cluster"
    cfg, params = _tiny_vig(impl)
    eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                         buckets=(1, 2), park_capacity=0)
    rng = np.random.default_rng(12)
    mk = lambda t: VigRequest(uid=rng.integers(1 << 30), image=_image(rng),
                              tenant=t)
    a1, b1 = mk("A"), mk("B")
    eng.submit(a1), eng.submit(b1)
    eng.step()
    c1 = mk("C")
    eng.submit(c1)
    eng.step()
    evicted = "A" if "A" not in eng.slot_tenant else "B"
    assert not eng._parked
    e2 = mk(evicted)
    eng.submit(e2)
    eng.step()
    assert eng.park_hits == 0 and not eng.last_restores
    ref_cold, _ = _replay_tenant(cfg, params, impl, [e2])
    np.testing.assert_allclose(e2.logits, ref_cold[0], rtol=1e-5, atol=1e-5)


def test_parking_lru_capacity_and_release():
    """The parking tier is bounded LRU (oldest parked copy dropped at
    capacity) and an explicit release() drops the parked copy too."""
    eng = _stub_engine((1, 2), park=2)
    img = np.zeros((16, 16, 3), np.float32)
    uid = 0
    # churn 5 tenants through 2 slots: evictions park in LRU order
    for t in ("A", "B", "C", "D", "E"):
        eng.submit(VigRequest(uid=uid, image=img, tenant=t))
        uid += 1
        eng.step()
    # A..C were evicted in order; capacity 2 keeps only the last two
    assert list(eng._parked) == ["B", "C"]
    assert eng.park_evictions == 1  # A dropped at capacity
    # release drops both the slot binding and the parked copy
    eng.release("C")
    assert "C" not in eng._parked
    # a re-admitted parked tenant consumes its copy (restore-once)
    eng.submit(VigRequest(uid=uid, image=img, tenant="B"))
    eng.step()
    assert eng.park_hits == 1 and "B" not in eng._parked
    assert eng.last_restores and not eng.last_resets


def test_bucketed_padding_lanes_keep_warm_gate_and_idle_rows():
    """A single tenant on a bucket-4 engine: three lanes are padding
    every tick. The tenant must still engage its warm start on tick 2
    (padding lanes replicate a live row, so the all-warm fast path
    holds), idle slots' rows must stay exactly zero, and tenant
    release() must cold-reset the slot."""
    impl = "cluster"
    cfg, params = _tiny_vig(impl)
    eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                         buckets=(4,))
    rng = np.random.default_rng(13)
    reqs = [VigRequest(uid=i, image=_image(rng), tenant="A")
            for i in range(3)]
    for r in reqs[:2]:
        eng.submit(r)
        eng.step()
        assert eng.last_bucket == 4 and len(eng.last_lanes) == 1
    refs, _ = _replay_tenant(cfg, params, impl, reqs[:2])
    for r, ref in zip(reqs[:2], refs):
        np.testing.assert_allclose(r.logits, ref, rtol=1e-5, atol=1e-5)
    # the warm gate engaged: slot row counted once per block per request
    slot = eng._tenant_slot["A"]
    row_steps = eng.slot_row_steps()["stage0"]
    assert row_steps[slot] == 2 * sum(cfg.depths)
    # idle slots: never served, rows exactly zero
    ent = eng._slot_state.entries["stage0"]
    for s in range(eng.slots):
        if s != slot:
            assert row_steps[s] == 0
            np.testing.assert_array_equal(
                np.asarray(ent.centroids[s]), 0.0)
    warm_cents = np.asarray(ent.centroids[slot])
    assert not np.allclose(warm_cents, 0.0)
    # release: the tenant's rows are cold-reset, its next request is cold
    eng.release("A")
    assert eng.slot_tenant[slot] is None
    np.testing.assert_array_equal(
        np.asarray(eng._slot_state.entries["stage0"].centroids[slot]), 0.0)
    eng.submit(reqs[2])
    eng.step()
    ref_cold, _ = _replay_tenant(cfg, params, impl, [reqs[2]])
    np.testing.assert_allclose(reqs[2].logits, ref_cold[0],
                               rtol=1e-5, atol=1e-5)


def test_bucketed_compile_count_real_jit():
    """Real compiled programs: a trace touching every bucket compiles
    exactly |buckets| programs, and the on_compile hook sees each."""
    cfg, params = _tiny_vig("blocked")
    seen = []
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         buckets=(1, 2), on_compile=seen.append)
    rng = np.random.default_rng(17)
    for wave in ([0], [1, 2], [3], [4, 5], [6]):
        for t in wave:
            eng.submit(VigRequest(uid=t, image=_image(rng), tenant=t))
        eng.step()
    assert eng.compile_count == 2
    assert sorted(seen) == [1, 2]
    assert all(r in (1, 2) for r in eng.stats()["bucket_ticks"])


def test_bucketed_requires_jit_mode_and_valid_buckets():
    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, autotune=False, mode="eager")
    eng.submit(VigRequest(uid=0, image=np.zeros((16, 16, 3), np.float32)))
    with pytest.raises(RuntimeError, match="jit"):
        eng.step()
    with pytest.raises(ValueError, match="buckets"):
        VigServeEngine(cfg, params, autotune=False, buckets=(0, 2))
    with pytest.raises(ValueError, match="active"):
        VigServeEngine(cfg, params, autotune=False,
                       buckets=(1, 2)).bucket_for(3)


def test_mesh_mode_rejects_invalid_configurations():
    """Sharded-mode validation: non-distributed impls have no mesh
    knobs; a sharded batch axis needs a bucket set (the exact-size
    policy serves counts that cannot all divide the axis — refusing at
    init beats crashing mid-tick after admission mutated slot state)."""
    cfg, params = _tiny_vig("ring")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="mesh-native"):
        VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                       mesh=mesh)
    with pytest.raises(ValueError, match="bucket set"):
        VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                       mesh=mesh, mesh_batch_axis="data", buckets=None)


def test_anonymous_requests_free_their_slot():
    """tenant=None requests are one-shot: their slot is freed the tick
    they complete, so a stream of anonymous requests can never pin
    slots and LRU-evict live warm tenants."""
    eng = _stub_engine((1, 2))
    eng.submit(VigRequest(uid=0, image=np.zeros((16, 16, 3), np.float32),
                          tenant="A"))
    eng.step()
    for uid in range(1, 5):  # anonymous churn on the other slot
        eng.submit(VigRequest(uid=uid,
                              image=np.zeros((16, 16, 3), np.float32)))
        eng.step()
        assert eng.last_resets  # each one-shot admitted cold
    # A's binding (and warm row) survived four anonymous one-shots
    assert "A" in eng.slot_tenant
    assert eng.slot_tenant.count(None) == eng.slots - 1
    a_slot = eng._tenant_slot["A"]
    assert eng.slot_row_steps()["stage0"][a_slot] == 1


def test_admission_reserves_active_tenants_before_evicting():
    """Queue order must not decide whose warm state survives: with
    warm tenants A/B on a full 2-slot engine and one tick's queue
    [C, A], A (active this tick) keeps its slot and warm row; C may
    only evict the idle tenant B."""
    eng = _stub_engine((1, 2))
    img = np.zeros((16, 16, 3), np.float32)
    for uid, t in ((0, "A"), (1, "B")):
        eng.submit(VigRequest(uid=uid, image=img, tenant=t))
    eng.step()
    a_slot = eng._tenant_slot["A"]
    # C arrives ahead of A in the same tick
    eng.submit(VigRequest(uid=2, image=img, tenant="C"))
    eng.submit(VigRequest(uid=3, image=img, tenant="A"))
    assert eng.step() == 2
    assert eng._tenant_slot["A"] == a_slot  # A kept its slot...
    assert eng.slot_row_steps()["stage0"][a_slot] == 2  # ...and warmth
    assert "B" not in eng._tenant_slot  # the idle tenant was evicted
    assert eng._tenant_slot["C"] not in (None, a_slot)


def test_warmup_schedule_never_leaks_into_other_buckets(tmp_path):
    """A warmup()-tuned schedule is a measurement at self.batch; the
    request path must tune per bucket instead of baking the B=batch
    tile into every bucket's program (only a user-provided VigSchedule
    applies everywhere)."""
    from repro.core.tuner import VigSchedule
    from repro.core.builder import DigcSpec

    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, batch=4, buckets=(1, 2),
                         tuner_path=tmp_path / "tune.json")
    eng.warmup()
    assert eng.schedule is not None and not eng._user_schedule
    choice = eng._bucket_choice(1)
    assert choice is not eng.schedule  # tuned at b=1, not reused from b=4
    assert 1 in eng._bucket_schedules
    # a user-provided schedule does apply to every bucket
    sched = VigSchedule(stages=(
        DigcSpec(impl="blocked", k=3, block_m=16, merge="topk"),
    ))
    eng2 = VigServeEngine(cfg, params, digc_impl=sched, buckets=(1, 2))
    assert eng2._bucket_choice(1) is sched
    assert eng2._bucket_choice(2) is sched


def test_fixed_policy_is_one_program_per_batch_size():
    """buckets=None: the PR-3 baseline — exact-size ticks, one program
    per distinct batch size (the bench's comparison anchor)."""
    cfg, params = _tiny_vig("blocked")
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         buckets=None, batch=4)
    rng = np.random.default_rng(19)
    uid = 0
    for wave_size in (1, 3, 2, 3, 1):
        for _ in range(wave_size):
            eng.submit(VigRequest(uid=uid, image=_image(rng), tenant=uid))
            uid += 1
        eng.step()
        assert eng.last_bucket == wave_size  # no padding
    assert eng.compile_count == 3  # sizes {1, 2, 3}


# ---------------------------------------------------------------------------
# Property tests: scheduler/state-lifecycle invariants under arbitrary
# arrival sequences. Programs are stubbed (no compiles), so hypothesis
# can drive hundreds of ticks; the stub bumps every state entry exactly
# like a depth-1 forward would.


class _StubProgramEngine(VigServeEngine):
    def _build_program(self, bucket):
        def fake_fwd(params, imgs, state):
            b = imgs.shape[0]
            new = DigcState(entries={
                k: e.bump() for k, e in state.entries.items()
            })
            return jnp.zeros((b, self.cfg.num_classes), jnp.float32), new

        return fake_fwd


def _stub_engine(buckets, on_compile=None, park=8):
    cfg, params = _tiny_vig("cluster")
    return _StubProgramEngine(cfg, params, digc_impl="cluster",
                              autotune=False, buckets=buckets,
                              on_compile=on_compile, park_capacity=park)


@settings(max_examples=60)
@given(active=st.integers(1, 8),
       buckets=st.sampled_from([(1, 2, 4, 8), (2, 8), (8,), (1, 3, 5, 8)]))
def test_property_bucket_is_smallest_that_fits(active, buckets):
    eng = _stub_engine(buckets)
    b = eng.bucket_for(active)
    assert b in buckets and b >= active
    assert all(c < active for c in buckets if c < b)  # none smaller fits


@settings(max_examples=25)
@given(arrivals=st.lists(st.integers(0, 5), min_size=1, max_size=14))
def test_property_padding_never_mutates_live_rows(arrivals):
    """Arbitrary arrival sequences (tenant ids 0-5 on 4 slots, so both
    padding, eviction and park/restore occur): after every tick, rows
    of slots that neither served nor were reset/restored this tick are
    bit-identical, the served slots' counters advanced exactly once
    (from 0 on a cold reset, from the parked value on a restore), and
    the bucket was the smallest that fits."""
    eng = _stub_engine((1, 2, 4))
    for i, t in enumerate(arrivals):
        eng.submit(VigRequest(
            uid=i, image=np.zeros((16, 16, 3), np.float32), tenant=t))
    served_total = 0
    while eng.queue:
        state = eng._ensure_slot_state()
        before = {
            k: jax.tree_util.tree_map(np.asarray, e)
            for k, e in state.entries.items()
        }
        parked_before = {
            t: {k: int(e.row_step[0]) for k, e in st.entries.items()}
            for t, st in eng._parked.items()
        }
        served = eng.step()
        served_total += served
        assert served == len(eng.last_lanes) >= 1
        assert eng.last_bucket == eng.bucket_for(served)
        touched = (set(eng.last_lanes) | set(eng.last_resets)
                   | set(eng.last_restores))
        after = eng._slot_state
        for key, ent in after.entries.items():
            for s in range(eng.slots):
                old_step = before[key].row_step[s]
                new_step = int(ent.row_step[s])
                if s not in touched:
                    # padding lanes replicate live rows but are dropped
                    # on scatter: untouched slots are bit-identical
                    assert new_step == old_step
                    np.testing.assert_array_equal(
                        np.asarray(ent.centroids[s]),
                        before[key].centroids[s])
                elif s in eng.last_lanes:
                    if s in eng.last_resets:
                        base = 0  # cold admit
                    elif s in eng.last_restores:
                        # warm re-admit: continue from the parked copy
                        base = parked_before[eng.slot_tenant[s]][key]
                    else:
                        base = old_step
                    assert new_step == base + 1
    assert served_total == len(arrivals)


@settings(max_examples=25)
@given(arrivals=st.lists(st.integers(0, 9), min_size=1, max_size=20),
       buckets=st.sampled_from([(1, 2, 4), (4,), (1, 4), (2, 3, 4)]))
def test_property_program_count_bounded_by_bucket_set(arrivals, buckets):
    compiled = []
    eng = _stub_engine(buckets, on_compile=compiled.append)
    for i, t in enumerate(arrivals):
        eng.submit(VigRequest(
            uid=i, image=np.zeros((16, 16, 3), np.float32), tenant=t))
    eng.run()
    assert eng.compile_count <= len(buckets)
    assert eng.compile_count == len(set(compiled))  # hook saw each once
    assert set(compiled) <= set(buckets)
    assert set(eng.bucket_ticks) == set(compiled)


# ---------------------------------------------------------------------------
# LM ServeEngine: per-slot commit mask across mixed-length slots


def _lm_setup():
    from repro.configs import get_smoke
    from repro.launch.api import get_api

    cfg = get_smoke("olmo-1b").replace(dtype="float32")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    return cfg, params


def test_serve_engine_mixed_length_slots_match_solo():
    """Regression (PR-4): without the per-slot commit mask a slot
    prefilling clobbered its neighbors' cache rows — mixed-length
    batches silently decoded garbage. Now with per-slot position
    vectors (one decode call per tick) each request must still match a
    solo (slots=1) run exactly."""
    from repro.serve.engine import Request, ServeEngine

    cfg, params = _lm_setup()
    prompts = {0: np.asarray([5, 9, 2], np.int32),
               1: np.asarray([7, 1, 4, 3, 8], np.int32)}
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    for uid, p in prompts.items():
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
    got = {r.uid: r.out_tokens for r in eng.run()}
    for uid, p in prompts.items():
        solo = ServeEngine(cfg, params, slots=1, max_len=32)
        solo.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        assert got[uid] == solo.run()[0].out_tokens, uid


def test_serve_engine_rejects_empty_prompt():
    from repro.serve.engine import Request, ServeEngine

    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.asarray([], np.int32)))


def test_serve_engine_respects_one_token_budget():
    """max_new_tokens=1 is satisfied by the prefill token itself: no
    extra decode step, exactly one output token."""
    from repro.serve.engine import Request, ServeEngine

    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    eng.submit(Request(uid=0, prompt=np.asarray([5, 9], np.int32),
                       max_new_tokens=1))
    out = eng.run()
    assert len(out) == 1 and len(out[0].out_tokens) == 1
    assert eng.decode_calls == 2  # prefill only, no decode tick


def test_user_schedule_sizes_slot_state():
    """_ensure_slot_state must allocate from the same impl choice the
    bucket programs run: a user VigSchedule with a cluster stage spec
    gets matching per-slot centroid buffers (warm starts engage)."""
    from repro.core.builder import DigcSpec
    from repro.core.strategies import default_cluster_params
    from repro.core.tuner import VigSchedule

    cfg, params = _tiny_vig("cluster")
    sched = VigSchedule(stages=(
        DigcSpec(impl="cluster", k=3, n_clusters=3, n_probe=3,
                 capacity_factor=8.0),
    ))
    eng = VigServeEngine(cfg, params, digc_impl=sched, autotune=False,
                         buckets=(1, 2))
    ent = eng._ensure_slot_state().entries["stage0"]
    nc, _ = default_cluster_params(16, 3, 3)
    assert ent.centroids.shape == (2, nc, 16)
    # and the warm start actually engages through the program
    rng = np.random.default_rng(29)
    for uid in range(2):
        eng.submit(VigRequest(uid=uid, image=_image(rng), tenant="A"))
        eng.step()
    slot = eng._tenant_slot["A"]
    assert eng.slot_row_steps()["stage0"][slot] == 2 * sum(cfg.depths)
    assert not np.allclose(
        np.asarray(eng._slot_state.entries["stage0"].centroids[slot]), 0.0)


def test_serve_engine_one_decode_call_per_tick_pinned():
    """Pin the collapsed scheduling (ROADMAP PR-4 follow-up, landed):
    ``decode_step`` takes the per-slot position *vector*, so a tick
    over slots at distinct positions is ONE jitted call — the
    per-position-group loop (one call per distinct length) is gone,
    and the per-slot commit masks still protect inactive slots."""
    from repro.serve.engine import Request, ServeEngine

    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    # same length: 1 decode call per tick
    eng.submit(Request(uid=0, prompt=np.asarray([5, 9], np.int32),
                       max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=np.asarray([7, 1], np.int32),
                       max_new_tokens=3))
    eng.step()  # prefill (2 tokens per slot) + first batched decode
    before = eng.decode_calls
    eng.step()
    assert eng.decode_calls == before + 1  # one call
    # mixed length: STILL one decode call per tick (the collapse)
    eng2 = ServeEngine(cfg, params, slots=2, max_len=32)
    eng2.submit(Request(uid=0, prompt=np.asarray([5], np.int32),
                        max_new_tokens=4))
    eng2.submit(Request(uid=1, prompt=np.asarray([7, 1, 4], np.int32),
                        max_new_tokens=4))
    eng2.step()
    before = eng2.decode_calls
    eng2.step()
    assert eng2.decode_calls == before + 1  # mixed lengths, one call
