"""SLO-bounded admission scheduling (DESIGN.md §14).

Property tests for the async admission queue, all under a
``VirtualClock`` so every deadline comparison is exact and every run
is deterministic:

* **Deadline bound**: no request dispatches later than its class SLO
  after arrival (replay wakes on deadlines, not just arrivals).
* **Per-tenant FIFO**: a tenant's requests complete in submission
  order even when tight-SLO requests pull other cells forward.
* **Deterministic bucket sets**: the same trace always tunes to the
  same bucket set, and ``buckets="auto"`` round-trips it through the
  host tuner cache.
* **Exact padding accounting**: ``padded_lanes`` equals the per-tick
  sum of (width - live), and ``util`` is derived from it.
* **Legacy parity**: ``slo_ms=0`` keeps the bind-on-next-tick engine
  byte-for-byte — identical logits, bucket ticks and compile counts —
  while still exposing the new queue/util counters.
* **Prefetch transparency**: prefetched parking restores change
  counters only, never logits (bitwise).

Scheduler-order tests run on stubbed programs (no compiles, the
``test_serve_multitenant`` idiom); the parity and prefetch tests use
real compiled programs on the exact cluster tier.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.state import DigcState
from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigRequest, VigServeEngine
from repro.serve.sched import Arrival, VirtualClock, arrival_trace, replay


def _tiny_vig(impl):
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3, digc_impl=impl,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _image(rng):
    return rng.standard_normal((16, 16, 3)).astype(np.float32)


_ZERO = np.zeros((16, 16, 3), np.float32)


class _StubProgramEngine(VigServeEngine):
    def _build_program(self, bucket):
        def fake_fwd(params, imgs, state):
            b = imgs.shape[0]
            new = DigcState(entries={
                k: e.bump() for k, e in state.entries.items()
            })
            return jnp.zeros((b, self.cfg.num_classes), jnp.float32), new

        return fake_fwd


def _stub_engine(**kw):
    cfg, params = _tiny_vig("cluster")
    kw.setdefault("autotune", False)
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("batch", 4)
    return _StubProgramEngine(cfg, params, digc_impl="cluster", **kw)


def _drain(eng, clock, arrivals, *, on_done=None):
    """Replay ``arrivals`` through a stub engine, stamping each
    request's dispatch time. Mirrors ``serve.sched.replay`` (deadline
    wakeups between arrivals) but returns the request objects."""
    reqs = []
    done = set()

    def _tick():
        served = eng.step()
        if served:
            for r in reqs:
                if r.done and r.uid not in done:
                    done.add(r.uid)
                    r._done_t = clock.now()
                    if on_done is not None:
                        on_done(r)
        return served

    for uid, arr in enumerate(arrivals):
        t_arr = arr.t_ms / 1e3
        while eng.queue:
            dl = eng.next_deadline()
            if dl is None or dl >= t_arr:
                break
            clock.advance_to(dl)
            _tick()
        clock.advance_to(t_arr)
        req = VigRequest(uid=uid, image=_ZERO, tenant=arr.tenant,
                         tclass=arr.tclass)
        reqs.append(req)
        eng.submit(req)
        _tick()
    guard = 0
    while eng.queue:
        if _tick() == 0:
            dl = eng.next_deadline()
            assert dl is not None, "deferred with no deadline"
            clock.advance_to(dl)
            guard += 1
            assert guard < 10_000, "drain stalled"
    return reqs


# ---------------------------------------------------------------------------
# VirtualClock / arrival_trace


def test_virtual_clock_monotonic():
    clk = VirtualClock()
    assert clk.now() == 0.0 and clk() == 0.0
    assert clk.advance(0.25) == 0.25
    # advance_to into the past is a no-op, never a rewind
    assert clk.advance_to(0.1) == 0.25
    assert clk.advance_to(1.5) == 1.5
    with pytest.raises(ValueError):
        clk.advance(-1.0)
    assert VirtualClock(start=3.0).now() == 3.0


def test_arrival_trace_deterministic_and_sorted():
    a = arrival_trace(seed=7, tenants=4, poisson_n=20, burst_n=2,
                      burst_size=3, classes=("gold", "default"))
    b = arrival_trace(seed=7, tenants=4, poisson_n=20, burst_n=2,
                      burst_size=3, classes=("gold", "default"))
    assert a == b
    assert len(a) == 20 + 2 * 3
    assert all(x.t_ms <= y.t_ms for x, y in zip(a, a[1:]))
    assert {x.tclass for x in a} == {"gold", "default"}
    assert {x.tenant for x in a} <= {f"t{i}" for i in range(4)}
    c = arrival_trace(seed=8, tenants=4, poisson_n=20, burst_n=2,
                      burst_size=3, classes=("gold", "default"))
    assert [x.t_ms for x in c] != [x.t_ms for x in a]


# ---------------------------------------------------------------------------
# Deadline bound


def _assert_deadline_bound(reqs, eng):
    for r in reqs:
        assert r.done
        assert r._done_t <= r._enq_t + eng._slo_s(r) + 1e-9, (
            f"uid {r.uid} dispatched {r._done_t:.6f}, deadline "
            f"{r._enq_t + eng._slo_s(r):.6f}")


def test_deadline_bound_on_bursty_trace():
    """Every request on the canonical Poisson+burst trace dispatches at
    or before arrival + its SLO — deferrals coalesce, never starve."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=50.0, clock=clock)
    arrivals = arrival_trace(seed=3, tenants=6, poisson_n=40,
                             poisson_ms=30.0, burst_n=3, burst_size=4)
    reqs = _drain(eng, clock, arrivals)
    _assert_deadline_bound(reqs, eng)
    assert eng.deferrals > 0  # the trickle actually waited
    assert eng.stats()["queue_depth"] == 0


def test_deadline_bound_per_class_slo():
    """Dict slo: a gold request's tighter budget binds it, and a gold
    request queued behind a lax one pulls the tenant head forward
    (effective-deadline attribution) so FIFO never starves gold."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms={"gold": 10.0, "default": 200.0},
                       clock=clock)
    arrivals = [
        Arrival(t_ms=0.0, tenant="a", tclass="default"),
        Arrival(t_ms=1.0, tenant="a", tclass="gold"),
        Arrival(t_ms=2.0, tenant="b", tclass="default"),
    ]
    reqs = _drain(eng, clock, arrivals)
    _assert_deadline_bound(reqs, eng)
    # the lax head itself must clear in time for the gold behind it
    assert reqs[0]._done_t <= (1.0 + 10.0) / 1e3 + 1e-9
    # unknown class falls back to "default"
    assert eng._slo_s(VigRequest(uid=9, image=_ZERO, tenant="x",
                                 tclass="nope")) == pytest.approx(0.2)


@settings(max_examples=25)
@given(gaps=st.lists(st.integers(0, 120), min_size=1, max_size=24),
       slo=st.integers(1, 200))
def test_property_deadline_bound(gaps, slo):
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=float(slo), clock=clock)
    t, arrivals = 0.0, []
    for i, g in enumerate(gaps):
        t += g
        arrivals.append(Arrival(t_ms=t, tenant=f"t{i % 5}"))
    reqs = _drain(eng, clock, arrivals)
    _assert_deadline_bound(reqs, eng)


# ---------------------------------------------------------------------------
# Per-tenant FIFO / dispatch policy


def test_per_tenant_fifo_across_deferrals():
    """A tenant's requests complete in submission order even when the
    scheduler reorders *cells*; only head requests are ever eligible."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=40.0, clock=clock)
    arrivals = arrival_trace(seed=11, tenants=3, poisson_n=30,
                             poisson_ms=15.0, burst_n=2, burst_size=5)
    order = []
    _drain(eng, clock, arrivals, on_done=lambda r: order.append(r))
    per_tenant = {}
    for r in order:
        per_tenant.setdefault(r.tenant, []).append(r.uid)
    for t, uids in per_tenant.items():
        assert uids == sorted(uids), f"tenant {t} served out of order"


@settings(max_examples=20)
@given(tenants=st.lists(st.integers(0, 3), min_size=2, max_size=20))
def test_property_per_tenant_fifo(tenants):
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=25.0, clock=clock)
    arrivals = [Arrival(t_ms=5.0 * i, tenant=f"t{t}")
                for i, t in enumerate(tenants)]
    order = []
    _drain(eng, clock, arrivals, on_done=lambda r: order.append(r))
    per_tenant = {}
    for r in order:
        per_tenant.setdefault(r.tenant, []).append(r.uid)
    for uids in per_tenant.values():
        assert uids == sorted(uids)


def test_full_width_dispatches_without_waiting():
    """A full slot width of distinct tenants is ripe immediately — the
    scheduler never sits on a full tick just because deadlines are far."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=10_000.0, clock=clock)
    for i in range(eng.slots):
        eng.submit(VigRequest(uid=i, image=_ZERO, tenant=f"t{i}"))
    assert eng.step() == eng.slots
    assert eng.deferrals == 0
    assert clock.now() == 0.0  # no time passed


def test_deferral_then_deadline_dispatch():
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=50.0, clock=clock)
    eng.submit(VigRequest(uid=0, image=_ZERO, tenant="a"))
    assert eng.step() == 0  # lone sub-width arrival waits
    assert eng.deferrals == 1
    assert eng._next_deadline == pytest.approx(0.05)
    assert eng.next_deadline() == pytest.approx(0.05)
    clock.advance_to(0.049)
    assert eng.step() == 0  # still early
    clock.advance_to(0.05)
    assert eng.step() == 1
    assert eng.stats()["queue_depth"] == 0


def test_run_drains_under_virtual_clock():
    """run() itself advances a VirtualClock to deadlines — a deferred
    drain terminates without any external ticking."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=30.0, clock=clock)
    reqs = [VigRequest(uid=i, image=_ZERO, tenant=f"t{i}")
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert [r.uid for r in done] == [0, 1]
    assert clock.now() >= 0.03


# ---------------------------------------------------------------------------
# Padding accounting / bucket-set determinism


def test_padding_accounting_sums_exactly():
    """padded_lanes == sum over dispatched ticks of (width - live),
    reconstructed independently from the replay's tick log."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=60.0, clock=clock)
    arrivals = arrival_trace(seed=5, tenants=5, poisson_n=32,
                             poisson_ms=25.0, burst_n=2, burst_size=4)
    ticks = replay(eng, arrivals, _ZERO, clock=clock)
    assert sum(served for served, _, _ in ticks) == len(arrivals)
    assert eng.live_lanes == sum(live for _, live, _ in ticks)
    assert eng.padded_lanes == sum(w - live for _, live, w in ticks)
    s = eng.stats()
    assert s["util"] == pytest.approx(
        eng.live_lanes / (eng.live_lanes + eng.padded_lanes))
    assert sum(s["lane_hist"].values()) == len(ticks)
    # the histogram's live counts re-sum to the lane totals
    assert sum(int(k.split("x")[1]) * n
               for k, n in s["lane_hist"].items()) == eng.live_lanes


def test_bucket_sets_deterministic_for_fixed_trace(tmp_path):
    """The same replayed trace always tunes to the same bucket set,
    and buckets="auto" round-trips it through the host tuner cache."""
    sets = []
    for _ in range(2):
        clock = VirtualClock()
        eng = _stub_engine(slo_ms=60.0, clock=clock)
        arrivals = arrival_trace(seed=9, tenants=6, poisson_n=40,
                                 burst_n=3, burst_size=4)
        replay(eng, arrivals, _ZERO, clock=clock)
        sets.append(eng.retune_buckets())
    assert sets[0] == sets[1]
    assert eng.buckets == sets[1]  # retune takes effect live
    assert len(sets[0]) <= eng.bucket_cap and max(sets[0]) == eng.slots
    # persist through the tuner cache, then construct on "auto"
    path = tmp_path / "tune.json"
    clock = VirtualClock()
    tuned = _stub_engine(slo_ms=60.0, clock=clock, tuner_path=path)
    arrivals = arrival_trace(seed=9, tenants=6, poisson_n=40,
                             burst_n=3, burst_size=4)
    replay(tuned, arrivals, _ZERO, clock=clock)
    persisted = tuned.retune_buckets()
    assert persisted == sets[0]
    auto = _stub_engine(buckets="auto", tuner_path=path)
    assert auto.buckets == persisted


def test_auto_buckets_fallback_without_cache(tmp_path):
    # no tuner path: the default ladder capped at slots
    assert _stub_engine(buckets="auto").buckets == (1, 2, 4)
    assert _stub_engine(buckets="auto", batch=8).buckets == (1, 2, 4, 8)
    # a tuner path with no matching entry falls back the same way
    eng = _stub_engine(buckets="auto", tuner_path=tmp_path / "t.json")
    assert eng.buckets == (1, 2, 4)
    with pytest.raises(ValueError):
        _stub_engine(buckets="nonsense")


@settings(max_examples=15)
@given(seed=st.integers(0, 50))
def test_property_bucket_set_seed_stability(seed):
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=45.0, clock=clock)
    replay(eng, arrival_trace(seed=seed, tenants=5, poisson_n=24),
           _ZERO, clock=clock)
    first = eng.retune_buckets()
    assert first == eng.retune_buckets()  # idempotent on the same hist
    assert max(first) == eng.slots


# ---------------------------------------------------------------------------
# slo_ms=0 legacy parity (byte-for-byte) + counters on the legacy path


def test_slo_zero_is_bitwise_legacy():
    """slo_ms=0 + a clock + prefetch must serve a ragged trace
    bit-identically to the default-constructed engine: same logits,
    same bucket ticks, same compile count — the scheduler machinery
    is provably inert until armed."""
    impl = "cluster"
    cfg, params = _tiny_vig(impl)
    waves = [["A"], ["B", "C"], ["A", "B"], ["C"], ["A", "B", "C"]]

    def _serve(**kw):
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=(1, 2, 4), **kw)
        rng = np.random.default_rng(41)
        out, uid = [], 0
        for wave in waves:
            reqs = [VigRequest(uid=uid + i, image=_image(rng), tenant=t)
                    for i, t in enumerate(wave)]
            uid += len(wave)
            for r in reqs:
                eng.submit(r)
            assert eng.step() == len(wave)
            out.extend(reqs)
        return eng, out

    base_eng, base = _serve()
    sched_eng, sched = _serve(slo_ms=0.0, clock=VirtualClock(),
                              prefetch=True)
    assert sched_eng._sched_active is False
    for b, s in zip(base, sched):
        assert np.asarray(b.logits).tobytes() == np.asarray(s.logits).tobytes()
    assert base_eng.stats()["bucket_ticks"] == sched_eng.stats()["bucket_ticks"]
    assert base_eng.compile_count == sched_eng.compile_count
    assert sched_eng.deferrals == 0 and sched_eng.prefetch_issued == 0


def test_legacy_path_reports_queue_and_util():
    """The new stats counters are live even with the scheduler off."""
    eng = _stub_engine()  # slo_ms=0 default
    for i in range(3):
        eng.submit(VigRequest(uid=i, image=_ZERO, tenant=f"t{i}"))
    assert eng.stats()["queue_depth"] == 3
    eng.step()  # 3 live on bucket 4 -> 1 padded lane
    s = eng.stats()
    assert s["queue_depth"] == 0
    assert s["live_lanes"] == 3 and s["padded_lanes"] == 1
    assert s["util"] == pytest.approx(0.75)
    assert s["lane_hist"] == {"16x3": 1}
    assert s["deferrals"] == 0 and s["slo_ms"] == 0.0


# ---------------------------------------------------------------------------
# Prefetched parking restore


def test_prefetch_counters_and_bitwise_parity():
    """Evict+park a tenant, resubmit it: the prefetcher issues the
    upload at submit time, the restoring tick consumes it, and the
    logits are bitwise identical to a prefetch=False engine serving
    the same trace — prefetch is a placement hint, never a semantic."""
    impl = "cluster"
    cfg, params = _tiny_vig(impl)

    def _serve(prefetch):
        eng = VigServeEngine(cfg, params, digc_impl=impl, autotune=False,
                             buckets=(1, 2), park_capacity=4,
                             prefetch=prefetch)
        rng = np.random.default_rng(17)
        out = []
        # waves of distinct tenants overflow the 2 slots -> A parks
        for uid, wave in enumerate([["A"], ["B", "C"], ["D", "E"], ["A"]]):
            reqs = [VigRequest(uid=(uid, i), image=_image(rng), tenant=t)
                    for i, t in enumerate(wave)]
            for r in reqs:
                eng.submit(r)
            assert eng.step() == len(wave)
            out.extend(reqs)
        return eng, out

    pre_eng, pre = _serve(True)
    base_eng, base = _serve(False)
    assert pre_eng.prefetch_issued >= 1 and pre_eng.prefetch_hits >= 1
    assert pre_eng.park_hits >= 1
    assert base_eng.prefetch_issued == 0 and base_eng.prefetch_hits == 0
    for p, b in zip(pre, base):
        assert np.asarray(p.logits).tobytes() == np.asarray(b.logits).tobytes()


def test_prefetch_scheduler_path_counts():
    """Under the scheduler the peek-select predicts the admitting cell;
    a parked tenant among the predicted admits is prefetched before the
    tick that restores it."""
    clock = VirtualClock()
    eng = _stub_engine(slo_ms=20.0, clock=clock, buckets=(1, 2),
                       batch=2, park_capacity=4)
    arrivals = [Arrival(t_ms=0.0, tenant="A"),
                Arrival(t_ms=30.0, tenant="B"),
                Arrival(t_ms=31.0, tenant="C"),
                Arrival(t_ms=60.0, tenant="A")]
    reqs = _drain(eng, clock, arrivals)
    assert all(r.done for r in reqs)
    assert eng.prefetch_issued >= 1
    assert eng.prefetch_hits >= 1
