"""Mesh-native multi-tenant serving (DESIGN.md §10).

The acceptance contract for sharded serving: on a 4-forced-host-device
mesh, a bucketed ragged multi-tenant trace through ``VigServeEngine``
(ring-sharded co-node construction, per-slot ``DigcState`` rows placed
with ``PartitionSpec``s) is **bit-identical** (CPU) to the per-tenant
B=1 replay of each tenant's own history, while compiling at most
|bucket set| programs (asserted through the ``compile_count`` /
``on_compile`` hook) — and the construction indices match the
single-device blocked tier bitwise.

Runs in a subprocess so the forced-device-count flag never leaks into
the main test process; tiny shapes keep it inside the tier-1 budget.
"""

from _subproc import run_snippet


def _run(snippet: str, *, devices: int = 4, timeout: int = 600) -> str:
    return run_snippet(snippet, devices=devices, timeout=timeout).stdout


def test_mesh_native_engine_bucketed_trace_matches_b1_replay():
    """Ragged trace, 3 tenants, buckets {1,2} on a 4-device ring:
    every request bit-matches its tenant's B=1 replay, <= 2 programs
    compile, the slot state lives on the mesh, and the construction is
    bitwise the single-device blocked result."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec, digc
        from repro.models import vig
        from repro.models.module import init_params
        from repro.serve.engine import VigRequest, VigServeEngine

        assert jax.device_count() == 4
        mesh = jax.make_mesh((4,), ("ring",))
        cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
            image_size=16, patch=4, embed_dims=(16,), depths=(2,),
            num_classes=3, k=3, digc_impl="ring")
        params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        img = lambda: rng.standard_normal((16, 16, 3)).astype(np.float32)

        compiled = []
        eng = VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                             buckets=(1, 2), mesh=mesh, mesh_axis="ring",
                             on_compile=compiled.append)
        waves = [["A"], ["B", "C"], ["A", "B"], ["C"], ["B", "A"]]
        per_t = {}
        uid = 0
        for w in waves:
            for t in w:
                r = VigRequest(uid=uid, image=img(), tenant=t)
                uid += 1
                per_t.setdefault(t, []).append(r)
                eng.submit(r)
            assert eng.step() == len(w)
            assert eng.last_bucket == eng.bucket_for(len(w))
        # <= |bucket set| compiled programs on the whole ragged trace
        assert eng.compile_count <= 2, eng.compile_count
        assert sorted(set(compiled)) == sorted(eng._programs)
        # the canonical slot state lives on the mesh
        ent = eng._slot_state.entries["stage0"]
        assert ent.row_step.sharding.mesh.shape == {"ring": 4}

        # per-tenant B=1 replay (same mesh-native spec): bit-identical
        spec = DigcSpec(impl="ring", mesh=mesh, axis_name="ring")
        def replay(reqs):
            state = vig.init_vig_state(cfg, 1, spec, per_slot=True,
                                       mesh=mesh, mesh_axis="ring")
            fwd = jax.jit(lambda p, im, s: vig.vig_forward(
                p, im, cfg, digc_impl=spec, state=s))
            outs = []
            for r in reqs:
                lg, state = fwd(params, jnp.asarray(r.image)[None], state)
                outs.append(np.asarray(lg)[0])
            return outs
        for t, reqs in per_t.items():
            for r, ref in zip(reqs, replay(reqs)):
                assert r.done
                assert np.array_equal(r.logits, ref), t
        # single-device exact-tier cross-check (fp-tolerant: a jitted
        # B>1 batch reassociates matmul sums vs the B=1 program)
        base = jax.jit(lambda p, im: vig.vig_forward(p, im, cfg,
                                                     digc_impl="blocked"))
        for t, reqs in per_t.items():
            for r in reqs:
                ref = np.asarray(base(params, jnp.asarray(r.image)[None]))[0]
                np.testing.assert_allclose(r.logits, ref, rtol=1e-5,
                                           atol=1e-5)
        # and the construction itself is bitwise the blocked result
        x = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)
        assert bool(jnp.all(
            digc(x, k=3, impl="blocked")
            == digc(x, spec=DigcSpec(impl="ring", k=3, mesh=mesh,
                                     axis_name="ring"))))
        print("SHARDED_ENGINE_OK")
        """
    )
    assert "SHARDED_ENGINE_OK" in out


def test_mesh_native_engine_parking_survives_slot_churn():
    """LRU state parking on the sharded path: a tenant evicted from a
    2-slot mesh-native engine re-admits WARM (bit-matches its full-
    history B=1 replay) because its sharded state rows round-tripped
    through the host-side parking tier; with park_capacity=0 the same
    churn re-admits cold."""
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DigcSpec
        from repro.models import vig
        from repro.models.module import init_params
        from repro.serve.engine import VigRequest, VigServeEngine

        mesh = jax.make_mesh((4,), ("ring",))
        cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
            image_size=16, patch=4, embed_dims=(16,), depths=(2,),
            num_classes=3, k=3, digc_impl="ring")
        params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(13)
        mk = lambda t: VigRequest(uid=int(rng.integers(1 << 30)),
                                  image=rng.standard_normal(
                                      (16, 16, 3)).astype(np.float32),
                                  tenant=t)
        spec = DigcSpec(impl="ring", mesh=mesh, axis_name="ring")
        def replay(reqs):
            state = vig.init_vig_state(cfg, 1, spec, per_slot=True,
                                       mesh=mesh, mesh_axis="ring")
            fwd = jax.jit(lambda p, im, s: vig.vig_forward(
                p, im, cfg, digc_impl=spec, state=s))
            outs = []
            for r in reqs:
                lg, state = fwd(params, jnp.asarray(r.image)[None], state)
                outs.append(np.asarray(lg)[0])
            return outs

        eng = VigServeEngine(cfg, params, digc_impl="ring", autotune=False,
                             buckets=(1, 2), mesh=mesh, mesh_axis="ring")
        a1, b1 = mk("A"), mk("B")
        eng.submit(a1), eng.submit(b1); eng.step()
        c1 = mk("C"); eng.submit(c1); eng.step()   # evicts + parks LRU
        evicted = "A" if "A" not in eng.slot_tenant else "B"
        assert evicted in eng._parked
        e2 = mk(evicted); eng.submit(e2); eng.step()  # restores warm
        assert eng.park_hits == 1 and eng.last_restores
        hist = {"A": [a1], "B": [b1]}[evicted] + [e2]
        refs = replay(hist)
        assert np.array_equal(e2.logits, refs[-1])
        # row counters continued from the parked copy (2 blocks/request)
        slot = eng._tenant_slot[evicted]
        assert eng.slot_row_steps()["stage0"][slot] == 2 * sum(cfg.depths)
        print("SHARDED_PARKING_OK")
        """
    )
    assert "SHARDED_PARKING_OK" in out
