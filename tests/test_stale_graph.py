"""Stale-graph serving: drift-gated graph reuse (DESIGN.md §12).

The reuse gate serves a *cached* k-NN graph instead of rebuilding when
per-row feature drift is small, staleness is bounded, and the cache
geometry matches. These tests pin the contract from four sides:

* **Identity**: ``drift_tau=0`` is bit-identical to ``reuse`` off on
  every stateful tier — the gate's strict ``<`` plus the static
  short-circuit mean a zero gate can never fire.
* **Engagement proofs** (stale-norms style): a warm entry seeded with a
  deliberately *corrupted* cached graph must change the result when the
  gate reuses (the rebuild path would recompute and hide it), and must
  NOT change it when drift or staleness forces a rebuild.
* **Per-row independence**: co-batched rows gate independently — a
  drifting row rebuilds while its neighbors ride the cache, and every
  row matches its own B=1 solo replay bitwise.
* **Lifecycle**: eviction -> parking -> re-admit carries the cached
  graph (the buffers live in ``_row_fields``); a hypothesis sweep holds
  the ``graph_age <= max_stale`` staleness invariant under arbitrary
  drift sequences.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.builder import DigcSpec, get_builder
from repro.core.digc import digc, drift_stat
from repro.core.state import DigcState, DigcStateEntry, state_entry
from repro.core.tuner import VigSchedule, tune_reuse
from repro.models import vig
from repro.models.module import init_params
from repro.serve.engine import VigRequest, VigServeEngine

STATEFUL_TIERS = ("blocked", "cluster")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _spec(impl, **kw):
    extra = {"n_clusters": 4, "n_probe": 4, "capacity_factor": 8.0} \
        if impl == "cluster" else {}
    return DigcSpec(impl=impl, k=3, **extra, **kw)


def _entry(impl, b, n, d, k=3, rows=None):
    kw = {"graph_shape": (b, n, k)}
    if impl == "cluster":
        kw["centroids_shape"] = (b, 4, d)
    if rows is not None:
        kw["rows"] = rows
    return state_entry(**kw)


def _stream(spec, xs, entry):
    """Jitted stateful digc over a list of inputs; returns per-call
    indices plus the final state."""
    st_ = DigcState.init({"g": entry})
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="g"))
    outs = []
    for x in xs:
        idx, st_ = fn(x, st_)
        outs.append(np.asarray(idx))
    return outs, st_


# ---------------------------------------------------------------------------
# Validation


def test_validate_rejects_bad_reuse_knobs():
    x = jnp.zeros((1, 8, 4))
    with pytest.raises(ValueError):
        digc(x, spec=_spec("blocked", reuse="sometimes"))
    with pytest.raises(ValueError):
        digc(x, spec=_spec("blocked", reuse="layer", drift_tau=-0.1))
    with pytest.raises(ValueError):
        digc(x, spec=_spec("blocked", reuse="layer", max_stale=0))
    # gate knobs without a policy are dead configuration — rejected
    with pytest.raises(ValueError):
        digc(x, spec=_spec("blocked", drift_tau=0.05))
    with pytest.raises(ValueError):
        digc(x, spec=_spec("blocked", reuse="off", max_stale=4))
    # the stateless kernel tier has no cache to serve from
    with pytest.raises(ValueError):
        digc(x, spec=DigcSpec(impl="pallas", k=3, reuse="layer"))


def test_reuse_knobs_dropped_on_degradation():
    from repro.core.builder import degraded_spec

    spec = _spec("cluster", reuse="tick", drift_tau=0.1, max_stale=2)
    deg = degraded_spec(spec, "blocked")
    assert deg.reuse is None and deg.drift_tau is None
    assert deg.max_stale is None


# ---------------------------------------------------------------------------
# Identity: drift_tau=0 == reuse off, bit for bit, per stateful tier


@pytest.mark.parametrize("impl", STATEFUL_TIERS)
def test_tau_zero_bit_identical_to_off(impl):
    rng = np.random.default_rng(0)
    b, n, d = 2, 24, 8
    xs = [_rand(rng, b, n, d)]
    for _ in range(3):
        xs.append(xs[-1] + 0.05 * _rand(rng, b, n, d))

    off, _ = _stream(_spec(impl), xs, _entry(impl, b, n, d))
    for policy in ("layer", "tick"):
        gated, _ = _stream(_spec(impl, reuse=policy, drift_tau=0.0),
                           xs, _entry(impl, b, n, d))
        for a, c in zip(off, gated):
            np.testing.assert_array_equal(a, c)


def test_tau_zero_bit_identical_at_model_level():
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    imgs = [_rand(rng, 1, 16, 16, 3) for _ in range(2)]

    def run(spec):
        state = vig.init_vig_state(cfg, 1, spec)
        outs = []
        for im in imgs:
            logits, state = vig.vig_forward(params, im, cfg,
                                            digc_impl=spec, state=state)
            outs.append(np.asarray(logits))
        return outs

    off = run(_spec("blocked"))
    zero = run(_spec("blocked", reuse="layer", drift_tau=0.0))
    for a, c in zip(off, zero):
        np.testing.assert_array_equal(a, c)


# ---------------------------------------------------------------------------
# Engagement proofs: the gate provably serves / provably rebuilds


def _warm_corrupt_entry(x, exact_idx, k, *, snap, age):
    """A warm entry whose cached graph is a deliberate corruption of
    the exact one (neighbors rolled by one): any output equal to it
    proves the cache was served; equal to exact proves a rebuild."""
    corrupt = jnp.roll(jnp.asarray(exact_idx), 1, axis=-1)
    b = x.shape[0]
    return DigcStateEntry(
        step=jnp.ones((), jnp.int32),
        graph_idx=corrupt.astype(jnp.int32),
        graph_dist=jnp.zeros(corrupt.shape, jnp.float32),
        graph_snap=jnp.asarray(snap, jnp.float32),
        graph_age=jnp.full((b,), age, jnp.int32),
    ), np.asarray(corrupt)


def test_gate_serves_cache_and_rebuilds_on_drift_and_expiry():
    rng = np.random.default_rng(2)
    b, n, d = 2, 24, 8
    x = _rand(rng, b, n, d)
    spec = _spec("blocked", reuse="layer", drift_tau=0.05, max_stale=4)
    exact = np.asarray(digc(x, spec=_spec("blocked")))
    stat = np.asarray(drift_stat(x))

    # (a) zero drift, fresh age -> the corrupted cache is served
    entry, corrupt = _warm_corrupt_entry(x, exact, 3, snap=stat, age=0)
    idx, _ = digc(x, spec=spec, state=DigcState.init({"g": entry}),
                  state_key="g")
    np.testing.assert_array_equal(np.asarray(idx), corrupt)

    # (b) forced drift (snapshot far from the live statistic) -> rebuild
    entry, _ = _warm_corrupt_entry(x, exact, 3, snap=stat * 10.0, age=0)
    idx, st2 = digc(x, spec=spec, state=DigcState.init({"g": entry}),
                    state_key="g")
    np.testing.assert_array_equal(np.asarray(idx), exact)
    # ...and the rebuild repaired the cache + reset age
    np.testing.assert_array_equal(
        np.asarray(st2.entries["g"].graph_idx), exact)
    assert np.all(np.asarray(st2.entries["g"].graph_age) == 0)

    # (c) staleness expiry: zero drift but age at the bound -> rebuild
    entry, _ = _warm_corrupt_entry(x, exact, 3, snap=stat, age=4)
    idx, _ = digc(x, spec=spec, state=DigcState.init({"g": entry}),
                  state_key="g")
    np.testing.assert_array_equal(np.asarray(idx), exact)


def test_max_stale_expiry_cycles_age():
    """Identical inputs, max_stale=2: builds at t0, reuses twice, then
    the staleness bound forces a rebuild — age cycles 0,1,2,0,..."""
    rng = np.random.default_rng(3)
    b, n, d = 1, 24, 8
    x = _rand(rng, b, n, d)
    spec = _spec("blocked", reuse="layer", drift_tau=0.05, max_stale=2)
    st_ = DigcState.init({"g": _entry("blocked", b, n, d)})
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="g"))
    ages = []
    for _ in range(6):
        _, st_ = fn(x, st_)
        ages.append(int(np.asarray(st_.entries["g"].graph_age)[0]))
    assert ages == [0, 1, 2, 0, 1, 2]


# ---------------------------------------------------------------------------
# Per-row independence


def test_per_row_gate_matches_solo_replay():
    """Row 2's features churn every tick while rows 0/1 hold still: the
    co-batched stream must serve rows 0/1 from cache and rebuild row 2,
    each bitwise equal to that row's own B=1 replay."""
    rng = np.random.default_rng(4)
    n, d = 24, 8
    hold = _rand(rng, 2, n, d)
    spec = _spec("blocked", reuse="layer", drift_tau=0.05, max_stale=8)
    xs = []
    for _ in range(4):
        churn = _rand(rng, 1, n, d)  # fresh content -> large drift
        xs.append(jnp.concatenate([hold, churn], axis=0))

    batched, st_b = _stream(spec, xs, _entry("blocked", 3, n, d, rows=3))
    for row in range(3):
        solo, _ = _stream(spec, [x[row:row + 1] for x in xs],
                          _entry("blocked", 1, n, d, rows=1))
        for t in range(4):
            np.testing.assert_array_equal(batched[t][row], solo[t][0])

    ages = np.asarray(st_b.entries["g"].graph_age)
    assert ages[0] == ages[1] == 3  # held rows rode the cache
    assert ages[2] == 0             # churning row rebuilt every tick


# ---------------------------------------------------------------------------
# Serving lifecycle: parking carries the cached graph; stats counters


def _tiny_cfg():
    return vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3,
    )


def _mk(rng, tenant, img):
    return VigRequest(uid=int(rng.integers(1 << 30)),
                      image=img, tenant=tenant)


def test_engine_reuse_counters_and_drift_stats():
    cfg = _tiny_cfg()
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    spec = _spec("blocked", reuse="tick", drift_tau=0.05, max_stale=8)
    eng = VigServeEngine(cfg, params, digc_impl=spec, autotune=False,
                         buckets=(1, 2))
    rng = np.random.default_rng(5)
    imgs = {t: np.asarray(_rand(rng, 16, 16, 3)) for t in "AB"}
    ticks = 4
    for _ in range(ticks):
        eng.submit(_mk(rng, "A", imgs["A"]))
        eng.submit(_mk(rng, "B", imgs["B"]))
        eng.step()
    s = eng.stats()
    # every (lane, entry) event is classified exactly once
    assert s["graph_reuses"] + s["graph_rebuilds"] == ticks * 2
    assert s["graph_rebuilds"] == 2   # one cold build per tenant
    assert s["graph_reuses"] == (ticks - 1) * 2
    assert s["drift"]["mean"] == pytest.approx(0.0, abs=1e-6)
    assert "stage0" in s["drift"]["last"]


def test_engine_off_policy_keeps_counters_zero():
    cfg = _tiny_cfg()
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    eng = VigServeEngine(cfg, params, digc_impl="blocked", autotune=False,
                         buckets=(1,))
    rng = np.random.default_rng(6)
    img = np.asarray(_rand(rng, 16, 16, 3))
    for _ in range(2):
        eng.submit(_mk(rng, "A", img))
        eng.step()
    s = eng.stats()
    assert s["graph_reuses"] == 0 and s["graph_rebuilds"] == 0
    assert s["drift"] == {"mean": 0.0, "last": {}}


def test_park_readmit_carries_cached_graph():
    """Evict a warm reuse-tier tenant (parks its rows), re-admit it:
    the restored lane must *reuse* on its first tick back — the cached
    graph and its age/snapshot traveled through the park — and its
    logits must match an uninterrupted B=1 replay."""
    cfg = _tiny_cfg()
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    spec = _spec("blocked", reuse="tick", drift_tau=0.05, max_stale=16)
    eng = VigServeEngine(cfg, params, digc_impl=spec, autotune=False,
                         buckets=(1, 2))
    rng = np.random.default_rng(7)
    imgs = {t: np.asarray(_rand(rng, 16, 16, 3)) for t in "ABC"}

    history = []
    for _ in range(2):  # warm A and B
        ra = _mk(rng, "A", imgs["A"])
        eng.submit(ra), eng.submit(_mk(rng, "B", imgs["B"]))
        eng.step()
        history.append(ra)
    # C evicts the LRU tenant; both A and B predate C equally, so pin
    # the evictee by touching B first (A becomes LRU)
    eng.submit(_mk(rng, "B", imgs["B"])), eng.step()
    eng.submit(_mk(rng, "C", imgs["C"])), eng.step()
    assert "A" in eng._parked

    rebuilds_before = eng.stats()["graph_rebuilds"]
    r_back = _mk(rng, "A", imgs["A"])
    eng.submit(r_back), eng.step()
    s = eng.stats()
    assert eng.park_hits == 1
    # the re-admitted lane served from cache: no new rebuild was paid
    assert s["graph_rebuilds"] == rebuilds_before
    lane = eng._tenant_slot.get("A", eng._tenant_slot.get(("tenant", "A")))

    # bitwise parity with an uninterrupted solo replay of A's stream
    state = vig.init_vig_state(cfg, 1, spec, per_slot=True)
    fwd = jax.jit(lambda p, im, s_: vig.vig_forward(
        p, im, cfg, digc_impl=spec, state=s_))
    for r in history + [r_back]:
        logits, state = fwd(params, jnp.asarray(r.image)[None], state)
    np.testing.assert_allclose(r_back.logits, np.asarray(logits)[0],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Staleness invariant (property)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), max_stale=st.integers(1, 3),
       ticks=st.integers(2, 5))
def test_reuse_never_serves_older_than_max_stale(seed, max_stale, ticks):
    """After any drift sequence, no row's cached graph has been served
    past the staleness bound: ``graph_age <= max_stale`` always (the
    gate requires ``age < max_stale`` *before* serving, so post-serve
    age can touch the bound but never cross it)."""
    rng = np.random.default_rng(seed)
    b, n, d = 2, 16, 4
    spec = _spec("blocked", reuse="layer", drift_tau=0.1,
                 max_stale=max_stale)
    st_ = DigcState.init({"g": _entry("blocked", b, n, d)})
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="g"))
    x = _rand(rng, b, n, d)
    for _ in range(ticks):
        # random per-tick drift: sometimes tiny (reuse), sometimes large
        x = x + float(rng.choice([0.0, 0.01, 1.0])) * _rand(rng, b, n, d)
        _, st_ = fn(x, st_)
        assert np.all(np.asarray(st_.entries["g"].graph_age) <= max_stale)


# ---------------------------------------------------------------------------
# Tuner: reuse joins the schedule space under a recall floor


def _fake_ticks(rng, n_ticks, *, drift):
    h0 = rng.standard_normal((1, 24, 8)).astype(np.float32)
    ticks = []
    h = h0
    for _ in range(n_ticks):
        h = h + drift * rng.standard_normal(h.shape).astype(np.float32)
        ticks.append([("s0", jnp.asarray(h), None)])
    return ticks


def test_tune_reuse_static_stream_admits_widest_tau():
    rng = np.random.default_rng(8)
    ticks = _fake_ticks(rng, 5, drift=0.0)
    tuned, results = tune_reuse(ticks, spec=_spec("blocked"),
                                policy="layer", taus=(0.02, 0.1),
                                max_stale=8, recall_floor=0.95)
    assert tuned.reuse == "layer" and tuned.drift_tau == 0.1
    assert all(r.recall == 1.0 and r.admitted for r in results)
    assert results[-1].reuse_frac > 0


def test_tune_reuse_rejects_below_recall_floor():
    rng = np.random.default_rng(9)
    ticks = _fake_ticks(rng, 5, drift=2.0)  # graph churns every tick
    tuned, results = tune_reuse(ticks, spec=_spec("blocked"),
                                policy="layer", taus=(10.0,),
                                recall_floor=0.99)
    # tau=10 reuses through heavy churn -> recall collapses -> refused
    assert not results[0].admitted
    assert tuned.reuse is None  # spec returned unchanged

    with pytest.raises(ValueError):
        tune_reuse(ticks, spec=_spec("blocked"), policy="always")


def test_schedule_with_reuse_skips_stateless_tiers():
    sched = VigSchedule(stages=(
        DigcSpec(impl="blocked", k=3),
        DigcSpec(impl="pallas", k=3),
    ))
    assert not get_builder("pallas").supports_state
    out = sched.with_reuse("tick", 0.05, 4)
    assert out.stages[0].reuse == "tick"
    assert out.stages[0].drift_tau == 0.05
    assert out.stages[1].reuse is None  # kernel tier untouched
    stripped = out.with_reuse(None)
    assert all(s.reuse is None for s in stripped.stages)
    assert [d["reuse"] for d in out.describe()] == ["tick", None]
