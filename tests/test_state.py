"""Functional DIGC state (core/state.py): pytree round-trips through
jitted forwards, runtime-gated warm starts, donation, and parity with
the legacy eager DigcCache shim."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DigcSpec, digc
from repro.core.state import DigcState, DigcStateEntry, state_entry


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# DigcState as a pytree


def test_state_is_a_pytree_and_functional():
    st = DigcState.init({
        "a": state_entry(centroids_shape=(1, 4, 8)),
        "b": state_entry(),
    })
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 3  # a.step, a.centroids, b.step
    st2 = st.set("b", st.entries["b"].bump())
    assert st.steps() == {"a": 0, "b": 0}  # original untouched
    assert st2.steps() == {"a": 0, "b": 1}
    assert st.get("missing") is None and st.get(None) is None


def test_state_entry_warm_flag():
    e = state_entry(centroids_shape=(1, 2, 3))
    assert not bool(e.warm)
    assert bool(e.bump().warm)


# ---------------------------------------------------------------------------
# digc(..., state=) — the functional form


def test_digc_state_passthrough_for_stateless_builders():
    """A builder without state support (reference) must return the
    state unchanged — same object structure, same steps."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 2, 20, 6)
    st = DigcState.init({"k0": state_entry()})
    idx, new_st = digc(x, k=3, impl="reference", state=st, state_key="k0")
    assert new_st.steps() == {"k0": 0}
    np.testing.assert_array_equal(
        np.asarray(idx), np.asarray(digc(x, k=3, impl="reference"))
    )


def test_digc_state_missing_entry_passthrough():
    """state without an entry for the key: stateless compute, state
    passes through (entries are init-time only — structure is the
    compiled program's contract)."""
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 20, 6)
    st = DigcState.init({})
    idx, new_st = digc(x, k=3, impl="blocked", state=st, state_key="k0")
    assert len(new_st) == 0
    np.testing.assert_array_equal(
        np.asarray(idx), np.asarray(digc(x, k=3, impl="blocked"))
    )


def test_digc_state_and_cache_mutually_exclusive():
    from repro.core.engine import DigcCache

    rng = np.random.default_rng(2)
    x = _rand(rng, 10, 4)
    with pytest.raises(ValueError, match="not both"):
        digc(x, k=2, impl="blocked", state=DigcState.init({}),
             cache=DigcCache())


def test_blocked_gallery_norms_jit_exact_and_counted():
    """Frozen-gallery norms through a jitted digc: exact indices on
    every call, sq_y filled on the cold call, step counts requests."""
    rng = np.random.default_rng(3)
    x, y = _rand(rng, 2, 40, 8), _rand(rng, 2, 64, 8)
    i_ref = digc(x, y, k=5, impl="reference")
    st = DigcState.init({"gal": state_entry(sq_y_shape=(2, 64))})
    fn = jax.jit(
        lambda a, by, s: digc(a, by, k=5, impl="blocked",
                              state=s, state_key="gal")
    )
    i1, st = fn(x, y, st)
    i2, st = fn(x, y, st)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i_ref))
    assert st.steps() == {"gal": 2}
    np.testing.assert_allclose(
        np.asarray(st.entries["gal"].sq_y),
        np.asarray(jnp.sum(y * y, -1)), rtol=1e-6,
    )


def test_blocked_gallery_norms_warm_branch_engages():
    """Proof the warm branch actually reads the carried norms: a warm
    entry seeded with deliberately wrong sq_y must change the result
    (the cold path would recompute and hide the reuse)."""
    rng = np.random.default_rng(4)
    x, y = _rand(rng, 1, 24, 4), _rand(rng, 1, 32, 4)
    wrong = jnp.linspace(100.0, 1000.0, 32)[None, :]
    warm_entry = DigcStateEntry(
        step=jnp.ones((), jnp.int32), sq_y=wrong
    )
    _, d_warm, _ = digc(
        x, y, k=3, impl="blocked", return_dists=True,
        state=DigcState.init({"g": warm_entry}), state_key="g",
    )
    _, d_true = digc(x, y, k=3, impl="blocked", return_dists=True)
    assert not np.allclose(np.asarray(d_warm), np.asarray(d_true))


def test_cluster_state_jit_warm_start_recall_and_drift():
    """Cluster tier through jit: full probe + ample capacity stays
    exact cold AND warm; centroids drift when the features drift."""
    from repro.core.strategies import recall_vs_exact

    rng = np.random.default_rng(5)
    x1 = _rand(rng, 2, 128, 16)
    x2 = x1 + 0.05 * _rand(rng, 2, 128, 16)
    spec = DigcSpec(impl="cluster", k=4, n_clusters=8, n_probe=8,
                    capacity_factor=8.0)
    st = DigcState.init({"s0": state_entry(centroids_shape=(2, 8, 16))})
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="s0"))
    i_cold, st1 = fn(x1, st)
    c1 = np.asarray(st1.entries["s0"].centroids)
    assert st1.steps() == {"s0": 1}
    assert not np.allclose(c1, 0.0)  # cold call wrote real centroids
    i_warm, st2 = fn(x2, st1)
    c2 = np.asarray(st2.entries["s0"].centroids)
    assert st2.steps() == {"s0": 2}
    assert not np.array_equal(c1, c2)  # warm start tracked the drift
    assert recall_vs_exact(x1, x1, i_cold, 4) == 1.0
    assert recall_vs_exact(x2, x2, i_warm, 4) == 1.0


def test_cluster_state_shape_mismatch_is_cold_and_safe():
    """A stale-shaped centroid buffer (workload changed) must not be
    read or written — cold build, counter still advances."""
    rng = np.random.default_rng(6)
    x = _rand(rng, 2, 128, 16)
    spec = DigcSpec(impl="cluster", k=4, n_clusters=8, n_probe=8,
                    capacity_factor=8.0)
    stale = state_entry(centroids_shape=(2, 5, 16))  # wrong C
    st = DigcState.init({"s0": stale})
    idx, st1 = digc(x, spec=spec, state=st, state_key="s0")
    assert st1.steps() == {"s0": 1}
    assert st1.entries["s0"].centroids.shape == (2, 5, 16)  # untouched
    np.testing.assert_array_equal(
        np.asarray(st1.entries["s0"].centroids), np.zeros((2, 5, 16))
    )


# ---------------------------------------------------------------------------
# vig_forward round-trip


def _tiny_vig(impl):
    from repro.models import vig
    from repro.models.module import init_params

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3,
        digc_impl=impl,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    return vig, cfg, params, imgs


def test_vig_forward_state_roundtrip_jitted_cluster():
    """DigcState through a jitted vig_forward: warm start engages on
    call 2 (centroids move under feature drift), steps count blocks x
    requests, logits stay finite."""
    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    assert st.entries["stage0"].centroids is not None
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="cluster",
                                         state=s)
    )
    l1, st1 = fwd(params, imgs, st)
    c1 = np.asarray(st1.entries["stage0"].centroids)
    imgs2 = imgs + 0.1 * jax.random.normal(jax.random.PRNGKey(2), imgs.shape)
    l2, st2 = fwd(params, imgs2, st1)
    c2 = np.asarray(st2.entries["stage0"].centroids)
    assert st1.steps() == {"stage0": 2}  # 2 blocks
    assert st2.steps() == {"stage0": 4}
    assert not np.allclose(c1, 0.0) and not np.array_equal(c1, c2)
    assert bool(jnp.all(jnp.isfinite(l1))) and bool(jnp.all(jnp.isfinite(l2)))


def test_vig_forward_state_exact_tier_indices_unchanged():
    """For the exact blocked tier the state must be observationally
    inert: jitted state-threaded logits == stateless logits."""
    vig, cfg, params, imgs = _tiny_vig("blocked")
    st = vig.init_vig_state(cfg, 2, "blocked")
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="blocked",
                                         state=s)
    )
    l1, st1 = fwd(params, imgs, st)
    l2, st2 = fwd(params, imgs, st1)
    base = jax.jit(
        lambda p, im: vig.vig_forward(p, im, cfg, digc_impl="blocked")
    )(params, imgs)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    # self-graph stages carry no norm buffers, only counters
    assert st2.steps() == {"stage0": 4}


def test_vig_forward_state_donation():
    """The serving pattern: state donated into the jitted forward. The
    donated input must be consumed (non-CPU backends) and the carried
    state must keep working either way."""
    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="cluster",
                                         state=s),
        donate_argnums=(2,),
    )
    import warnings

    with warnings.catch_warnings():
        # CPU ignores donation with a warning; that is fine here.
        warnings.simplefilter("ignore")
        l1, st1 = fwd(params, imgs, st)
        l2, st2 = fwd(params, imgs, st1)
    assert st2.steps() == {"stage0": 4}
    assert bool(jnp.all(jnp.isfinite(l2)))
    if jax.default_backend() != "cpu":
        assert st.entries["stage0"].centroids.is_deleted()


def test_vig_forward_state_matches_eager_cache_shim():
    """Pytree path vs the legacy eager DigcCache shim: same Lloyd
    schedule (cold 5 iters, warm 2), so the cluster-tier logits agree
    request over request."""
    from repro.core.engine import DigcCache

    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    cache = DigcCache()
    for _ in range(2):
        l_state, st = vig.vig_forward(params, imgs, cfg,
                                      digc_impl="cluster", state=st)
        l_cache = vig.vig_forward(params, imgs, cfg, digc_impl="cluster",
                                  cache=cache)
        np.testing.assert_allclose(
            np.asarray(l_state), np.asarray(l_cache), rtol=1e-4, atol=1e-4
        )
    assert cache.stats()["hits"] >= 1


def test_init_vig_state_pyramid_shapes():
    """Pyramid models get one entry per stage; cluster stages size
    their centroid buffers off the stage's pooled co-node count."""
    from repro.core.strategies import default_cluster_params
    from repro.models import vig

    cfg = vig.VIG_VARIANTS["vig_ti_pyr"].replace(
        image_size=32, embed_dims=(8, 12, 16, 24), depths=(1, 1, 1, 1),
        num_classes=3, k=3,
    )
    st = vig.init_vig_state(cfg, 4, "cluster")
    assert sorted(st.entries) == ["stage0", "stage1", "stage2", "stage3"]
    grid = cfg.base_grid
    for si in range(4):
        r = cfg.reduce_ratios[si]
        m = (grid // max(r, 1)) ** 2
        nc, _ = default_cluster_params(m, None, None)
        e = st.entries[f"stage{si}"]
        assert e.centroids.shape == (4, nc, cfg.embed_dims[si])
        if si < 3:
            grid //= 2
    # non-cluster impls: counters only
    st_b = vig.init_vig_state(cfg, 4, "blocked")
    assert all(e.centroids is None for e in st_b.entries.values())
