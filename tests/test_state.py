"""Functional DIGC state (core/state.py): pytree round-trips through
jitted forwards, runtime-gated warm starts, donation, and parity with
the legacy eager DigcCache shim."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DigcSpec, digc
from repro.core.state import DigcState, DigcStateEntry, state_entry


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# DigcState as a pytree


def test_state_is_a_pytree_and_functional():
    st = DigcState.init({
        "a": state_entry(centroids_shape=(1, 4, 8)),
        "b": state_entry(),
    })
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 3  # a.step, a.centroids, b.step
    st2 = st.set("b", st.entries["b"].bump())
    assert st.steps() == {"a": 0, "b": 0}  # original untouched
    assert st2.steps() == {"a": 0, "b": 1}
    assert st.get("missing") is None and st.get(None) is None


def test_state_entry_warm_flag():
    e = state_entry(centroids_shape=(1, 2, 3))
    assert not bool(e.warm)
    assert bool(e.bump().warm)


def test_state_entry_row_counters_and_bump():
    """Per-row counters (multi-tenant serving): allocated via rows=,
    advanced by bump alongside the scalar step, row_warm per row."""
    e = state_entry(centroids_shape=(3, 2, 4), rows=3)
    assert e.row_step.shape == (3,) and e.row_warm is not None
    assert not bool(jnp.any(e.row_warm))
    e2 = e.bump()
    assert int(e2.step) == 1
    np.testing.assert_array_equal(np.asarray(e2.row_step), [1, 1, 1])
    assert bool(jnp.all(e2.row_warm))
    # legacy entries carry no row counters: pytree structure unchanged
    assert state_entry().row_step is None
    assert state_entry().row_warm is None
    assert len(jax.tree_util.tree_leaves(state_entry())) == 1


def test_state_row_lifecycle_take_put_reset():
    """The serving slot lifecycle: gather slot rows into a bucket batch
    (repeats = padding lanes), scatter live lanes back (padding lanes
    dropped), cold-reset a reassigned slot."""
    st = DigcState.init({
        "s": state_entry(centroids_shape=(4, 2, 3), sq_y_shape=(4, 5),
                         rows=4),
    })
    # make rows distinguishable: row r's centroids are all r+1
    marked = DigcStateEntry(
        step=jnp.int32(7),
        centroids=jnp.arange(1, 5, dtype=jnp.float32)[:, None, None]
        * jnp.ones((4, 2, 3)),
        sq_y=jnp.arange(1, 5, dtype=jnp.float32)[:, None] * jnp.ones((4, 5)),
        row_step=jnp.asarray([3, 0, 2, 1], jnp.int32),
    )
    st = st.set("s", marked)
    # bucket of 4 over lanes [2, 0] + padding replicating lane 0 (slot 2)
    bucket = st.take_rows([2, 0, 2, 2])
    b = bucket.entries["s"]
    np.testing.assert_array_equal(np.asarray(b.row_step), [2, 3, 2, 2])
    np.testing.assert_array_equal(np.asarray(b.centroids[1]),
                                  np.asarray(marked.centroids[0]))
    assert int(b.step) == 7
    # the forward bumps; pretend it also rewrote centroids
    served = bucket.set("s", b.bump(centroids=b.centroids + 100.0))
    back = st.put_rows(served, [2, 0])
    a = back.entries["s"]
    # live lanes landed at their slots
    np.testing.assert_array_equal(np.asarray(a.row_step), [4, 0, 3, 1])
    np.testing.assert_allclose(np.asarray(a.centroids[2]),
                               np.asarray(marked.centroids[2]) + 100.0)
    np.testing.assert_allclose(np.asarray(a.centroids[0]),
                               np.asarray(marked.centroids[0]) + 100.0)
    # padding lanes (src rows 2, 3) dropped: untouched slots identical
    np.testing.assert_array_equal(np.asarray(a.centroids[1]),
                                  np.asarray(marked.centroids[1]))
    np.testing.assert_array_equal(np.asarray(a.centroids[3]),
                                  np.asarray(marked.centroids[3]))
    np.testing.assert_array_equal(np.asarray(a.sq_y[3]),
                                  np.asarray(marked.sq_y[3]))
    assert int(a.step) == 8  # scalar counter taken from the served entry
    # reset: slot 0 reassigned to a new tenant -> cold zero rows
    reset = back.reset_rows([0])
    r = reset.entries["s"]
    np.testing.assert_array_equal(np.asarray(r.row_step), [0, 0, 3, 1])
    np.testing.assert_array_equal(np.asarray(r.centroids[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(r.sq_y[0]), 0.0)
    np.testing.assert_allclose(np.asarray(r.centroids[2]),
                               np.asarray(a.centroids[2]))
    assert back.row_steps() == {"s": [4, 0, 3, 1]}


# ---------------------------------------------------------------------------
# digc(..., state=) — the functional form


def test_digc_state_passthrough_for_stateless_builders():
    """A builder without state support (reference) must return the
    state unchanged — same object structure, same steps."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 2, 20, 6)
    st = DigcState.init({"k0": state_entry()})
    idx, new_st = digc(x, k=3, impl="reference", state=st, state_key="k0")
    assert new_st.steps() == {"k0": 0}
    np.testing.assert_array_equal(
        np.asarray(idx), np.asarray(digc(x, k=3, impl="reference"))
    )


def test_digc_state_missing_entry_passthrough():
    """state without an entry for the key: stateless compute, state
    passes through (entries are init-time only — structure is the
    compiled program's contract)."""
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 20, 6)
    st = DigcState.init({})
    idx, new_st = digc(x, k=3, impl="blocked", state=st, state_key="k0")
    assert len(new_st) == 0
    np.testing.assert_array_equal(
        np.asarray(idx), np.asarray(digc(x, k=3, impl="blocked"))
    )


def test_digc_state_and_cache_mutually_exclusive():
    from repro.core.engine import DigcCache

    rng = np.random.default_rng(2)
    x = _rand(rng, 10, 4)
    with pytest.raises(ValueError, match="not both"):
        digc(x, k=2, impl="blocked", state=DigcState.init({}),
             cache=DigcCache())


def test_blocked_gallery_norms_jit_exact_and_counted():
    """Frozen-gallery norms through a jitted digc: exact indices on
    every call, sq_y filled on the cold call, step counts requests."""
    rng = np.random.default_rng(3)
    x, y = _rand(rng, 2, 40, 8), _rand(rng, 2, 64, 8)
    i_ref = digc(x, y, k=5, impl="reference")
    st = DigcState.init({"gal": state_entry(sq_y_shape=(2, 64))})
    fn = jax.jit(
        lambda a, by, s: digc(a, by, k=5, impl="blocked",
                              state=s, state_key="gal")
    )
    i1, st = fn(x, y, st)
    i2, st = fn(x, y, st)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i_ref))
    assert st.steps() == {"gal": 2}
    np.testing.assert_allclose(
        np.asarray(st.entries["gal"].sq_y),
        np.asarray(jnp.sum(y * y, -1)), rtol=1e-6,
    )


def test_blocked_gallery_norms_warm_branch_engages():
    """Proof the warm branch actually reads the carried norms: a warm
    entry seeded with deliberately wrong sq_y must change the result
    (the cold path would recompute and hide the reuse)."""
    rng = np.random.default_rng(4)
    x, y = _rand(rng, 1, 24, 4), _rand(rng, 1, 32, 4)
    wrong = jnp.linspace(100.0, 1000.0, 32)[None, :]
    warm_entry = DigcStateEntry(
        step=jnp.ones((), jnp.int32), sq_y=wrong
    )
    _, d_warm, _ = digc(
        x, y, k=3, impl="blocked", return_dists=True,
        state=DigcState.init({"g": warm_entry}), state_key="g",
    )
    _, d_true = digc(x, y, k=3, impl="blocked", return_dists=True)
    assert not np.allclose(np.asarray(d_warm), np.asarray(d_true))


def test_cluster_state_jit_warm_start_recall_and_drift():
    """Cluster tier through jit: full probe + ample capacity stays
    exact cold AND warm; centroids drift when the features drift."""
    from repro.core.strategies import recall_vs_exact

    rng = np.random.default_rng(5)
    x1 = _rand(rng, 2, 128, 16)
    x2 = x1 + 0.05 * _rand(rng, 2, 128, 16)
    spec = DigcSpec(impl="cluster", k=4, n_clusters=8, n_probe=8,
                    capacity_factor=8.0)
    st = DigcState.init({"s0": state_entry(centroids_shape=(2, 8, 16))})
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="s0"))
    i_cold, st1 = fn(x1, st)
    c1 = np.asarray(st1.entries["s0"].centroids)
    assert st1.steps() == {"s0": 1}
    assert not np.allclose(c1, 0.0)  # cold call wrote real centroids
    i_warm, st2 = fn(x2, st1)
    c2 = np.asarray(st2.entries["s0"].centroids)
    assert st2.steps() == {"s0": 2}
    assert not np.array_equal(c1, c2)  # warm start tracked the drift
    assert recall_vs_exact(x1, x1, i_cold, 4) == 1.0
    assert recall_vs_exact(x2, x2, i_warm, 4) == 1.0


def test_cluster_rowwise_warm_gate_matches_b1_replay():
    """Per-row warm gating (multi-tenant batches): a batch mixing a
    warm row with a freshly reset (cold) row must give each row exactly
    what a B=1 call with that row's own state history gives — warm rows
    the 2-Lloyd refinement, cold rows the full cold build."""
    rng = np.random.default_rng(40)
    x1 = _rand(rng, 3, 64, 8)
    x2 = x1 + 0.05 * _rand(rng, 3, 64, 8)
    spec = DigcSpec(impl="cluster", k=4, n_clusters=4, n_probe=4,
                    capacity_factor=8.0)
    st = DigcState.init({
        "s": state_entry(centroids_shape=(3, 4, 8), rows=3)
    })
    fn = jax.jit(lambda a, s: digc(a, spec=spec, state=s, state_key="s"))
    _, st1 = fn(x1, st)
    assert st1.row_steps() == {"s": [1, 1, 1]}
    # row 2's tenant evicted: cold reset; rows 0/1 stay warm
    i_mixed, st2 = fn(x2, st1.reset_rows([2]))
    assert st2.row_steps() == {"s": [2, 2, 1]}

    def replay(row, warm):
        s = DigcState.init({
            "s": state_entry(centroids_shape=(1, 4, 8), rows=1)
        })
        f1 = jax.jit(lambda a, sv: digc(a, spec=spec, state=sv,
                                        state_key="s"))
        if warm:
            _, s = f1(x1[row:row + 1], s)
        idx, _ = f1(x2[row:row + 1], s)
        return np.asarray(idx)[0]

    np.testing.assert_array_equal(np.asarray(i_mixed[0]), replay(0, True))
    np.testing.assert_array_equal(np.asarray(i_mixed[1]), replay(1, True))
    np.testing.assert_array_equal(np.asarray(i_mixed[2]), replay(2, False))


def test_blocked_rowwise_gallery_norms_exact_after_reset():
    """Blocked frozen-gallery norms with per-row counters stay exact
    through resets (warm rows read carried norms, reset rows
    recompute)."""
    rng = np.random.default_rng(41)
    x, y = _rand(rng, 2, 20, 6), _rand(rng, 2, 32, 6)
    i_ref = digc(x, y, k=3, impl="reference")
    st = DigcState.init({"g": state_entry(sq_y_shape=(2, 32), rows=2)})
    fn = jax.jit(lambda a, by, s: digc(a, by, k=3, impl="blocked",
                                       state=s, state_key="g"))
    i1, st = fn(x, y, st)
    i2, st = fn(x, y, st.reset_rows([0]))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i_ref))
    assert st.row_steps() == {"g": [1, 2]}
    np.testing.assert_allclose(np.asarray(st.entries["g"].sq_y),
                               np.asarray(jnp.sum(y * y, -1)), rtol=1e-6)


def test_init_vig_state_per_slot_rows():
    """per_slot=True allocates (B,) row counters on every stage entry
    (the multi-tenant serving layout)."""
    from repro.models import vig

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3,
    )
    st = vig.init_vig_state(cfg, 4, "cluster", per_slot=True)
    e = st.entries["stage0"]
    assert e.row_step.shape == (4,) and e.centroids is not None
    assert st.row_steps() == {"stage0": [0, 0, 0, 0]}
    # default stays the single-tenant layout (no row counters)
    st_flat = vig.init_vig_state(cfg, 4, "cluster")
    assert st_flat.entries["stage0"].row_step is None


def test_cluster_state_shape_mismatch_is_cold_and_safe():
    """A stale-shaped centroid buffer (workload changed) must not be
    read or written — cold build, counter still advances."""
    rng = np.random.default_rng(6)
    x = _rand(rng, 2, 128, 16)
    spec = DigcSpec(impl="cluster", k=4, n_clusters=8, n_probe=8,
                    capacity_factor=8.0)
    stale = state_entry(centroids_shape=(2, 5, 16))  # wrong C
    st = DigcState.init({"s0": stale})
    idx, st1 = digc(x, spec=spec, state=st, state_key="s0")
    assert st1.steps() == {"s0": 1}
    assert st1.entries["s0"].centroids.shape == (2, 5, 16)  # untouched
    np.testing.assert_array_equal(
        np.asarray(st1.entries["s0"].centroids), np.zeros((2, 5, 16))
    )


# ---------------------------------------------------------------------------
# vig_forward round-trip


def _tiny_vig(impl):
    from repro.models import vig
    from repro.models.module import init_params

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=32, embed_dims=(16,), depths=(2,), num_classes=3, k=3,
        digc_impl=impl,
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    return vig, cfg, params, imgs


def test_vig_forward_state_roundtrip_jitted_cluster():
    """DigcState through a jitted vig_forward: warm start engages on
    call 2 (centroids move under feature drift), steps count blocks x
    requests, logits stay finite."""
    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    assert st.entries["stage0"].centroids is not None
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="cluster",
                                         state=s)
    )
    l1, st1 = fwd(params, imgs, st)
    c1 = np.asarray(st1.entries["stage0"].centroids)
    imgs2 = imgs + 0.1 * jax.random.normal(jax.random.PRNGKey(2), imgs.shape)
    l2, st2 = fwd(params, imgs2, st1)
    c2 = np.asarray(st2.entries["stage0"].centroids)
    assert st1.steps() == {"stage0": 2}  # 2 blocks
    assert st2.steps() == {"stage0": 4}
    assert not np.allclose(c1, 0.0) and not np.array_equal(c1, c2)
    assert bool(jnp.all(jnp.isfinite(l1))) and bool(jnp.all(jnp.isfinite(l2)))


def test_vig_forward_state_exact_tier_indices_unchanged():
    """For the exact blocked tier the state must be observationally
    inert: jitted state-threaded logits == stateless logits."""
    vig, cfg, params, imgs = _tiny_vig("blocked")
    st = vig.init_vig_state(cfg, 2, "blocked")
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="blocked",
                                         state=s)
    )
    l1, st1 = fwd(params, imgs, st)
    l2, st2 = fwd(params, imgs, st1)
    base = jax.jit(
        lambda p, im: vig.vig_forward(p, im, cfg, digc_impl="blocked")
    )(params, imgs)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    # self-graph stages carry no norm buffers, only counters
    assert st2.steps() == {"stage0": 4}


def test_vig_forward_state_donation():
    """The serving pattern: state donated into the jitted forward. The
    donated input must be consumed (non-CPU backends) and the carried
    state must keep working either way."""
    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    fwd = jax.jit(
        lambda p, im, s: vig.vig_forward(p, im, cfg, digc_impl="cluster",
                                         state=s),
        donate_argnums=(2,),
    )
    import warnings

    with warnings.catch_warnings():
        # CPU ignores donation with a warning; that is fine here.
        warnings.simplefilter("ignore")
        l1, st1 = fwd(params, imgs, st)
        l2, st2 = fwd(params, imgs, st1)
    assert st2.steps() == {"stage0": 4}
    assert bool(jnp.all(jnp.isfinite(l2)))
    if jax.default_backend() != "cpu":
        assert st.entries["stage0"].centroids.is_deleted()


def test_vig_forward_state_matches_eager_cache_shim():
    """Pytree path vs the legacy eager DigcCache shim: same Lloyd
    schedule (cold 5 iters, warm 2), so the cluster-tier logits agree
    request over request."""
    from repro.core.engine import DigcCache

    vig, cfg, params, imgs = _tiny_vig("cluster")
    st = vig.init_vig_state(cfg, 2, "cluster")
    cache = DigcCache()
    for _ in range(2):
        l_state, st = vig.vig_forward(params, imgs, cfg,
                                      digc_impl="cluster", state=st)
        l_cache = vig.vig_forward(params, imgs, cfg, digc_impl="cluster",
                                  cache=cache)
        np.testing.assert_allclose(
            np.asarray(l_state), np.asarray(l_cache), rtol=1e-4, atol=1e-4
        )
    assert cache.stats()["hits"] >= 1


def test_init_vig_state_pyramid_shapes():
    """Pyramid models get one entry per stage; cluster stages size
    their centroid buffers off the stage's pooled co-node count."""
    from repro.core.strategies import default_cluster_params
    from repro.models import vig

    cfg = vig.VIG_VARIANTS["vig_ti_pyr"].replace(
        image_size=32, embed_dims=(8, 12, 16, 24), depths=(1, 1, 1, 1),
        num_classes=3, k=3,
    )
    st = vig.init_vig_state(cfg, 4, "cluster")
    assert sorted(st.entries) == ["stage0", "stage1", "stage2", "stage3"]
    grid = cfg.base_grid
    for si in range(4):
        r = cfg.reduce_ratios[si]
        m = (grid // max(r, 1)) ** 2
        nc, _ = default_cluster_params(m, None, None)
        e = st.entries[f"stage{si}"]
        assert e.centroids.shape == (4, nc, cfg.embed_dims[si])
        if si < 3:
            grid //= 2
    # non-cluster impls: counters only
    st_b = vig.init_vig_state(cfg, 4, "blocked")
    assert all(e.centroids is None for e in st_b.entries.values())


# ---------------------------------------------------------------------------
# Sharding-aware allocation + row ops (DESIGN.md §10)


def test_state_entry_mesh_placement():
    """``state_entry(mesh=)`` places the buffers with PartitionSpecs:
    ``sq_y`` partitioned along the ring axis on its co-node dim, the
    counters and centroids replicated — and a co-node count that does
    not divide the axis falls back to replication (placement is a
    performance choice, never a semantic one)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    e = state_entry(sq_y_shape=(2, 8), centroids_shape=(2, 3, 4), rows=2,
                    mesh=mesh)
    assert isinstance(e.sq_y.sharding, NamedSharding)
    assert e.sq_y.sharding.spec == P(None, "data")
    assert e.centroids.sharding.spec == P()
    assert e.row_step.sharding.spec == P()
    # step counter semantics unchanged
    assert int(e.step) == 0 and int(e.bump().step) == 1
    # a placement axis the mesh does not have is a named error, not a
    # KeyError deep in the divisibility check
    with pytest.raises(ValueError, match="not an axis"):
        state_entry(sq_y_shape=(1, 8), mesh=mesh, axis_name="ring")
    # (the ragged-M replicated fallback needs a >1-device axis to be
    # observable; asserted in test_ring's 4-device subprocess)


def test_state_row_ops_preserve_named_sharding():
    """take_rows / put_rows / reset_rows keep sharded entries on their
    mesh — an eager slot-lifecycle pass must not collapse a
    device-resident buffer onto the default device — and accept
    host-side (numpy) source rows, the parking round trip."""
    mesh = jax.make_mesh((1,), ("data",))
    st = DigcState.init({
        "s": state_entry(sq_y_shape=(4, 8), centroids_shape=(4, 2, 3),
                         rows=4, mesh=mesh),
    })
    want = st.entries["s"].sq_y.sharding
    bucket = st.take_rows([2, 0, 2, 2])
    assert bucket.entries["s"].sq_y.sharding == want
    back = st.put_rows(bucket, [1, 3])
    assert back.entries["s"].sq_y.sharding == want
    assert back.entries["s"].centroids.sharding == st.entries["s"].centroids.sharding
    reset = back.reset_rows([0])
    assert reset.entries["s"].sq_y.sharding == want
    # parking round trip: host copies scatter back onto the mesh
    parked = jax.tree_util.tree_map(np.asarray, st.take_rows([1]))
    restored = st.put_rows(parked, [2])
    assert restored.entries["s"].sq_y.sharding == want


def test_init_vig_state_mesh_placement_and_spec_mesh_wins():
    """``init_vig_state(mesh=)`` places every stage entry; a stage spec
    that names its own mesh/axis wins over the argument."""
    from repro.core.builder import DigcSpec
    from repro.models import vig

    mesh = jax.make_mesh((1,), ("data",))
    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=16, patch=4, embed_dims=(16,), depths=(2,),
        num_classes=3, k=3,
    )
    st = vig.init_vig_state(cfg, 2, "cluster", per_slot=True, mesh=mesh)
    e = st.entries["stage0"]
    assert e.row_step.sharding.mesh.shape == {"data": 1}
    spec = DigcSpec(impl="ring", mesh=mesh, axis_name="data")
    st2 = vig.init_vig_state(cfg, 2, spec, per_slot=True)
    assert st2.entries["stage0"].row_step.sharding.mesh.shape == {"data": 1}
