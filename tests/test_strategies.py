"""Alternative construction strategies (paper §VI modularity claim):
ClusterViG-family IVF search and GreedyViG-family axial graphs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.digc import BIG, digc_reference
from repro.core.strategies import axial_digc, cluster_digc, kmeans, recall_vs_exact


def test_kmeans_reduces_quantization_error():
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    c1 = kmeans(y, 8, iters=1)
    c8 = kmeans(y, 8, iters=8)

    def qerr(c):
        d = jnp.min(
            jnp.sum((y[:, None] - c[None]) ** 2, -1), axis=1
        )
        return float(jnp.mean(d))

    assert qerr(c8) <= qerr(c1) + 1e-5


def test_cluster_recall_improves_with_probes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((400, 48)), jnp.float32)
    i_lo = cluster_digc(x, k=8, n_clusters=20, n_probe=2)
    i_hi = cluster_digc(x, k=8, n_clusters=20, n_probe=16)
    r_lo = recall_vs_exact(x, x, i_lo, 8)
    r_hi = recall_vs_exact(x, x, i_hi, 8)
    assert r_hi > r_lo
    assert r_hi > 0.85  # probing 16/20 clusters ~ near-exact


def test_cluster_full_probe_is_near_exact():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((200, 24)), jnp.float32)
    idx = cluster_digc(x, k=5, n_clusters=8, n_probe=8, capacity_factor=8.0)
    # probing all clusters with no capacity drops == exact
    assert recall_vs_exact(x, x, idx, 5) == 1.0


def test_cluster_clustered_data_high_recall_few_probes():
    """On genuinely clustered data (the ViG-feature regime) few probes
    suffice — the ClusterViG premise."""
    rng = np.random.default_rng(3)
    centers = rng.standard_normal((8, 24)) * 10
    pts = np.concatenate(
        [centers[i] + 0.1 * rng.standard_normal((50, 24)) for i in range(8)]
    )
    x = jnp.asarray(pts, jnp.float32)
    idx = cluster_digc(x, k=5, n_clusters=8, n_probe=2, capacity_factor=2.0)
    assert recall_vs_exact(x, x, idx, 5) > 0.95


def test_axial_support_and_exactness_within_support():
    rng = np.random.default_rng(4)
    h, w, d, k = 8, 8, 16, 4
    x = jnp.asarray(rng.standard_normal((h * w, d)), jnp.float32)
    idx, dist = axial_digc(x, grid_h=h, grid_w=w, k=k, return_dists=True)
    idx_np = np.asarray(idx)
    for i in range(h * w):
        r, c = divmod(i, w)
        for j in idx_np[i]:
            jr, jc = divmod(int(j), w)
            assert jr == r or jc == c, (i, j)  # axial support
    # exact top-k *within* the axial support
    xn = np.asarray(x)
    for i in range(0, h * w, 7):
        r, c = divmod(i, w)
        support = [r * w + cc for cc in range(w)] + [rr * w + c for rr in range(h)]
        ds = {j: float(np.sum((xn[j] - xn[i]) ** 2)) for j in support}
        best = sorted(set(ds), key=lambda j: (ds[j]))[:k]
        got = sorted(idx_np[i].tolist(), key=lambda j: ds[int(j)])[:k]
        assert sorted(ds[j] for j in best) == pytest.approx(
            sorted(ds[int(j)] for j in got), rel=1e-5
        )


def test_axial_self_first():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((36, 8)), jnp.float32)
    idx = axial_digc(x, grid_h=6, grid_w=6, k=3)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.arange(36))


def test_vig_runs_with_all_strategies():
    from repro.models import vig
    from repro.models.module import init_params

    cfg = vig.VIG_VARIANTS["vig_ti_iso"].replace(
        image_size=64, embed_dims=(32,), depths=(1,), num_classes=5, k=3
    )
    params = init_params(vig.vig_param_spec(cfg), jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    for impl in ("blocked", "cluster", "axial"):
        out = vig.vig_forward(params, imgs, cfg, digc_impl=impl)
        assert out.shape == (1, 5)
        assert bool(jnp.all(jnp.isfinite(out))), impl
