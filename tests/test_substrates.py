"""Substrate tests: data pipeline, checkpointing, optimizer, training
loop convergence, serving engine, straggler monitor, compression math."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, lm_pipeline, synth_lm_batch
from repro.distributed.compression import ErrorFeedback, dequantize_int8, quantize_int8
from repro.distributed.straggler import StragglerConfig, StragglerMonitor, aggregate_host_times
from repro.launch.api import get_api
from repro.models.module import init_params
from repro.train.optimizer import OptConfig, lr_at
from repro.train.trainer import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# data


def test_pipeline_deterministic_across_restart():
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=97, seed=7)
    b1 = synth_lm_batch(dc, 5)
    b2 = synth_lm_batch(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synth_lm_batch(dc, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_sharding_disjoint():
    a = synth_lm_batch(DataConfig(32, 8, 97, seed=1, num_hosts=2, host_id=0), 0)
    b = synth_lm_batch(DataConfig(32, 8, 97, seed=1, num_hosts=2, host_id=1), 0)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetch_iterator_order():
    dc = DataConfig(seq_len=8, global_batch=2, vocab_size=11, seed=3)
    pipe = lm_pipeline(dc, start_step=10)
    try:
        steps = [next(pipe)[0] for _ in range(4)]
        assert steps == [10, 11, 12, 13]
    finally:
        pipe.close()


def test_labels_are_next_tokens():
    dc = DataConfig(seq_len=16, global_batch=2, vocab_size=31, seed=0)
    b = synth_lm_batch(dc, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": (jnp.zeros((2,)), jnp.full((3,), 7.0))}
    ckpt.save(tmp_path, 3, tree)
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 3
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_latest_and_gc(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step"))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"w": jnp.ones((2,))}
    ckpt.save(tmp_path, 1, tree)
    # fake a crashed half-write at step 2
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    assert ckpt.latest_step(tmp_path) == 1


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.arange(4.0)}
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.save(7, tree)
    saver.wait()
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"w": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# optimizer / training


def test_lr_schedule():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(jnp.int32(0), oc)) == 0.0
    assert abs(float(lr_at(jnp.int32(10), oc)) - 1.0) < 1e-6
    assert float(lr_at(jnp.int32(100), oc)) == pytest.approx(0.1, rel=1e-3)


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = get_smoke("olmo-1b")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step_fn = jax.jit(make_train_step(cfg, oc, loss_fn=api.loss_fn))
    opt = init_train_state(params)
    dc = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size, seed=0)
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in synth_lm_batch(dc, s).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, losses[::10]


def test_grad_accumulation_equivalence():
    cfg = get_smoke("olmo-1b").replace(dtype="float32")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = DataConfig(seq_len=16, global_batch=4, vocab_size=cfg.vocab_size, seed=0)
    batch = {k: jnp.asarray(v) for k, v in synth_lm_batch(dc, 0).items()}
    s1 = make_train_step(cfg, oc, loss_fn=api.loss_fn, accum_steps=1,
                         param_dtype=jnp.float32)
    s2 = make_train_step(cfg, oc, loss_fn=api.loss_fn, accum_steps=2,
                         param_dtype=jnp.float32)
    opt = init_train_state(params)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    # microbatched grads average to ~the full-batch grads (exact up to
    # per-microbatch loss normalization with uniform masks)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# serving


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke("olmo-1b")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 3
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_greedy_matches_direct_decode():
    from repro.serve.engine import Request, ServeEngine
    from repro.models import transformer as tr

    cfg = get_smoke("olmo-1b").replace(dtype="float32")
    api = get_api(cfg)
    params = init_params(api.param_spec(), jax.random.PRNGKey(0))
    prompt = np.asarray([5, 9, 2], np.int32)
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    out = eng.run()[0].out_tokens
    # direct greedy decode
    cache = tr.init_cache(cfg, 1, 16)
    toks = list(prompt)
    ref = []
    for t in range(len(prompt) + 3):
        cur = jnp.asarray([[toks[t] if t < len(toks) else ref[-1]]], jnp.int32)
        lg, cache = tr.decode_step(params, cache, cur, jnp.int32(t), cfg)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(lg[0, -1]))
            ref.append(nxt)
            if t >= len(prompt):
                toks.append(nxt)
    assert out == ref[:4]


# ---------------------------------------------------------------------------
# distributed utilities


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    resid = ErrorFeedback.init(g)
    total_q = np.zeros(512)
    for _ in range(50):
        q, resid = ErrorFeedback.apply(g, resid)
        total_q += np.asarray(q)
    # accumulated quantized stream approximates accumulated true grads
    np.testing.assert_allclose(total_q / 50, np.asarray(g), atol=2e-4)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(StragglerConfig(window=20, mad_k=4, min_samples=5))
    for _ in range(10):
        mon.record(0.1)
    assert not mon.is_straggler(0.105)
    assert mon.is_straggler(0.5)


def test_aggregate_host_times():
    times = {0: 0.1, 1: 0.11, 2: 0.1, 3: 0.98}
    assert aggregate_host_times(times) == [3]
